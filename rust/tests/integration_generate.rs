//! Integration: autoregressive generation through a backend's fwd path.
//!
//! Runs un-ignored on the **native backend** (offline, artifact-free); the
//! same `generate()` entry point drives the PJRT artifact path unchanged
//! once `make artifacts` exists, because generation is written against the
//! `ExecBackend` trait.

mod common;

use common::{tiny_manifest, tiny_schedule};
use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::generate::{generate, Sampler};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::Manifest;

fn setup() -> (NativeBackend, texpand::runtime::StageExec, ParamStore, usize) {
    let m = tiny_manifest();
    let mut be = NativeBackend::new();
    let stage = be.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(77);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = m.batch;
    (be, stage, params, batch)
}

#[test]
fn generates_requested_length_and_valid_tokens() {
    let (be, stage, params, batch) = setup();
    let vocab = params.config().vocab as u32;
    let prompts = vec![vec![10u32, 20, 30]; batch];
    let s = Sampler { temperature: 0.9, top_k: Some(20), seed: 1 };
    let out = generate(&be, &stage, &params, &prompts, 12, &s).unwrap();
    assert_eq!(out.len(), batch);
    for row in &out {
        assert_eq!(row.len(), 3 + 12);
        assert_eq!(&row[..3], &[10, 20, 30], "prompt must be preserved");
        assert!(row.iter().all(|&t| t < vocab));
    }
}

#[test]
fn greedy_generation_is_deterministic() {
    let (be, stage, params, batch) = setup();
    let prompts = vec![vec![5u32]; batch];
    let s = Sampler { temperature: 0.0, top_k: None, seed: 1 };
    let a = generate(&be, &stage, &params, &prompts, 8, &s).unwrap();
    let b = generate(&be, &stage, &params, &prompts, 8, &s).unwrap();
    assert_eq!(a, b);
}

#[test]
fn sampling_seed_changes_output() {
    let (be, stage, params, batch) = setup();
    let prompts = vec![vec![5u32, 6]; batch];
    let a = generate(&be, &stage, &params, &prompts, 16, &Sampler { temperature: 1.0, top_k: None, seed: 1 }).unwrap();
    let b = generate(&be, &stage, &params, &prompts, 16, &Sampler { temperature: 1.0, top_k: None, seed: 2 }).unwrap();
    assert_ne!(a, b);
}

#[test]
fn generation_slides_past_seq_window() {
    let (be, stage, params, batch) = setup();
    let seq = params.config().seq;
    // prompt nearly fills the window; generation must continue past it
    let prompts = vec![(0..(seq - 2) as u32).map(|t| t % 50).collect::<Vec<u32>>(); batch];
    let s = Sampler { temperature: 0.5, top_k: Some(10), seed: 3 };
    let out = generate(&be, &stage, &params, &prompts, 10, &s).unwrap();
    assert_eq!(out[0].len(), seq - 2 + 10);
}

#[test]
fn generation_preserved_across_expansion() {
    // greedy decode from expanded params must equal decode from the base:
    // function preservation extends to the entire autoregressive rollout.
    let m = tiny_manifest();
    let mut be = NativeBackend::new();
    let stage0 = be.load_stage(&m, "stage0").unwrap();
    let stage1 = be.load_stage(&m, "stage1").unwrap();
    let mut rng = Pcg32::seeded(78);
    let params0 = ParamStore::init(&stage0.meta.config, &mut rng, 0.05);
    // the tiny schedule's stage0 -> stage1 surgery
    let ops = tiny_schedule().stages[1].apply.clone();
    let opts = texpand::expand::ExpandOptions {
        init: texpand::expand::Init::Normal(0.2),
        ..Default::default()
    };
    let params1 = texpand::expand::ExpansionPlan::new(params0.config(), ops)
        .unwrap()
        .materialize(&params0, &opts, &mut rng)
        .unwrap();
    assert_eq!(params1.config(), &stage1.meta.config);

    let prompts = vec![vec![7u32, 8, 9]; m.batch];
    let s = Sampler { temperature: 0.0, top_k: None, seed: 0 };
    let a = generate(&be, &stage0, &params0, &prompts, 20, &s).unwrap();
    let b = generate(&be, &stage1, &params1, &prompts, 20, &s).unwrap();
    assert_eq!(a, b, "greedy rollout must be identical after expansion");
}

#[test]
fn rejects_bad_inputs() {
    let (be, stage, params, batch) = setup();
    let s = Sampler::default();
    // wrong batch
    assert!(generate(&be, &stage, &params, &[vec![1u32]], 4, &s).is_err());
    // empty prompt
    let mut prompts = vec![vec![1u32]; batch];
    prompts[0].clear();
    assert!(generate(&be, &stage, &params, &prompts, 4, &s).is_err());
    // out-of-vocab token
    let prompts = vec![vec![params.config().vocab as u32]; batch];
    assert!(generate(&be, &stage, &params, &prompts, 4, &s).is_err());
}

#[test]
fn native_and_reference_decode_agree() {
    // generate() through the native backend vs the KV-less pure-Rust
    // oracle generate_ref(): same windowing, same sampler, same model —
    // greedy outputs must be identical.
    let (be, stage, params, batch) = setup();
    let prompts = vec![vec![3u32, 1, 4, 1]; batch];
    let s = Sampler { temperature: 0.0, top_k: None, seed: 9 };
    let via_backend = generate(&be, &stage, &params, &prompts, 10, &s).unwrap();
    let via_ref = texpand::generate::generate_ref(&params, &prompts, 10, &s).unwrap();
    assert_eq!(via_backend, via_ref);
}

#[test]
#[ignore = "PJRT-specific: decoding through compiled fwd artifacts needs real xla bindings + `make artifacts` (stub xla build in-tree); the native-backend decode tests above cover generate() offline"]
fn pjrt_generation_smoke() {
    let m = Manifest::load(common::ARTIFACTS, "manifest.json").unwrap();
    let mut rt = texpand::runtime::Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let mut rng = Pcg32::seeded(77);
    let params = ParamStore::init(&stage.meta.config, &mut rng, 0.02);
    let prompts = vec![vec![10u32, 20, 30]; m.batch];
    let s = Sampler { temperature: 0.9, top_k: Some(20), seed: 1 };
    let out = generate(&rt, &stage, &params, &prompts, 12, &s).unwrap();
    assert_eq!(out.len(), m.batch);
}
