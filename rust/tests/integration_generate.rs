//! Integration: autoregressive generation through the fwd artifacts.

mod common;

use common::manifest;
use texpand::generate::{generate, Sampler};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::Runtime;

fn setup() -> (Runtime, texpand::runtime::StageExec, ParamStore, usize) {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(77);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = m.batch;
    (rt, stage, params, batch)
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn generates_requested_length_and_valid_tokens() {
    let (rt, stage, params, batch) = setup();
    let vocab = params.config().vocab as u32;
    let prompts = vec![vec![10u32, 20, 30]; batch];
    let s = Sampler { temperature: 0.9, top_k: Some(20), seed: 1 };
    let out = generate(&rt, &stage, &params, &prompts, 12, &s).unwrap();
    assert_eq!(out.len(), batch);
    for row in &out {
        assert_eq!(row.len(), 3 + 12);
        assert_eq!(&row[..3], &[10, 20, 30], "prompt must be preserved");
        assert!(row.iter().all(|&t| t < vocab));
    }
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn greedy_generation_is_deterministic() {
    let (rt, stage, params, batch) = setup();
    let prompts = vec![vec![5u32]; batch];
    let s = Sampler { temperature: 0.0, top_k: None, seed: 1 };
    let a = generate(&rt, &stage, &params, &prompts, 8, &s).unwrap();
    let b = generate(&rt, &stage, &params, &prompts, 8, &s).unwrap();
    assert_eq!(a, b);
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn sampling_seed_changes_output() {
    let (rt, stage, params, batch) = setup();
    let prompts = vec![vec![5u32, 6]; batch];
    let a = generate(&rt, &stage, &params, &prompts, 16, &Sampler { temperature: 1.0, top_k: None, seed: 1 }).unwrap();
    let b = generate(&rt, &stage, &params, &prompts, 16, &Sampler { temperature: 1.0, top_k: None, seed: 2 }).unwrap();
    assert_ne!(a, b);
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn generation_slides_past_seq_window() {
    let (rt, stage, params, batch) = setup();
    let seq = params.config().seq;
    // prompt nearly fills the window; generation must continue past it
    let prompts = vec![(0..(seq - 2) as u32).map(|t| t % 50).collect::<Vec<u32>>(); batch];
    let s = Sampler { temperature: 0.5, top_k: Some(10), seed: 3 };
    let out = generate(&rt, &stage, &params, &prompts, 10, &s).unwrap();
    assert_eq!(out[0].len(), seq - 2 + 10);
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn generation_preserved_across_expansion() {
    // greedy decode from expanded params must equal decode from the base:
    // function preservation extends to the entire autoregressive rollout.
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage0 = rt.load_stage(&m, "stage0").unwrap();
    let stage1 = rt.load_stage(&m, "stage1").unwrap();
    let mut rng = Pcg32::seeded(78);
    let params0 = ParamStore::init(&stage0.meta.config, &mut rng, 0.05);
    let ops = vec![
        texpand::config::GrowthOp::Mlp { p: 256 },
        texpand::config::GrowthOp::HeadsAdd { count: 1 },
    ];
    let opts = texpand::expand::ExpandOptions {
        init: texpand::expand::Init::Normal(0.2),
        ..Default::default()
    };
    let params1 = texpand::expand::apply_ops(&params0, &ops, &mut rng, &opts).unwrap();

    let prompts = vec![vec![7u32, 8, 9]; m.batch];
    let s = Sampler { temperature: 0.0, top_k: None, seed: 0 };
    let a = generate(&rt, &stage0, &params0, &prompts, 20, &s).unwrap();
    let b = generate(&rt, &stage1, &params1, &prompts, 20, &s).unwrap();
    assert_eq!(a, b, "greedy rollout must be identical after expansion");
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn rejects_bad_inputs() {
    let (rt, stage, params, batch) = setup();
    let s = Sampler::default();
    // wrong batch
    assert!(generate(&rt, &stage, &params, &[vec![1u32]], 4, &s).is_err());
    // empty prompt
    let mut prompts = vec![vec![1u32]; batch];
    prompts[0].clear();
    assert!(generate(&rt, &stage, &params, &prompts, 4, &s).is_err());
    // out-of-vocab token
    let prompts = vec![vec![params.config().vocab as u32]; batch];
    assert!(generate(&rt, &stage, &params, &prompts, 4, &s).is_err());
}
