//! Integration: manifest validation + PJRT execution of real artifacts.

mod common;

use common::{manifest, random_batch, schedule};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn manifest_loads_and_matches_schedule() {
    let m = manifest();
    let s = schedule();
    assert_eq!(m.stages.len(), s.stages.len());
    assert_eq!(m.batch, s.batch);
    for (ms, ss) in m.stages.iter().zip(&s.stages) {
        assert_eq!(ms.name, ss.name);
        assert_eq!(ms.config, ss.config);
        assert_eq!(ms.num_params, ss.config.num_params());
    }
}

#[test]
fn manifest_rejects_missing_dir() {
    assert!(Manifest::load("/nonexistent-dir", "manifest.json").is_err());
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn manifest_rejects_tampered_params() {
    // corrupt one param name in a copy of the manifest: load must fail
    let orig = std::fs::read_to_string(format!("{}/manifest.json", common::ARTIFACTS)).unwrap();
    let dir = std::env::temp_dir().join(format!("texpand-manifest-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let tampered = orig.replacen("\"embed\"", "\"embedx\"", 1);
    std::fs::write(dir.join("manifest.json"), tampered).unwrap();
    let err = Manifest::load(dir.to_str().unwrap(), "manifest.json").unwrap_err().to_string();
    assert!(err.contains("embedx"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn stage0_executes_and_caches() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    assert_eq!(rt.cached_executables(), 2);
    // loading again hits the cache
    let _again = rt.load_stage(&m, "stage0").unwrap();
    assert_eq!(rt.cached_executables(), 2);

    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(1);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 2);

    let logits = rt.forward(&stage, &params, &batch.tokens).unwrap();
    assert_eq!(logits.len(), m.batch);
    assert_eq!(logits[0].shape(), &[cfg.seq, cfg.vocab]);
    assert!(logits.iter().all(|t| t.all_finite()));
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn step_returns_finite_loss_and_usable_grads() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(3);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 4);

    let (loss, grads) = rt.step(&stage, &params, &batch).unwrap();
    assert!(loss.is_finite());
    // random targets => loss near ln(vocab)
    assert!((loss - (cfg.vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    assert_eq!(grads.len(), params.len());
    for (g, (spec, _)) in grads.iter().zip(params.iter()) {
        assert_eq!(g.shape(), spec.shape.as_slice(), "{}", spec.name);
        assert!(g.all_finite(), "{}", spec.name);
    }
    // at least the output projection must receive gradient signal
    let w_out_idx = params.specs().iter().position(|s| s.name == "w_out").unwrap();
    assert!(grads[w_out_idx].max_abs() > 0.0);
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn sgd_on_pjrt_grads_descends() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(5);
    let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 6);

    let (loss0, grads) = rt.step(&stage, &params, &batch).unwrap();
    for (p, g) in params.tensors_mut().iter_mut().zip(&grads) {
        let mut step = g.clone();
        step.scale(0.5);
        p.sub_assign(&step).unwrap();
    }
    let (loss1, _) = rt.step(&stage, &params, &batch).unwrap();
    assert!(loss1 < loss0, "one SGD step must descend on the same batch: {loss0} -> {loss1}");
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn runtime_rejects_mismatched_inputs() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage0 = rt.load_stage(&m, "stage0").unwrap();
    let stage1_cfg = m.stage("stage1").unwrap().config;
    let mut rng = Pcg32::seeded(7);

    // params for the wrong stage
    let wrong_params = ParamStore::init(&stage1_cfg, &mut rng, 0.02);
    let batch = random_batch(&stage0.meta.config, m.batch, 8);
    assert!(rt.forward(&stage0, &wrong_params, &batch.tokens).is_err());

    // wrong batch size
    let params = ParamStore::init(&stage0.meta.config, &mut rng, 0.02);
    let small = random_batch(&stage0.meta.config, m.batch - 1, 9);
    assert!(rt.forward(&stage0, &params, &small.tokens).is_err());

    // wrong seq length
    let mut bad = random_batch(&stage0.meta.config, m.batch, 10);
    bad.tokens[0].pop();
    assert!(rt.forward(&stage0, &params, &bad.tokens).is_err());
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn all_stages_compile_and_execute() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    for stage_meta in &m.stages {
        let stage = rt.load_stage(&m, &stage_meta.name).unwrap();
        let mut rng = Pcg32::seeded(11);
        let params = ParamStore::init(&stage.meta.config, &mut rng, 0.02);
        let batch = random_batch(&stage.meta.config, m.batch, 12);
        let (loss, _) = rt.step(&stage, &params, &batch).unwrap();
        assert!(loss.is_finite(), "{}", stage_meta.name);
    }
    // fwd+step per stage, all cached
    assert_eq!(rt.cached_executables(), 2 * m.stages.len());
}
