//! Integration: backend execution contracts.
//!
//! The native-backend family runs offline against the synthesized manifest
//! (`Manifest::from_schedule` on `configs/growth_tiny.json`). The handful
//! of genuinely PJRT-specific tests — HLO artifact compilation, the
//! executable cache, validation of the *on-disk* `manifest.json` — stay
//! `#[ignore]`d until real xla bindings + `make artifacts` are available.

mod common;

use common::{manifest, random_batch, schedule, tiny_manifest};
use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::{Manifest, Runtime};

// ---------------------------------------------------------------------------
// Native backend (offline)
// ---------------------------------------------------------------------------

#[test]
fn step_returns_finite_loss_and_usable_grads() {
    let m = tiny_manifest();
    let mut be = NativeBackend::new();
    let stage = be.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(3);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 4);

    let (loss, grads) = be.step(&stage, &params, &batch).unwrap();
    assert!(loss.is_finite());
    // random targets => loss near ln(vocab)
    assert!((loss - (cfg.vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    assert_eq!(grads.len(), params.len());
    for (g, (spec, _)) in grads.iter().zip(params.iter()) {
        assert_eq!(g.shape(), spec.shape.as_slice(), "{}", spec.name);
        assert!(g.all_finite(), "{}", spec.name);
    }
    // at least the output projection must receive gradient signal
    let w_out_idx = params.specs().iter().position(|s| s.name == "w_out").unwrap();
    assert!(grads[w_out_idx].max_abs() > 0.0);
}

#[test]
fn sgd_on_native_grads_descends() {
    let m = tiny_manifest();
    let mut be = NativeBackend::new();
    let stage = be.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(5);
    let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 6);

    let (loss0, grads) = be.step(&stage, &params, &batch).unwrap();
    for (p, g) in params.tensors_mut().iter_mut().zip(&grads) {
        let mut step = g.clone();
        step.scale(0.5);
        p.sub_assign(&step).unwrap();
    }
    let (loss1, _) = be.step(&stage, &params, &batch).unwrap();
    assert!(loss1 < loss0, "one SGD step must descend on the same batch: {loss0} -> {loss1}");
}

#[test]
fn runtime_rejects_mismatched_inputs() {
    let m = tiny_manifest();
    let mut be = NativeBackend::new();
    let stage0 = be.load_stage(&m, "stage0").unwrap();
    let stage1_cfg = m.stage("stage1").unwrap().config;
    let mut rng = Pcg32::seeded(7);

    // params for the wrong stage
    let wrong_params = ParamStore::init(&stage1_cfg, &mut rng, 0.02);
    let batch = random_batch(&stage0.meta.config, m.batch, 8);
    assert!(be.forward(&stage0, &wrong_params, &batch.tokens).is_err());

    // wrong batch size
    let params = ParamStore::init(&stage0.meta.config, &mut rng, 0.02);
    let small = random_batch(&stage0.meta.config, m.batch - 1, 9);
    assert!(be.forward(&stage0, &params, &small.tokens).is_err());

    // wrong seq length
    let mut bad = random_batch(&stage0.meta.config, m.batch, 10);
    bad.tokens[0].pop();
    assert!(be.forward(&stage0, &params, &bad.tokens).is_err());
}

#[test]
fn native_all_stages_execute() {
    let m = tiny_manifest();
    let mut be = NativeBackend::new();
    for stage_meta in &m.stages {
        let stage = be.load_stage(&m, &stage_meta.name).unwrap();
        let mut rng = Pcg32::seeded(11);
        let params = ParamStore::init(&stage.meta.config, &mut rng, 0.02);
        let batch = random_batch(&stage.meta.config, m.batch, 12);
        let logits = be.forward(&stage, &params, &batch.tokens).unwrap();
        assert_eq!(logits.len(), m.batch, "{}", stage_meta.name);
        let (loss, _) = be.step(&stage, &params, &batch).unwrap();
        assert!(loss.is_finite(), "{}", stage_meta.name);
    }
}

// ---------------------------------------------------------------------------
// PJRT-specific (artifact compilation / on-disk manifest) — still gated
// ---------------------------------------------------------------------------

#[test]
#[ignore = "PJRT-specific: validates the on-disk artifacts/manifest.json written by `make artifacts`, absent from this repo (stub xla build); the synthesized-manifest equivalent is unit-tested in runtime.rs (`manifest_from_schedule_mirrors_stage_metadata`)"]
fn manifest_loads_and_matches_schedule() {
    let m = manifest();
    let s = schedule();
    assert_eq!(m.stages.len(), s.stages.len());
    assert_eq!(m.batch, s.batch);
    for (ms, ss) in m.stages.iter().zip(&s.stages) {
        assert_eq!(ms.name, ss.name);
        assert_eq!(ms.config, ss.config);
        assert_eq!(ms.num_params, ss.config.num_params());
    }
}

#[test]
fn manifest_rejects_missing_dir() {
    assert!(Manifest::load("/nonexistent-dir", "manifest.json").is_err());
}

#[test]
#[ignore = "PJRT-specific: tampers with the on-disk artifacts/manifest.json from `make artifacts`, absent from this repo (stub xla build)"]
fn manifest_rejects_tampered_params() {
    // corrupt one param name in a copy of the manifest: load must fail
    let orig = std::fs::read_to_string(format!("{}/manifest.json", common::ARTIFACTS)).unwrap();
    let dir = std::env::temp_dir().join(format!("texpand-manifest-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let tampered = orig.replacen("\"embed\"", "\"embedx\"", 1);
    std::fs::write(dir.join("manifest.json"), tampered).unwrap();
    let err = Manifest::load(dir.to_str().unwrap(), "manifest.json").unwrap_err().to_string();
    assert!(err.contains("embedx"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
#[ignore = "PJRT-specific: exercises HLO compilation + the executable cache, needs real xla bindings + `make artifacts` (stub xla build in-tree); native execution coverage lives in `native_all_stages_execute`"]
fn stage0_executes_and_caches() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    assert_eq!(rt.cached_executables(), 2);
    // loading again hits the cache
    let _again = rt.load_stage(&m, "stage0").unwrap();
    assert_eq!(rt.cached_executables(), 2);

    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(1);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 2);

    let logits = rt.forward(&stage, &params, &batch.tokens).unwrap();
    assert_eq!(logits.len(), m.batch);
    assert_eq!(logits[0].shape(), &[cfg.seq, cfg.vocab]);
    assert!(logits.iter().all(|t| t.all_finite()));
}

#[test]
#[ignore = "PJRT-specific: executes compiled step artifacts, needs real xla bindings + `make artifacts` (stub xla build in-tree); native equivalents `step_returns_finite_loss_and_usable_grads` / `sgd_on_native_grads_descends` run un-ignored above"]
fn pjrt_step_returns_finite_loss_and_usable_grads() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(3);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 4);

    let (loss, grads) = rt.step(&stage, &params, &batch).unwrap();
    assert!(loss.is_finite());
    assert!((loss - (cfg.vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    assert_eq!(grads.len(), params.len());
    for (g, (spec, _)) in grads.iter().zip(params.iter()) {
        assert_eq!(g.shape(), spec.shape.as_slice(), "{}", spec.name);
        assert!(g.all_finite(), "{}", spec.name);
    }
    // at least the output projection must receive gradient signal
    let w_out_idx = params.specs().iter().position(|s| s.name == "w_out").unwrap();
    assert!(grads[w_out_idx].max_abs() > 0.0);
}

#[test]
#[ignore = "PJRT-specific: descends through compiled step-artifact gradients, needs real xla bindings + `make artifacts` (stub xla build in-tree); native equivalent `sgd_on_native_grads_descends` runs un-ignored above"]
fn sgd_on_pjrt_grads_descends() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(5);
    let mut params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 6);

    let (loss0, grads) = rt.step(&stage, &params, &batch).unwrap();
    for (p, g) in params.tensors_mut().iter_mut().zip(&grads) {
        let mut step = g.clone();
        step.scale(0.5);
        p.sub_assign(&step).unwrap();
    }
    let (loss1, _) = rt.step(&stage, &params, &batch).unwrap();
    assert!(loss1 < loss0, "one SGD step must descend on the same batch: {loss0} -> {loss1}");
}

#[test]
#[ignore = "PJRT-specific: exercises the Runtime's own input validation against compiled artifacts, needs real xla bindings + `make artifacts` (stub xla build in-tree); native equivalent `runtime_rejects_mismatched_inputs` runs un-ignored above"]
fn pjrt_runtime_rejects_mismatched_inputs() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage0 = rt.load_stage(&m, "stage0").unwrap();
    let stage1_cfg = m.stage("stage1").unwrap().config;
    let mut rng = Pcg32::seeded(7);

    // params for the wrong stage
    let wrong_params = ParamStore::init(&stage1_cfg, &mut rng, 0.02);
    let batch = random_batch(&stage0.meta.config, m.batch, 8);
    assert!(rt.forward(&stage0, &wrong_params, &batch.tokens).is_err());

    // wrong batch size
    let params = ParamStore::init(&stage0.meta.config, &mut rng, 0.02);
    let small = random_batch(&stage0.meta.config, m.batch - 1, 9);
    assert!(rt.forward(&stage0, &params, &small.tokens).is_err());

    // wrong seq length
    let mut bad = random_batch(&stage0.meta.config, m.batch, 10);
    bad.tokens[0].pop();
    assert!(rt.forward(&stage0, &params, &bad.tokens).is_err());
}

#[test]
#[ignore = "PJRT-specific: executes all compiled stage artifacts, needs real xla bindings + `make artifacts` (stub xla build in-tree); native equivalent `native_all_stages_execute` runs un-ignored above"]
fn all_stages_compile_and_execute() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    for stage_meta in &m.stages {
        let stage = rt.load_stage(&m, &stage_meta.name).unwrap();
        let mut rng = Pcg32::seeded(11);
        let params = ParamStore::init(&stage.meta.config, &mut rng, 0.02);
        let batch = random_batch(&stage.meta.config, m.batch, 12);
        let (loss, _) = rt.step(&stage, &params, &batch).unwrap();
        assert!(loss.is_finite(), "{}", stage_meta.name);
    }
    // fwd+step per stage, all cached
    assert_eq!(rt.cached_executables(), 2 * m.stages.len());
}
