//! Integration: the serving subsystem end to end, on the pure-Rust
//! reference path (no AOT artifacts required).
//!
//! Proves the two acceptance properties of the `serve` subsystem:
//! (a) KV-cached incremental decode is **token-identical** to the KV-less
//!     full-re-forward oracle (`generate::generate_ref`) for greedy
//!     sampling, including past the sliding-window boundary;
//! (b) a mid-serving function-preserving hot-swap leaves in-flight greedy
//!     generations **byte-identical** while the live model grows, with the
//!     preservation probe at `max|Δ logits| ≤ preserve_tol` — including
//!     when the in-flight caches are the block-quantized int8 KV tier
//!     (`kv_tier = int8`), whose remap re-quantizes from the exact f32
//!     residual stream (DESIGN.md §17).

use texpand::config::{GrowthOp, LayerPosition, ModelConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::generate::{generate_ref, Sampler};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::serve::{Engine, EngineOptions, KvTier};

const PRESERVE_TOL: f32 = 1e-4; // DESIGN.md §8

fn cfg() -> ModelConfig {
    ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
}

fn setup(seed: u64, n_prompts: usize) -> (ParamStore, Vec<Vec<u32>>) {
    let c = cfg();
    let mut rng = Pcg32::seeded(seed);
    let params = ParamStore::init(&c, &mut rng, 0.05);
    let prompts = (0..n_prompts)
        .map(|i| (0..(2 + i % 3)).map(|_| rng.below(c.vocab) as u32).collect())
        .collect();
    (params, prompts)
}

fn greedy() -> Sampler {
    Sampler { temperature: 0.0, top_k: None, seed: 0 }
}

fn engine(params: ParamStore, slots: usize, parallel: bool) -> Engine {
    Engine::new(params, EngineOptions { max_slots: slots, parallel, ..Default::default() })
}

/// Build a validated plan from the engine's live config (the only swap
/// currency the engine accepts).
fn plan_for(eng: &Engine, ops: Vec<GrowthOp>) -> ExpansionPlan {
    ExpansionPlan::new(eng.config(), ops).unwrap()
}

/// Run every prompt through the engine and return completions in submit
/// order.
fn serve_all(
    engine: &mut Engine,
    prompts: &[Vec<u32>],
    new_tokens: usize,
    sampler: Sampler,
) -> Vec<Vec<u32>> {
    let ids: Vec<_> =
        prompts.iter().map(|p| engine.submit(p.clone(), new_tokens, sampler).unwrap()).collect();
    engine.run_until_idle().unwrap();
    ids.iter().map(|&id| engine.poll(id).unwrap().tokens).collect()
}

#[test]
fn kv_decode_is_token_identical_to_full_reforward_greedy() {
    let (params, prompts) = setup(41, 4);
    // 24 new tokens on seq=16: every sequence crosses the sliding-window
    // boundary, exercising both the incremental and the re-prime paths
    let new_tokens = 24;
    let want = generate_ref(&params, &prompts, new_tokens, &greedy()).unwrap();
    let mut eng = engine(params, 4, false);
    let got = serve_all(&mut eng, &prompts, new_tokens, greedy());
    assert_eq!(got, want, "KV-cached decode diverged from the full-re-forward oracle");
}

#[test]
fn continuous_batching_beyond_slot_count_matches_oracle() {
    // 6 requests through 2 slots: completions free slots mid-run and the
    // queue drains into them; batching must not perturb any sequence
    let (params, prompts) = setup(43, 6);
    let want = generate_ref(&params, &prompts, 10, &greedy()).unwrap();
    let mut eng = engine(params, 2, false);
    let got = serve_all(&mut eng, &prompts, 10, greedy());
    assert_eq!(got, want);
    assert_eq!(eng.counters().completed, 6);
    assert_eq!(eng.counters().tokens_generated, 60);
}

#[test]
fn parallel_decode_matches_serial() {
    let (params, prompts) = setup(47, 5);
    let sampler = Sampler { temperature: 0.8, top_k: Some(8), seed: 3 };
    let mut serial = engine(params.clone(), 4, false);
    let mut parallel = engine(params, 4, true);
    assert_eq!(
        serve_all(&mut serial, &prompts, 12, sampler),
        serve_all(&mut parallel, &prompts, 12, sampler)
    );
}

#[test]
fn hot_swap_mid_flight_keeps_greedy_continuations_identical() {
    // acceptance (b): expand_mlp + add_heads + add_layers applied to the
    // live model with generations in flight; the finished outputs must be
    // byte-identical to a rollout that never saw a swap
    let (params, prompts) = setup(53, 3);
    let new_tokens = 20;
    let want = generate_ref(&params, &prompts, new_tokens, &greedy()).unwrap();

    let mut eng = engine(params, 4, false);
    let ids: Vec<_> =
        prompts.iter().map(|p| eng.submit(p.clone(), new_tokens, greedy()).unwrap()).collect();
    for _ in 0..5 {
        eng.tick().unwrap();
    }
    assert!(!eng.is_idle(), "swap must land mid-flight");

    let plan = plan_for(
        &eng,
        vec![
            GrowthOp::Mlp { p: 64 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(1) },
        ],
    );
    // aggressive unconstrained init: preservation must hold regardless
    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
    let report = eng.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap();
    assert_eq!(report.params_after, report.params_predicted, "plan prediction must hold");
    assert!(report.probe_delta <= PRESERVE_TOL, "probe delta {}", report.probe_delta);
    assert_eq!(report.remapped_sequences, 3);
    assert_eq!((eng.config().mlp, eng.config().heads, eng.config().layers), (64, 3, 3));
    assert!(report.params_after > report.params_before);

    eng.run_until_idle().unwrap();
    let got: Vec<_> = ids.iter().map(|&id| eng.poll(id).unwrap().tokens).collect();
    assert_eq!(got, want, "hot-swap perturbed in-flight greedy generations");
}

#[test]
fn hot_swap_with_scaling_ops_stays_within_probe_tolerance() {
    // attn_expand and hidden carry the paper's sqrt scale factors: the
    // remap is exact only up to float reassociation, so the guarantee is
    // the probe tolerance (plus the swap committing under live traffic)
    let (params, prompts) = setup(59, 2);
    let mut eng = engine(params, 2, false);
    let ids: Vec<_> =
        prompts.iter().map(|p| eng.submit(p.clone(), 12, greedy()).unwrap()).collect();
    for _ in 0..3 {
        eng.tick().unwrap();
    }
    let plan = plan_for(&eng, vec![GrowthOp::AttnExpand { k: 16 }, GrowthOp::Hidden { h: 24 }]);
    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
    let report = eng.hot_swap(&plan, &mut Pcg32::seeded(11), &opts).unwrap();
    assert!(report.probe_delta <= PRESERVE_TOL, "probe delta {}", report.probe_delta);
    assert_eq!((eng.config().k, eng.config().hidden), (16, 24));
    eng.run_until_idle().unwrap();
    for id in ids {
        let c = eng.poll(id).unwrap();
        assert_eq!(c.generated, 12);
        assert!(c.tokens.iter().all(|&t| (t as usize) < eng.config().vocab));
    }
}

#[test]
fn quant_kv_cache_rides_a_hot_swap_with_identical_greedy_continuations() {
    // ISSUE 9: the int8 KV tier must survive expansion. Stream-preserving
    // ops (mlp widen + layer insert) touch neither the K/V widths nor the
    // residual stream, and the remap re-quantizes each head from the
    // exact f32 stream buffers, so the swapped engine's greedy
    // continuations must be byte-identical to a quantized engine that
    // never swapped — quantization error must not compound across a swap.
    let c = ModelConfig {
        layers: 2, hidden: 16, heads: 2, k: 16, v: 16, mlp: 32, seq: 16, vocab: 32,
    };
    let mut rng = Pcg32::seeded(71);
    let params = ParamStore::init(&c, &mut rng, 0.05);
    let prompts: Vec<Vec<u32>> = (0..3)
        .map(|i| (0..(2 + i % 3)).map(|_| rng.below(c.vocab) as u32).collect())
        .collect();
    let new_tokens = 20;
    let qopts =
        EngineOptions { max_slots: 4, parallel: false, kv_tier: KvTier::Int8, ..Default::default() };

    // the oracle: the same quantized engine, never swapped
    let mut base = Engine::new(params.clone(), qopts);
    let want = serve_all(&mut base, &prompts, new_tokens, greedy());

    let mut eng = Engine::new(params, qopts);
    let ids: Vec<_> =
        prompts.iter().map(|p| eng.submit(p.clone(), new_tokens, greedy()).unwrap()).collect();
    for _ in 0..5 {
        eng.tick().unwrap();
    }
    assert!(!eng.is_idle(), "swap must land mid-flight");
    assert!(eng.peak_kv_bytes_per_seq() > 0, "engine must report quant-tier resident bytes");

    let plan = plan_for(
        &eng,
        vec![
            GrowthOp::Mlp { p: 64 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(1) },
        ],
    );
    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
    let report = eng.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap();
    assert!(report.probe_delta <= PRESERVE_TOL, "probe delta {}", report.probe_delta);
    assert_eq!(report.remapped_sequences, 3);
    assert_eq!((eng.config().mlp, eng.config().layers), (64, 3));

    eng.run_until_idle().unwrap();
    let got: Vec<_> = ids.iter().map(|&id| eng.poll(id).unwrap().tokens).collect();
    assert_eq!(got, want, "hot-swap perturbed the quantized KV tier's greedy continuations");
}

#[test]
fn rejected_swap_leaves_serving_byte_identical() {
    // a constraint-violating surgery (E6 ablation) must be rejected by the
    // probe and leave the engine producing exactly the no-swap outputs
    let (params, prompts) = setup(61, 2);
    let want = generate_ref(&params, &prompts, 10, &greedy()).unwrap();
    let mut eng = engine(params, 2, false);
    let ids: Vec<_> =
        prompts.iter().map(|p| eng.submit(p.clone(), 10, greedy()).unwrap()).collect();
    eng.tick().unwrap();

    let opts = ExpandOptions {
        init: Init::Normal(0.5),
        zero_constrained: false,
        ..Default::default()
    };
    let plan = plan_for(&eng, vec![GrowthOp::Mlp { p: 64 }]);
    let err = eng.hot_swap(&plan, &mut Pcg32::seeded(13), &opts).unwrap_err();
    assert!(err.to_string().contains("rejected"), "{err}");
    assert_eq!(eng.config(), &cfg());

    eng.run_until_idle().unwrap();
    let got: Vec<_> = ids.iter().map(|&id| eng.poll(id).unwrap().tokens).collect();
    assert_eq!(got, want);
}

#[test]
fn two_consecutive_swaps_compose_under_load() {
    // growth is composable (paper §3): two separate swaps mid-serving must
    // keep greedy outputs identical end to end
    let (params, prompts) = setup(67, 2);
    let new_tokens = 18;
    let want = generate_ref(&params, &prompts, new_tokens, &greedy()).unwrap();
    let mut eng = engine(params, 2, false);
    let ids: Vec<_> =
        prompts.iter().map(|p| eng.submit(p.clone(), new_tokens, greedy()).unwrap()).collect();

    let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
    let mut rng = Pcg32::seeded(17);
    for _ in 0..3 {
        eng.tick().unwrap();
    }
    let first = plan_for(&eng, vec![GrowthOp::Mlp { p: 48 }]);
    eng.hot_swap(&first, &mut rng, &opts).unwrap();
    for _ in 0..3 {
        eng.tick().unwrap();
    }
    // the second plan is built from the *grown* live config — plans are
    // config-anchored, so composition across swaps is explicit
    let second = plan_for(
        &eng,
        vec![GrowthOp::HeadsAdd { count: 1 }, GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top }],
    );
    eng.hot_swap(&second, &mut rng, &opts).unwrap();
    assert_eq!(eng.counters().swaps, 2);

    eng.run_until_idle().unwrap();
    let got: Vec<_> = ids.iter().map(|&id| eng.poll(id).unwrap().tokens).collect();
    assert_eq!(got, want);
}
