//! Integration: the growth coordinator end to end (short runs).
//!
//! Runs against the **native autodiff backend** on the shipped tiny
//! schedule (`configs/growth_tiny.json`), so the full train → expand →
//! keep-training loop executes offline — no AOT artifacts, no PJRT. The
//! same scenarios work unchanged on the PJRT backend once artifacts exist
//! (swap `NativeBackend::new()` for `Runtime::cpu()` and the manifest for
//! the artifact one).

mod common;

use common::{tiny_manifest, tiny_schedule};
use texpand::autodiff::NativeBackend;
use texpand::config::TrainConfig;
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::CorpusKind;
use texpand::params::ParamStore;

fn tmp_runs(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("texpand-coord-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

fn mini_coordinator(steps_scale: f64, save: bool) -> Coordinator {
    let opts = CoordinatorOptions {
        steps_scale,
        save_checkpoints: save,
        corpus: CorpusKind::MarkovText,
        corpus_len: 50_000,
        ..Default::default()
    };
    Coordinator::new(
        tiny_schedule(),
        tiny_manifest(),
        Box::new(NativeBackend::new()),
        TrainConfig { log_every: 1000, ..Default::default() },
        opts,
    )
    .unwrap()
}

#[test]
fn full_schedule_short_run_preserves_and_descends() {
    let runs = tmp_runs("full");
    let mut coord = mini_coordinator(1.0, true); // 30 steps per stage
    let summary = coord.run(&runs, "t1").unwrap();

    assert_eq!(summary.stages.len(), 3);
    assert_eq!(summary.boundaries.len(), 2);
    for b in &summary.boundaries {
        assert!(b.rust_delta <= 1e-4, "{}: rust {}", b.into_stage, b.rust_delta);
        assert!(b.pjrt_delta <= 1e-4, "{}: backend {}", b.into_stage, b.pjrt_delta);
        assert!((b.loss_after - b.loss_before).abs() <= 1e-4, "loss continuity at {}", b.into_stage);
    }
    // losses should broadly descend across the whole run
    let first = summary.stages.first().unwrap().first_loss;
    let last = summary.stages.last().unwrap().final_loss;
    assert!(last < first, "no learning: {first} -> {last}");

    // artifacts of the run exist
    assert!(std::path::Path::new(&format!("{}/loss.csv", summary.run_dir)).exists());
    assert!(std::path::Path::new(&format!("{}/events.jsonl", summary.run_dir)).exists());
    for st in &coord.schedule.stages {
        assert!(std::path::Path::new(&format!("{}/{}.txpd", summary.run_dir, st.name)).exists());
    }
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn checkpoints_reload_into_matching_configs() {
    let runs = tmp_runs("ckpt");
    let mut coord = mini_coordinator(0.1, true);
    let summary = coord.run(&runs, "t2").unwrap();
    for (i, st) in coord.schedule.stages.iter().enumerate() {
        let (params, meta) = ParamStore::load(&format!("{}/{}.txpd", summary.run_dir, st.name)).unwrap();
        assert_eq!(params.config(), &st.config, "stage {i}");
        assert!(params.all_finite());
        assert_eq!(meta.req("stage").unwrap().as_str().unwrap(), st.name);
    }
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn loss_curve_is_continuous_at_boundaries() {
    // stronger E3 check: the *training* loss right after a boundary must
    // not spike above the pre-boundary loss by more than normal step noise.
    let runs = tmp_runs("cont");
    let mut coord = mini_coordinator(0.5, false); // 15 steps per stage
    let summary = coord.run(&runs, "t3").unwrap();
    for w in summary.stages.windows(2) {
        let (prev, next) = (&w[0], &w[1]);
        assert!(
            next.first_loss < prev.tail_mean_loss + 0.5,
            "loss spike across boundary {} -> {}: {} vs tail {}",
            prev.stage,
            next.stage,
            next.first_loss,
            prev.tail_mean_loss
        );
    }
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn branch_produces_trainable_family_member() {
    let runs = tmp_runs("branch");
    let mut coord = mini_coordinator(0.1, true);
    let summary = coord.run(&runs, "t4").unwrap();
    let (base, _) = ParamStore::load(&format!("{}/stage0.txpd", summary.run_dir)).unwrap();

    // branch stage0 -> stage1 and finetune a few steps
    let ops = coord.schedule.stages[1].apply.clone();
    let probe = texpand::data::Batcher::from_corpus(
        coord.opts.corpus,
        coord.opts.corpus_len,
        base.config().vocab,
        base.config().seq,
        coord.schedule.batch,
        coord.tcfg.seed ^ 0xC0DE,
    )
    .unwrap()
    .probe(1);
    let (branched, report, eval) =
        coord.branch(&base, &ops, "stage1", 5, &runs, "t4-branch", &probe).unwrap();
    assert_eq!(branched.config(), &coord.schedule.stages[1].config);
    assert_eq!(report.steps_run, 5);
    assert!(eval.is_finite());
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn branch_rejects_mismatched_stage() {
    let runs = tmp_runs("branch-bad");
    let mut coord = mini_coordinator(0.1, false);
    let cfg0 = coord.schedule.stages[0].config;
    let mut rng = texpand::rng::Pcg32::seeded(0);
    let base = ParamStore::init(&cfg0, &mut rng, 0.02);
    let probe = common::random_batch(&cfg0, coord.schedule.batch, 1);
    // no ops, but target stage1 (bigger config): must fail the config check
    let err = coord.branch(&base, &[], "stage1", 1, &runs, "bad", &probe).unwrap_err();
    assert!(err.to_string().contains("expects"), "{err}");
    std::fs::remove_dir_all(&runs).unwrap();
}

/// A stub backend that *claims* to execute AOT artifacts, for exercising
/// the manifest cross-validation without real PJRT bindings — validation
/// happens in `Coordinator::new`, before any execution method is reached.
struct ArtifactStubBackend;

impl texpand::autodiff::ExecBackend for ArtifactStubBackend {
    fn platform(&self) -> String {
        "artifact-stub".to_string()
    }

    // needs_artifacts() defaults to true — that's the point of the stub

    fn load_stage(
        &mut self,
        _manifest: &texpand::runtime::Manifest,
        _stage_name: &str,
    ) -> texpand::Result<texpand::runtime::StageExec> {
        unreachable!("validation-only stub")
    }

    fn forward(
        &self,
        _stage: &texpand::runtime::StageExec,
        _params: &ParamStore,
        _tokens: &[Vec<u32>],
    ) -> texpand::Result<Vec<texpand::tensor::Tensor>> {
        unreachable!("validation-only stub")
    }

    fn step(
        &self,
        _stage: &texpand::runtime::StageExec,
        _params: &ParamStore,
        _batch: &texpand::data::Batch,
    ) -> texpand::Result<(f32, Vec<texpand::tensor::Tensor>)> {
        unreachable!("validation-only stub")
    }
}

#[test]
fn artifact_backend_rejects_schedule_manifest_drift() {
    // a backend that loads compiled artifacts must refuse a manifest that
    // disagrees with the schedule (the two halves of the build drifted)
    let mut sched = tiny_schedule();
    sched.stages[1].config.mlp += 8; // simulate drift
    let result = Coordinator::new(
        sched,
        tiny_manifest(),
        Box::new(ArtifactStubBackend),
        TrainConfig::default(),
        CoordinatorOptions::default(),
    );
    match result {
        Ok(_) => panic!("drifted schedule must be rejected"),
        Err(err) => assert!(err.to_string().contains("mismatch"), "{err}"),
    }
}

#[test]
fn native_backend_tolerates_manifest_drift() {
    // the native backend synthesizes its stage metadata from the live run,
    // so a drifted (or entirely vestigial) manifest must not abort runs
    // that never read artifacts — construction succeeds AND a short run
    // trains end to end
    let mut drifted = tiny_manifest();
    drifted.stages[1].config.mlp += 8;
    drifted.stages.pop(); // stage-count mismatch too
    let mut coord = Coordinator::new(
        tiny_schedule(),
        drifted,
        Box::new(NativeBackend::new()),
        TrainConfig { log_every: 1000, ..Default::default() },
        CoordinatorOptions {
            steps_scale: 0.1,
            save_checkpoints: false,
            corpus_len: 50_000,
            ..Default::default()
        },
    )
    .expect("native coordinator must not validate the manifest");
    let runs = tmp_runs("drift-ok");
    let summary = coord.run(&runs, "t5").unwrap();
    assert_eq!(summary.stages.len(), 3, "all schedule stages ran despite manifest drift");
    std::fs::remove_dir_all(&runs).unwrap();
}
