//! Integration: policy-driven growth, end to end (native backend).
//!
//! The load-bearing test is the **equivalence oracle**: a coordinator run
//! under the default `FixedSchedule` policy must be bit-identical — every
//! loss-curve row and every final parameter — to a hand-rolled replay of
//! the pre-refactor stage-wise loop (train_stage per stage, surgery at
//! each boundary). That pins the refactor: the policy seam added a
//! decision point, not a numerics change. The adaptive policies then get
//! their own offline end-to-end runs.

mod common;

use common::{tiny_manifest, tiny_schedule};
use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::config::{PolicyConfig, PolicyKind, TrainConfig};
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::{Batcher, CorpusKind};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::growth::{GreedyBranch, LossPlateau};
use texpand::metrics::RunLogger;
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::train::{train_stage, TrainState};

fn tmp_runs(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("texpand-policy-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

const CORPUS_LEN: usize = 50_000;

fn mini_coordinator(steps_scale: f64, save: bool) -> Coordinator {
    Coordinator::new(
        tiny_schedule(),
        tiny_manifest(),
        Box::new(NativeBackend::new()),
        TrainConfig { log_every: 1000, ..Default::default() },
        CoordinatorOptions {
            steps_scale,
            save_checkpoints: save,
            corpus: CorpusKind::MarkovText,
            corpus_len: CORPUS_LEN,
            ..Default::default()
        },
    )
    .unwrap()
}

/// Strip the wall-clock column from a loss.csv (the only
/// non-deterministic field).
fn loss_rows_without_wall(dir: &str) -> Vec<String> {
    let csv = std::fs::read_to_string(format!("{dir}/loss.csv")).unwrap();
    csv.lines()
        .skip(1) // header
        .map(|l| {
            let (row, _wall) = l.rsplit_once(',').unwrap();
            row.to_string()
        })
        .collect()
}

#[test]
fn fixed_policy_bit_identical_to_stagewise_replay() {
    // --- the policy-driven run (FixedSchedule via Coordinator::run) -----
    let runs = tmp_runs("oracle");
    let mut coord = mini_coordinator(1.0, true);
    let summary = coord.run(&runs, "policy").unwrap();
    assert_eq!(summary.policy, "fixed");
    assert_eq!(summary.stages.len(), 3);
    assert_eq!(summary.boundaries.len(), 2);

    // --- the pre-refactor semantics, replayed by hand -------------------
    // exactly what Coordinator::run did before the policy seam: per stage,
    // (surgery if i > 0) then train_stage for its scheduled step count,
    // all on one shared rng/batcher/optimizer lineage
    let sched = tiny_schedule();
    let manifest = tiny_manifest();
    let tcfg = TrainConfig { log_every: 1000, ..Default::default() };
    let mut backend = NativeBackend::new();
    let mut rng = Pcg32::seeded(tcfg.seed);
    let first_cfg = sched.stages[0].config;
    let mut params = ParamStore::init(&first_cfg, &mut rng, 0.02);
    let mut opt = Optimizer::new(&tcfg, &params);
    let mut batcher = Batcher::from_corpus(
        CorpusKind::MarkovText,
        CORPUS_LEN,
        first_cfg.vocab,
        first_cfg.seq,
        sched.batch,
        tcfg.seed ^ 0xC0DE,
    )
    .unwrap();
    let mut logger = RunLogger::create(&runs, "replay").unwrap().quiet();
    let mut state = TrainState::new();
    for (i, stage) in sched.stages.iter().enumerate() {
        if i > 0 && !stage.apply.is_empty() {
            let expand_opts = ExpandOptions { init: Init::Normal(0.02), ..Default::default() };
            let plan = ExpansionPlan::new(params.config(), stage.apply.clone()).unwrap();
            plan.apply_train(&mut params, &mut opt, &expand_opts, &mut rng).unwrap();
        }
        let exec = backend.load_stage(&manifest, &stage.name).unwrap();
        train_stage(
            &backend,
            &exec,
            &mut params,
            &mut opt,
            &mut batcher,
            &tcfg,
            &mut logger,
            &mut state,
            stage.steps,
        )
        .unwrap();
    }
    drop(logger);

    // --- bit-identical loss trajectory ----------------------------------
    let policy_rows = loss_rows_without_wall(&format!("{runs}/policy"));
    let replay_rows = loss_rows_without_wall(&format!("{runs}/replay"));
    assert_eq!(policy_rows.len(), 90, "30 steps x 3 stages");
    assert_eq!(
        policy_rows, replay_rows,
        "loss trajectory diverged between policy-driven and stage-wise runs"
    );

    // --- bit-identical final parameters ---------------------------------
    let (ckpt, _) = ParamStore::load(&format!("{runs}/policy/stage2.txpd")).unwrap();
    assert_eq!(ckpt.config(), params.config());
    for ((spec, a), (_, b)) in ckpt.iter().zip(params.iter()) {
        for (x, y) in a.data().iter().zip(b.data()) {
            assert_eq!(x.to_bits(), y.to_bits(), "param '{}' diverged", spec.name);
        }
    }
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn plateau_policy_runs_offline_with_logged_expansions() {
    let runs = tmp_runs("plateau");
    let mut coord = mini_coordinator(0.5, false); // 15 steps per stage, 45 total
    let pcfg = PolicyConfig {
        kind: PolicyKind::Plateau,
        eval_every: 2,
        window: 2,
        min_slope: 1.0, // tiny-model progress is < 1 nat/eval: plateaus fast
        cooldown: 3,
        deadline_scale: 2.0,
        probe_budget: 4,
    };
    let mut policy = LossPlateau::new(&coord.schedule, coord.opts.steps_scale, &pcfg);
    let summary = coord.run_with_policy(&runs, "plateau", &mut policy).unwrap();

    assert_eq!(summary.policy, "plateau");
    assert_eq!(summary.total_steps, 45, "stops exactly at the scaled step budget");
    assert_eq!(summary.boundaries.len(), 2, "both staged expansions fired");
    for b in &summary.boundaries {
        assert!(b.rust_delta <= 1e-4, "{}: preservation {}", b.into_stage, b.rust_delta);
        assert!(b.pjrt_delta <= 1e-4, "{}: backend {}", b.into_stage, b.pjrt_delta);
    }
    // the run grew to the schedule's final architecture
    let final_cfg = *coord.schedule.final_config();
    assert_eq!(summary.stages.len(), 3);
    assert_eq!(summary.stages.last().unwrap().params, final_cfg.num_params());

    // the decision audit trail is in the run log, evidence attached
    let events = std::fs::read_to_string(format!("{}/events.jsonl", summary.run_dir)).unwrap();
    let expansions = events.lines().filter(|l| l.contains(r#""decision":"expand""#)).count();
    assert_eq!(expansions, 2, "one decision row per committed expansion");
    assert!(
        events.lines().any(|l| l.contains(r#""event":"decision""#) && l.contains(r#""eval_loss":"#)),
        "decision rows must carry their eval evidence"
    );
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn greedy_policy_runs_offline_and_any_commit_preserves() {
    // two-stage schedule so the greedy param cap (= final stage size) sits
    // above the base architecture and probing is reachable
    let runs = tmp_runs("greedy");
    let schedule = texpand::config::GrowthSchedule::from_json(
        &texpand::json::Value::parse(
            r#"{
                "name": "greedy-it", "batch": 2, "seq": 8, "vocab": 16,
                "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                "stages": [
                    {"steps": 10},
                    {"steps": 10, "apply": [{"op":"mlp","p":32},{"op":"heads_add","count":1}]}
                ]
            }"#,
        )
        .unwrap(),
    )
    .unwrap();
    let manifest = texpand::runtime::Manifest::from_schedule(&schedule);
    let tcfg = TrainConfig { log_every: 1000, ..Default::default() };
    let mut coord = Coordinator::new(
        schedule.clone(),
        manifest,
        Box::new(NativeBackend::new()),
        tcfg.clone(),
        CoordinatorOptions {
            save_checkpoints: false,
            corpus_len: 20_000,
            ..Default::default()
        },
    )
    .unwrap();
    let pcfg = PolicyConfig {
        kind: PolicyKind::Greedy,
        eval_every: 2,
        window: 2,
        min_slope: 1.0,
        cooldown: 2,
        deadline_scale: 0.0,
        probe_budget: 2,
    };
    let mut policy = GreedyBranch::new(&schedule, 1.0, &pcfg, tcfg.seed);
    let summary = coord.run_with_policy(&runs, "greedy", &mut policy).unwrap();

    assert_eq!(summary.policy, "greedy");
    assert_eq!(summary.total_steps, 20, "greedy spends exactly the matched budget");
    // commits are data-dependent; whatever was committed must preserve
    for b in &summary.boundaries {
        assert_eq!(b.ops, 1, "greedy commits one op per boundary");
        assert!(b.rust_delta <= 1e-4, "{}: preservation {}", b.into_stage, b.rust_delta);
    }
    let events = std::fs::read_to_string(format!("{}/events.jsonl", summary.run_dir)).unwrap();
    assert!(
        events.lines().any(|l| l.contains(r#""event":"decision""#)),
        "greedy run must leave a decision audit trail"
    );
    std::fs::remove_dir_all(&runs).unwrap();
}

/// The plateau policy must behave identically through the public
/// `build_policy` factory (what `texpand train --policy plateau` uses).
#[test]
fn build_policy_plateau_matches_direct_construction() {
    let runs = tmp_runs("factory");
    let pcfg = PolicyConfig {
        kind: PolicyKind::Plateau,
        eval_every: 2,
        window: 2,
        min_slope: 1.0,
        cooldown: 3,
        deadline_scale: 2.0,
        probe_budget: 4,
    };
    let mut direct_coord = mini_coordinator(0.5, false);
    let mut direct = LossPlateau::new(&direct_coord.schedule, 0.5, &pcfg);
    let a = direct_coord.run_with_policy(&runs, "direct", &mut direct).unwrap();

    let mut factory_coord = mini_coordinator(0.5, false);
    let mut boxed = texpand::growth::build_policy(&factory_coord.schedule, 0.5, &pcfg, 0);
    let b = factory_coord.run_with_policy(&runs, "factory", boxed.as_mut()).unwrap();

    assert_eq!(a.total_steps, b.total_steps);
    assert_eq!(a.boundaries.len(), b.boundaries.len());
    assert_eq!(a.final_eval_loss.to_bits(), b.final_eval_loss.to_bits());
    std::fs::remove_dir_all(&runs).unwrap();
}
