//! Integration: the HTTP serve front-end end to end, over real sockets.
//!
//! Proves the acceptance properties of `serve::http` (DESIGN.md §18):
//! (a) greedy tokens streamed over `POST /v1/generate` are **byte-identical**
//!     to an in-process `Engine::submit`/`poll` of the same request;
//! (b) a wall-clock `deadline_ms` maps onto the engine's tick-denominated
//!     timeout — expiry streams the partial output and a terminal
//!     `"finish":"timeout"` chunk (and bumps the serve timeout counter),
//!     while `deadline_ms: 0` stays unbounded;
//! (c) past the admission window the server sheds with
//!     `429 Too Many Requests` + `Retry-After` instead of queueing;
//! (d) the `texpand loadgen` client fleet drives a live server and its
//!     client-observed counts reconcile with the server-side summary.

use std::sync::Arc;
use std::time::Duration;

use texpand::config::ModelConfig;
use texpand::generate::Sampler;
use texpand::json::Value;
use texpand::obs::{http_get, http_post_stream, render, MetricsRegistry};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::serve::http::{AimdOptions, HttpServer, HttpServerOptions};
use texpand::serve::{loadgen, Engine, EngineOptions};

const TIMEOUT: Duration = Duration::from_secs(30);

fn cfg() -> ModelConfig {
    ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 32, vocab: 32 }
}

fn params(seed: u64) -> ParamStore {
    ParamStore::init(&cfg(), &mut Pcg32::seeded(seed), 0.05)
}

fn greedy(seed: u64) -> Sampler {
    Sampler { temperature: 0.0, top_k: None, seed }
}

/// Parse a finished NDJSON stream: (token ids in order, terminal line).
fn parse_stream(lines: &[String]) -> (Vec<u32>, Value) {
    let mut ids = Vec::new();
    let mut done = None;
    for line in lines {
        let v = Value::parse(line).expect("stream line is JSON");
        if let Some(toks) = v.get("tokens") {
            for t in toks.as_arr().expect("tokens is an array") {
                ids.push(t.as_usize().expect("token id") as u32);
            }
        }
        if v.get("done").is_some() {
            done = Some(v);
        }
    }
    (ids, done.expect("stream has a terminal done chunk"))
}

#[test]
fn streamed_greedy_matches_in_process_engine() {
    let prompt: Vec<u32> = vec![3, 1, 4, 1, 5];
    let new_tokens = 12;

    // oracle: same params, same request, no network
    let mut oracle = Engine::new(params(42), EngineOptions::default());
    let id = oracle.submit(prompt.clone(), new_tokens, greedy(7)).unwrap();
    oracle.run_until_idle().unwrap();
    let want = oracle.poll(id).expect("oracle completion");
    assert_eq!(want.generated, new_tokens);
    let want_ids = &want.tokens[want.prompt_len..];

    let reg = Arc::new(MetricsRegistry::new());
    let engine = Engine::with_registry(params(42), EngineOptions::default(), &reg);
    let server = HttpServer::bind_with_registry(
        "127.0.0.1:0",
        engine,
        HttpServerOptions::default(),
        Arc::clone(&reg),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let (status, body) = http_get(&addr, "/healthz", TIMEOUT).unwrap();
    assert_eq!((status, body.as_str()), (200, "ok\n"));

    let ids: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let body = format!(
        r#"{{"tokens":[{}],"max_new_tokens":{new_tokens},"temperature":0,"seed":7}}"#,
        ids.join(",")
    );
    // incremental delivery: every on_line callback fires before the call
    // returns, so counting both proves the stream really was chunked
    let mut live_lines = 0usize;
    let out = http_post_stream(&addr, "/v1/generate", &body, TIMEOUT, &mut |_| live_lines += 1)
        .unwrap();
    assert_eq!(out.status, 200);
    assert_eq!(live_lines, out.lines.len());
    assert!(out.lines.len() >= 2, "at least one token chunk plus the terminal");

    let (got_ids, done) = parse_stream(&out.lines);
    assert_eq!(got_ids, want_ids, "streamed greedy tokens differ from in-process");
    assert_eq!(done.req("finish").unwrap().as_str().unwrap(), "max_tokens");
    assert_eq!(done.req("generated").unwrap().as_usize().unwrap(), new_tokens);
    assert_eq!(done.req("prompt_len").unwrap().as_usize().unwrap(), prompt.len());

    let (_, summary) = server.shutdown().unwrap();
    assert_eq!((summary.requests, summary.streamed, summary.rejected), (1, 1, 0));
}

#[test]
fn deadline_expires_with_partial_stream_and_zero_means_unbounded() {
    let reg = Arc::new(MetricsRegistry::new());
    let engine = Engine::with_registry(params(43), EngineOptions::default(), &reg);
    let server = HttpServer::bind_with_registry(
        "127.0.0.1:0",
        engine,
        HttpServerOptions::default(),
        Arc::clone(&reg),
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    // deadline_ms=1 with the EWMA seeded at 5 ms/tick maps to a 1-tick
    // budget: the request must expire with a small partial prefix
    let body = r#"{"tokens":[1,2,3],"max_new_tokens":256,"deadline_ms":1,"temperature":0}"#;
    let out = http_post_stream(&addr, "/v1/generate", body, TIMEOUT, &mut |_| {}).unwrap();
    assert_eq!(out.status, 200);
    let (ids, done) = parse_stream(&out.lines);
    assert_eq!(done.req("finish").unwrap().as_str().unwrap(), "timeout");
    let generated = done.req("generated").unwrap().as_usize().unwrap();
    assert!(generated < 256, "deadline must cut generation short, got {generated}");
    assert_eq!(ids.len(), generated, "partial stream delivers exactly the decoded prefix");

    // deadline_ms=0 is explicitly unbounded, not instantly expired
    let body = r#"{"tokens":[1,2,3],"max_new_tokens":8,"deadline_ms":0,"temperature":0}"#;
    let out = http_post_stream(&addr, "/v1/generate", body, TIMEOUT, &mut |_| {}).unwrap();
    let (ids, done) = parse_stream(&out.lines);
    assert_eq!(done.req("finish").unwrap().as_str().unwrap(), "max_tokens");
    assert_eq!(ids.len(), 8);

    let text = render(&reg);
    assert!(
        text.contains("texpand_serve_timeouts_total 1"),
        "engine timeout counter missing from the shared registry:\n{text}"
    );
    server.shutdown().unwrap();
}

#[test]
fn overload_sheds_with_429_and_retry_after() {
    // window pinned to 1 (static): any overlapping second request must be
    // shed, never queued
    let aimd = AimdOptions {
        initial_window: 1.0,
        min_window: 1.0,
        max_window: 1.0,
        adaptive: false,
        ..AimdOptions::default()
    };
    let reg = Arc::new(MetricsRegistry::new());
    let engine = Engine::with_registry(params(44), EngineOptions::default(), &reg);
    let opts = HttpServerOptions { aimd, ..HttpServerOptions::default() };
    let server =
        HttpServer::bind_with_registry("127.0.0.1:0", engine, opts, Arc::clone(&reg)).unwrap();
    let addr = server.local_addr().to_string();

    let barrier = Arc::new(std::sync::Barrier::new(6));
    let workers: Vec<_> = (0..6)
        .map(|_| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                let body =
                    r#"{"tokens":[1,2,3,4],"max_new_tokens":24,"temperature":0}"#;
                http_post_stream(&addr, "/v1/generate", body, TIMEOUT, &mut |_| {}).unwrap()
            })
        })
        .collect();
    let outcomes: Vec<_> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    let oks = outcomes.iter().filter(|o| o.status == 200).count();
    let shed: Vec<_> = outcomes.iter().filter(|o| o.status == 429).collect();
    assert!(oks >= 1, "someone must get through the window");
    assert!(!shed.is_empty(), "6 simultaneous clients vs window 1 must shed");
    for o in &shed {
        assert!(o.retry_after.is_some(), "429 must carry Retry-After");
        assert!(o.retry_after.unwrap() >= 1);
    }
    assert_eq!(oks + shed.len(), 6, "every outcome is either streamed or shed");

    let (_, summary) = server.shutdown().unwrap();
    assert_eq!(summary.rejected as usize, shed.len());
    assert_eq!(summary.streamed as usize, oks);
    let text = render(&reg);
    assert!(text.contains("texpand_http_rejected_total"), "shed counter exported:\n{text}");
}

#[test]
fn loadgen_fleet_reconciles_with_server_summary() {
    let reg = Arc::new(MetricsRegistry::new());
    let engine = Engine::with_registry(params(45), EngineOptions::default(), &reg);
    // window pinned above the client count so the reconciliation below is
    // deterministic (no noise-driven shedding)
    let aimd = AimdOptions {
        initial_window: 8.0,
        min_window: 8.0,
        max_window: 8.0,
        adaptive: false,
        ..AimdOptions::default()
    };
    let server = HttpServer::bind_with_registry(
        "127.0.0.1:0",
        engine,
        HttpServerOptions { aimd, ..HttpServerOptions::default() },
        Arc::clone(&reg),
    )
    .unwrap();

    let opts = loadgen::LoadgenOptions {
        addr: server.local_addr().to_string(),
        clients: 2,
        requests: 6,
        tokens: 4,
        prompt_mix: vec![2, 5],
        vocab: cfg().vocab,
        seed: 9,
        ..loadgen::LoadgenOptions::default()
    };
    let report = loadgen::run(&opts).unwrap();
    assert_eq!(report.sent, 6);
    assert_eq!(report.mode, "closed");
    // closed loop, 2 clients, default window 4: nothing sheds, nothing
    // times out — every stream runs to max_tokens
    assert_eq!(
        (report.completed, report.rejected, report.timeouts, report.errors),
        (6, 0, 0, 0)
    );
    assert_eq!(report.tokens_streamed, 6 * 4);
    assert!(report.p50_ms > 0.0 && report.p99_ms >= report.p50_ms);
    assert!(report.tokens_per_sec > 0.0);

    let (_, summary) = server.shutdown().unwrap();
    assert_eq!((summary.requests, summary.streamed), (6, 6));
}
