//! Integration: the run store over real coordinator output (S20a).
//!
//! The unit tests in `obs::store` cover cursor mechanics on synthetic
//! logs; these tests close the loop with the actual writers: a native
//! 3-stage growth run on `configs/growth_tiny.json` must ingest into
//! stats that (a) count every expansion with valid, cross-checked plan
//! evidence, (b) show measured param deltas equal to the plan's exact
//! prediction, and (c) carry a within-tolerance preservation record for
//! every boundary — the properties `texpand report` and the CI smoke
//! lean on.

mod common;

use common::{tiny_manifest, tiny_schedule};
use texpand::autodiff::NativeBackend;
use texpand::config::TrainConfig;
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::CorpusKind;
use texpand::json::Value;
use texpand::obs::RunStore;

fn tmp_runs(tag: &str) -> String {
    let d = std::env::temp_dir().join(format!("texpand-store-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_str().unwrap().to_string()
}

#[test]
fn growth_run_ingests_into_complete_stats() {
    let runs = tmp_runs("e2e");
    let opts = CoordinatorOptions {
        steps_scale: 0.2, // 6 steps per stage: enough to emit every event kind
        save_checkpoints: false,
        corpus: CorpusKind::MarkovText,
        corpus_len: 50_000,
        ..Default::default()
    };
    let mut coord = Coordinator::new(
        tiny_schedule(),
        tiny_manifest(),
        Box::new(NativeBackend::new()),
        TrainConfig { log_every: 1000, ..Default::default() },
        opts,
    )
    .unwrap();
    let summary = coord.run(&runs, "grow").unwrap();
    assert_eq!(summary.boundaries.len(), 2, "tiny schedule has 2 boundaries");

    let store = RunStore::open(&runs).unwrap();
    let reports = store.ingest_all().unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].0, "grow");
    assert!(reports[0].1.new_records > 0);

    let s = store.stats("grow").unwrap();
    assert_eq!(s.malformed, 0, "a real run log must parse cleanly");
    assert_eq!(s.policy.as_deref(), Some("fixed"));
    assert_eq!(s.segments.len(), 3);
    assert!(s.loss_points.len() >= s.segments.len(), "loss curve sampled per segment");

    // every expansion carries validated plan evidence, and the measured
    // param delta equals the plan's exact prediction
    assert_eq!(s.expansions.len(), 2);
    for e in &s.expansions {
        let plan = e.plan.as_ref().unwrap_or_else(|| {
            panic!("expansion into '{}' lost its plan: {:?}", e.into_stage, e.plan_error)
        });
        let measured = e.param_delta.expect("measured delta recorded");
        assert_eq!(measured, plan.param_delta() as u64, "at '{}'", e.into_stage);
        assert_eq!(e.params_after, plan.params_after() as u64, "at '{}'", e.into_stage);
    }
    assert!(s.params_delta_total() > 0, "growth must add parameters");

    // every boundary has a preservation measurement, within tolerance
    assert_eq!(s.preservation.len(), 2);
    for (e, p) in s.expansions.iter().zip(&s.preservation) {
        assert_eq!(p.boundary, e.into_stage);
        assert!(p.within_tol, "drift {} vs tol {} at '{}'", p.probe_delta, p.tol, p.boundary);
        assert!(p.probe_delta <= p.tol);
    }

    assert!(s.final_eval_loss.unwrap().is_finite());
    assert_eq!(s.total_steps, Some(summary.total_steps as u64));

    // the summary document landed next to the records and agrees
    let doc = Value::load(&format!("{}/grow/summary.json", store.dir())).unwrap();
    assert_eq!(doc.req("expansions").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(
        doc.req("params_delta_total").unwrap().as_i64().unwrap() as u64,
        s.params_delta_total()
    );

    // re-ingest of a finished run is a no-op
    assert_eq!(store.ingest("grow").unwrap().new_records, 0);
    std::fs::remove_dir_all(&runs).unwrap();
}

#[test]
fn ingest_all_discovers_runs_and_skips_non_runs() {
    let runs = tmp_runs("discover");
    for (name, id) in [("beta", 2), ("alpha", 1)] {
        let dir = format!("{runs}/{name}");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            format!("{dir}/events.jsonl"),
            format!("{{\"event\":\"span\",\"id\":{id}}}\n"),
        )
        .unwrap();
    }
    // a directory without events.jsonl is not a run
    std::fs::create_dir_all(format!("{runs}/scratch")).unwrap();
    std::fs::write(format!("{runs}/bench.jsonl"), "{\"kind\":\"row\"}\n").unwrap();

    let store = RunStore::open(&runs).unwrap();
    let reports = store.ingest_all().unwrap();
    let names: Vec<&str> = reports.iter().map(|(n, _)| n.as_str()).collect();
    assert_eq!(names, ["alpha", "beta"], "sorted, .store and scratch skipped");
    assert!(reports.iter().all(|(_, r)| r.new_records == 1));
    assert_eq!(store.runs().unwrap(), vec!["alpha".to_string(), "beta".to_string()]);

    // second pass: everything is already ingested (including bench rows)
    let reports = store.ingest_all().unwrap();
    assert!(reports.iter().all(|(_, r)| r.new_records == 0 && r.total_records == 1));
    let bench = std::fs::read_to_string(format!("{}/bench.jsonl", store.dir())).unwrap();
    assert_eq!(bench.lines().count(), 1);

    // asking for a run that was never ingested names the fix
    let err = store.stats("nope").unwrap_err().to_string();
    assert!(err.contains("not ingested"), "{err}");
    std::fs::remove_dir_all(&runs).unwrap();
}
