//! Shared helpers for integration tests.
//!
//! Two worlds: the PJRT artifact world ([`manifest`]) requires `make
//! artifacts` + real xla bindings and stays `#[ignore]`d in-tree; the
//! native world ([`tiny_schedule`] / [`tiny_manifest`]) runs fully offline
//! against the shipped `configs/growth_tiny.json` and the autodiff
//! backend, and carries the bulk of the integration coverage.
#![allow(dead_code)] // each test binary uses its own subset of helpers

use texpand::config::GrowthSchedule;
use texpand::runtime::Manifest;

pub const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
pub const SCHEDULE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/growth_default.json");
pub const TINY_SCHEDULE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/growth_tiny.json");

/// Load the shipped manifest, with a clear failure if artifacts are absent.
pub fn manifest() -> Manifest {
    Manifest::load(ARTIFACTS, "manifest.json").unwrap_or_else(|e| {
        panic!("integration tests need AOT artifacts — run `make artifacts` first: {e}")
    })
}

pub fn schedule() -> GrowthSchedule {
    GrowthSchedule::load(SCHEDULE).expect("shipped schedule must parse")
}

/// The small offline schedule the native-backend integration tests run on
/// (3 stages, 2 boundaries, 4 of the 6 expansion ops).
pub fn tiny_schedule() -> GrowthSchedule {
    GrowthSchedule::load(TINY_SCHEDULE).expect("shipped tiny schedule must parse")
}

/// Synthetic manifest for the native backend (no artifacts involved).
pub fn tiny_manifest() -> Manifest {
    Manifest::from_schedule(&tiny_schedule())
}

/// Random token batch for a stage config.
pub fn random_batch(
    cfg: &texpand::config::ModelConfig,
    batch: usize,
    seed: u64,
) -> texpand::data::Batch {
    texpand::data::Batch::random(cfg, batch, seed)
}

// --- fault-injection helpers (DESIGN.md §16.5) ------------------------
//
// Two complementary failure models share this module:
//  * process death — a spawned `texpand` child armed with
//    `TEXPAND_FAULT=<site>:<nth>` aborts at an exact program point
//    ([`fault_env`] builds the pair, [`texpand_cmd`] the child);
//  * I/O failure  — [`FailingWriter`] makes a `RunLogger` writer start
//    erroring ENOSPC-style after a set number of writes, for the
//    error-surfacing (not crash-recovery) paths.

/// The env `(key, value)` pair arming fault site `site` to abort the
/// child process on its `nth` (1-based) hit. See `texpand::faults`.
pub fn fault_env(site: &str, nth: usize) -> (String, String) {
    ("TEXPAND_FAULT".to_string(), format!("{site}:{nth}"))
}

/// A `texpand` binary invocation rooted at `dir`. Tests that write runs
/// or checkpoints point this at a temp dir so the repo tree stays clean;
/// pass absolute schedule paths ([`TINY_SCHEDULE`]) alongside.
pub fn texpand_cmd(dir: &std::path::Path) -> std::process::Command {
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_texpand"));
    cmd.current_dir(dir);
    cmd
}

/// A writer that succeeds for the first `ok_writes` write calls and then
/// fails every write and flush — the deterministic stand-in for a disk
/// that fills up mid-run. Box it into `RunLogger::with_writers` to drive
/// the logger's error-surfacing paths.
pub struct FailingWriter {
    ok_writes: usize,
    written: usize,
}

impl FailingWriter {
    pub fn after(ok_writes: usize) -> FailingWriter {
        FailingWriter { ok_writes, written: 0 }
    }
}

impl std::io::Write for FailingWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.written >= self.ok_writes {
            return Err(std::io::Error::new(std::io::ErrorKind::WriteZero, "injected write failure"));
        }
        self.written += 1;
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        if self.written >= self.ok_writes {
            return Err(std::io::Error::new(std::io::ErrorKind::WriteZero, "injected flush failure"));
        }
        Ok(())
    }
}
