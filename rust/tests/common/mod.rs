//! Shared helpers for integration tests (require `make artifacts` first).

use texpand::config::GrowthSchedule;
use texpand::runtime::Manifest;

pub const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
pub const SCHEDULE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/growth_default.json");

/// Load the shipped manifest, with a clear failure if artifacts are absent.
pub fn manifest() -> Manifest {
    Manifest::load(ARTIFACTS, "manifest.json").unwrap_or_else(|e| {
        panic!("integration tests need AOT artifacts — run `make artifacts` first: {e}")
    })
}

pub fn schedule() -> GrowthSchedule {
    GrowthSchedule::load(SCHEDULE).expect("shipped schedule must parse")
}

/// Random token batch for a stage config.
pub fn random_batch(
    cfg: &texpand::config::ModelConfig,
    batch: usize,
    seed: u64,
) -> texpand::data::Batch {
    let mut rng = texpand::rng::Pcg32::seeded(seed);
    let row = |rng: &mut texpand::rng::Pcg32| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
    texpand::data::Batch {
        tokens: (0..batch).map(|_| row(&mut rng)).collect(),
        targets: (0..batch).map(|_| row(&mut rng)).collect(),
    }
}
