//! Shared helpers for integration tests.
//!
//! Two worlds: the PJRT artifact world ([`manifest`]) requires `make
//! artifacts` + real xla bindings and stays `#[ignore]`d in-tree; the
//! native world ([`tiny_schedule`] / [`tiny_manifest`]) runs fully offline
//! against the shipped `configs/growth_tiny.json` and the autodiff
//! backend, and carries the bulk of the integration coverage.
#![allow(dead_code)] // each test binary uses its own subset of helpers

use texpand::config::GrowthSchedule;
use texpand::runtime::Manifest;

pub const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
pub const SCHEDULE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/growth_default.json");
pub const TINY_SCHEDULE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/growth_tiny.json");

/// Load the shipped manifest, with a clear failure if artifacts are absent.
pub fn manifest() -> Manifest {
    Manifest::load(ARTIFACTS, "manifest.json").unwrap_or_else(|e| {
        panic!("integration tests need AOT artifacts — run `make artifacts` first: {e}")
    })
}

pub fn schedule() -> GrowthSchedule {
    GrowthSchedule::load(SCHEDULE).expect("shipped schedule must parse")
}

/// The small offline schedule the native-backend integration tests run on
/// (3 stages, 2 boundaries, 4 of the 6 expansion ops).
pub fn tiny_schedule() -> GrowthSchedule {
    GrowthSchedule::load(TINY_SCHEDULE).expect("shipped tiny schedule must parse")
}

/// Synthetic manifest for the native backend (no artifacts involved).
pub fn tiny_manifest() -> Manifest {
    Manifest::from_schedule(&tiny_schedule())
}

/// Random token batch for a stage config.
pub fn random_batch(
    cfg: &texpand::config::ModelConfig,
    batch: usize,
    seed: u64,
) -> texpand::data::Batch {
    texpand::data::Batch::random(cfg, batch, seed)
}
