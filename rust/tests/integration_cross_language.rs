//! Integration: three-way agreement — JAX (via the AOT artifacts), the
//! pure-Rust reference model, and the expansion surgery on both sides.
//!
//! The artifacts *are* the lowered JAX model, so executing them against the
//! Rust reference forward on identical parameters is the cross-language
//! equivalence check (DESIGN.md E1's "three harnesses").

mod common;

use common::{manifest, random_batch};
use texpand::config::{GrowthOp, LayerPosition};
use texpand::expand::{ExpandOptions, ExpansionPlan, Init};
use texpand::model::{cross_entropy, forward, max_logit_delta};
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::runtime::Runtime;

/// Cross-implementation tolerance: XLA fuses/reorders float reductions, so
/// agreement is ~1e-5 at these magnitudes, not bit-exact (DESIGN.md §8).
const CROSS_TOL: f32 = 5e-4;

#[test]
#[ignore = "genuinely PJRT-specific: three-way JAX/Rust/PJRT agreement is only meaningful against real compiled artifacts (stub xla build in-tree); run `make artifacts` with real bindings to enable — Rust-side gradient/forward correctness is covered offline by the autodiff finite-difference suite and the native-backend integration tests"]
fn pjrt_forward_matches_rust_reference_all_stages() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    for stage_meta in &m.stages {
        let stage = rt.load_stage(&m, &stage_meta.name).unwrap();
        let cfg = stage.meta.config;
        let mut rng = Pcg32::seeded(21);
        let params = ParamStore::init(&cfg, &mut rng, 0.02);
        let batch = random_batch(&cfg, m.batch, 22);

        let pjrt_logits = rt.forward(&stage, &params, &batch.tokens).unwrap();
        let rust_logits = forward(&cfg, &params, &batch.tokens).unwrap();
        let delta = max_logit_delta(&pjrt_logits, &rust_logits).unwrap();
        assert!(delta <= CROSS_TOL, "stage {}: jax-vs-rust max|Δ| = {delta}", stage_meta.name);
    }
}

#[test]
#[ignore = "genuinely PJRT-specific: three-way JAX/Rust/PJRT agreement is only meaningful against real compiled artifacts (stub xla build in-tree); run `make artifacts` with real bindings to enable — Rust-side gradient/forward correctness is covered offline by the autodiff finite-difference suite and the native-backend integration tests"]
fn pjrt_loss_matches_rust_cross_entropy() {
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage = rt.load_stage(&m, "stage0").unwrap();
    let cfg = stage.meta.config;
    let mut rng = Pcg32::seeded(23);
    let params = ParamStore::init(&cfg, &mut rng, 0.02);
    let batch = random_batch(&cfg, m.batch, 24);

    let (pjrt_loss, _) = rt.step(&stage, &params, &batch).unwrap();
    let rust_logits = forward(&cfg, &params, &batch.tokens).unwrap();
    let rust_loss = cross_entropy(&rust_logits, &batch.targets).unwrap();
    assert!(
        (pjrt_loss - rust_loss).abs() < 1e-4,
        "loss mismatch: pjrt {pjrt_loss} vs rust {rust_loss}"
    );
}

#[test]
#[ignore = "genuinely PJRT-specific: three-way JAX/Rust/PJRT agreement is only meaningful against real compiled artifacts (stub xla build in-tree); run `make artifacts` with real bindings to enable — Rust-side gradient/forward correctness is covered offline by the autodiff finite-difference suite and the native-backend integration tests"]
fn surgery_preserves_across_the_language_boundary() {
    // logits(old params, old artifact) == logits(expanded params, new artifact):
    // the strongest statement — Rust surgery on params feeding the *JAX*
    // compiled graph of the larger architecture reproduces the function.
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage0 = rt.load_stage(&m, "stage0").unwrap();
    let stage1 = rt.load_stage(&m, "stage1").unwrap();

    let cfg0 = stage0.meta.config;
    let mut rng = Pcg32::seeded(25);
    let params0 = ParamStore::init(&cfg0, &mut rng, 0.02);
    let batch = random_batch(&cfg0, m.batch, 26);

    // the schedule's stage0→stage1 ops (mlp 256, heads_add 1)
    let ops = vec![GrowthOp::Mlp { p: 256 }, GrowthOp::HeadsAdd { count: 1 }];
    let opts = ExpandOptions { init: Init::Normal(0.2), ..Default::default() };
    let params1 = ExpansionPlan::new(params0.config(), ops)
        .unwrap()
        .materialize(&params0, &opts, &mut rng)
        .unwrap();
    assert_eq!(params1.config(), &stage1.meta.config);

    let before = rt.forward(&stage0, &params0, &batch.tokens).unwrap();
    let after = rt.forward(&stage1, &params1, &batch.tokens).unwrap();
    let delta = max_logit_delta(&before, &after).unwrap();
    assert!(delta <= CROSS_TOL, "cross-stage preservation: max|Δ| = {delta}");
}

#[test]
#[ignore = "genuinely PJRT-specific: three-way JAX/Rust/PJRT agreement is only meaningful against real compiled artifacts (stub xla build in-tree); run `make artifacts` with real bindings to enable — Rust-side gradient/forward correctness is covered offline by the autodiff finite-difference suite and the native-backend integration tests"]
fn composed_surgery_reaches_final_stage_exactly() {
    // walk all schedule boundaries in one shot: stage0 params expanded by
    // the concatenation of every stage's ops must satisfy stage3's artifact
    // and preserve stage0's function.
    let m = manifest();
    let s = common::schedule();
    let mut rt = Runtime::cpu().unwrap();
    let first = rt.load_stage(&m, &s.stages[0].name).unwrap();
    let last = rt.load_stage(&m, &s.stages.last().unwrap().name).unwrap();

    let mut rng = Pcg32::seeded(27);
    let params0 = ParamStore::init(&first.meta.config, &mut rng, 0.02);
    let batch = random_batch(&first.meta.config, m.batch, 28);

    let all_ops: Vec<GrowthOp> = s.stages.iter().flat_map(|st| st.apply.clone()).collect();
    assert!(all_ops.len() >= 6, "default schedule should compose many ops");
    let opts = ExpandOptions { init: Init::Normal(0.2), ..Default::default() };
    let params_final = ExpansionPlan::new(params0.config(), all_ops)
        .unwrap()
        .materialize(&params0, &opts, &mut rng)
        .unwrap();
    assert_eq!(params_final.config(), &last.meta.config);

    let before = rt.forward(&first, &params0, &batch.tokens).unwrap();
    let after = rt.forward(&last, &params_final, &batch.tokens).unwrap();
    let delta = max_logit_delta(&before, &after).unwrap();
    assert!(delta <= CROSS_TOL, "composed preservation: max|Δ| = {delta}");
}

#[test]
#[ignore = "genuinely PJRT-specific: three-way JAX/Rust/PJRT agreement is only meaningful against real compiled artifacts (stub xla build in-tree); run `make artifacts` with real bindings to enable — Rust-side gradient/forward correctness is covered offline by the autodiff finite-difference suite and the native-backend integration tests"]
fn violated_constraints_break_preservation_through_pjrt() {
    // negative control at the integration level: the same surgery with
    // zero_constrained=false must NOT preserve through the compiled graph.
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let stage0 = rt.load_stage(&m, "stage0").unwrap();
    let stage1 = rt.load_stage(&m, "stage1").unwrap();
    let mut rng = Pcg32::seeded(29);
    let params0 = ParamStore::init(&stage0.meta.config, &mut rng, 0.05);
    let batch = random_batch(&stage0.meta.config, m.batch, 30);

    let ops = vec![GrowthOp::Mlp { p: 256 }, GrowthOp::HeadsAdd { count: 1 }];
    let opts = ExpandOptions {
        init: Init::Normal(0.2),
        zero_constrained: false,
        ..Default::default()
    };
    let bad = ExpansionPlan::new(params0.config(), ops)
        .unwrap()
        .materialize(&params0, &opts, &mut rng)
        .unwrap();
    let before = rt.forward(&stage0, &params0, &batch.tokens).unwrap();
    let after = rt.forward(&stage1, &bad, &batch.tokens).unwrap();
    let delta = max_logit_delta(&before, &after).unwrap();
    assert!(delta > 1e-2, "violation should break preservation, got {delta}");
}

#[test]
#[ignore = "genuinely PJRT-specific: three-way JAX/Rust/PJRT agreement is only meaningful against real compiled artifacts (stub xla build in-tree); run `make artifacts` with real bindings to enable — Rust-side gradient/forward correctness is covered offline by the autodiff finite-difference suite and the native-backend integration tests"]
fn add_layers_positions_agree_with_artifacts() {
    // Layer insertion at any position must satisfy the *same* stage
    // artifact (architecture is position-agnostic) and preserve function.
    let m = manifest();
    let s = common::schedule();
    let mut rt = Runtime::cpu().unwrap();
    // stage2 -> stage3 includes layers_add; rebuild it with each position
    let stage2 = rt.load_stage(&m, "stage2").unwrap();
    let stage3 = rt.load_stage(&m, "stage3").unwrap();
    let ops_spec = &s.stages[3].apply;
    assert!(ops_spec.iter().any(|o| matches!(o, GrowthOp::LayersAdd { .. })));

    let mut rng = Pcg32::seeded(31);
    let params2 = ParamStore::init(&stage2.meta.config, &mut rng, 0.02);
    let batch = random_batch(&stage2.meta.config, m.batch, 32);
    let before = rt.forward(&stage2, &params2, &batch.tokens).unwrap();

    for position in [LayerPosition::Top, LayerPosition::Bottom, LayerPosition::At(1)] {
        let ops: Vec<GrowthOp> = ops_spec
            .iter()
            .map(|o| match o {
                GrowthOp::LayersAdd { count, .. } => GrowthOp::LayersAdd { count: *count, position },
                other => other.clone(),
            })
            .collect();
        let opts = ExpandOptions { init: Init::Normal(0.2), ..Default::default() };
        let params3 = ExpansionPlan::new(params2.config(), ops)
            .unwrap()
            .materialize(&params2, &opts, &mut rng)
            .unwrap();
        let after = rt.forward(&stage3, &params3, &batch.tokens).unwrap();
        let delta = max_logit_delta(&before, &after).unwrap();
        assert!(delta <= CROSS_TOL, "{position:?}: max|Δ| = {delta}");
    }
}
