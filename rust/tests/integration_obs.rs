//! Integration: the observability layer end to end (S19/S20;
//! DESIGN.md §14–§15).
//!
//! Acceptance properties:
//! (a) `/metrics` output is valid Prometheus text exposition — parsed
//!     back here: HELP/TYPE headers precede samples, names are valid,
//!     label escaping round-trips, histogram buckets are cumulative,
//!     monotone and end in a `le="+Inf"` bucket equal to `_count`, and
//!     exemplar annotations appear only on `_bucket` lines in the
//!     ` # {request_id="N"} V` shape (malformed ones are rejected);
//! (b) the serve engine publishes counters, latency histograms and
//!     per-request spans through a registry, live over real TCP, and
//!     the `/spans` route streams ring contents as chunked JSON lines,
//!     surviving a client that disconnects mid-stream;
//! (c) histogram percentile estimates match an exact sorted-quantile
//!     oracle to within one bucket width (property test).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use texpand::config::{GrowthOp, ModelConfig};
use texpand::expand::{ExpandOptions, ExpansionPlan};
use texpand::generate::Sampler;
use texpand::obs::registry::valid_metric_name;
use texpand::obs::{
    http_get, http_stream_lines, render, MetricsRegistry, MetricsServer, SpanRing,
    LATENCY_MS_BOUNDS,
};
use texpand::params::ParamStore;
use texpand::prop::Runner;
use texpand::rng::Pcg32;
use texpand::serve::{Engine, EngineOptions};

/// Per-series histogram state accumulated while walking an exposition
/// document (keyed by family + labels minus `le`).
#[derive(Default)]
struct HistSeries {
    last_le: f64,
    last_cum: u64,
    buckets: usize,
    inf_cum: Option<u64>,
    sum_seen: bool,
    count: Option<u64>,
}

/// Split a rendered label body into (labels minus `le`, the `le` value).
/// Test label values deliberately avoid commas, so a plain split is safe.
fn strip_le(labels: &str) -> (String, Option<String>) {
    let mut le = None;
    let kept: Vec<&str> = labels
        .split(',')
        .filter(|part| match part.strip_prefix("le=\"") {
            Some(v) => {
                le = Some(v.trim_end_matches('"').to_string());
                false
            }
            None => !part.is_empty(),
        })
        .collect();
    (kept.join(","), le)
}

/// Parse an exposition document back and assert the format contract the
/// module docs of `obs::prometheus` promise.
fn validate_exposition(text: &str) {
    let mut seen_families: Vec<String> = Vec::new();
    let mut pending_help: Option<String> = None;
    let mut current: Option<(String, String)> = None;
    let mut hists: HashMap<String, HistSeries> = HashMap::new();

    for line in text.lines() {
        assert!(!line.is_empty(), "exposition has no blank lines");
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split(' ').next().unwrap().to_string();
            assert!(!seen_families.contains(&name), "family '{name}' emitted twice");
            pending_help = Some(name);
            current = None;
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let name = it.next().unwrap().to_string();
            let kind = it.next().unwrap_or("").to_string();
            assert_eq!(pending_help.take(), Some(name.clone()), "TYPE without HELP: {line}");
            assert!(valid_metric_name(&name), "invalid family name '{name}'");
            assert!(
                ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                "unknown kind in {line}"
            );
            seen_families.push(name.clone());
            current = Some((name, kind));
        } else {
            let (fam, kind) = current.clone().expect("sample line before any TYPE header");
            // exemplar annotations ride after the sample as a ` # {...} V`
            // comment; split them off before parsing the sample itself
            let (sample, exemplar) = match line.split_once(" # ") {
                Some((s, e)) => (s, Some(e)),
                None => (line, None),
            };
            let (series, value) = sample.rsplit_once(' ').expect("sample line has no value");
            let (name_part, label_part) = match series.find('{') {
                Some(i) => {
                    assert!(series.ends_with('}'), "unterminated labels in {line}");
                    (&series[..i], &series[i + 1..series.len() - 1])
                }
                None => (series, ""),
            };
            match kind.as_str() {
                "counter" => {
                    assert_eq!(name_part, fam, "stray sample {line}");
                    assert!(exemplar.is_none(), "exemplar on a counter line: {line}");
                    value.parse::<u64>().expect("counter value must be an unsigned integer");
                }
                "gauge" => {
                    assert_eq!(name_part, fam, "stray sample {line}");
                    assert!(exemplar.is_none(), "exemplar on a gauge line: {line}");
                    // Rust's f64 parser accepts the format's NaN/+Inf/-Inf
                    value.parse::<f64>().expect("gauge value must parse");
                }
                "histogram" => {
                    let (key_labels, le) = strip_le(label_part);
                    let key = format!("{fam}|{key_labels}");
                    let suffix = name_part
                        .strip_prefix(fam.as_str())
                        .unwrap_or_else(|| panic!("sample '{line}' outside family '{fam}'"));
                    match suffix {
                        "_bucket" => {
                            if let Some(ex) = exemplar {
                                validate_exemplar(ex, line);
                            }
                            let le = le.expect("bucket line without le label");
                            let cum = value.parse::<u64>().expect("bucket count");
                            let h = hists.entry(key).or_default();
                            assert!(cum >= h.last_cum, "non-monotone cumulative bucket: {line}");
                            if le == "+Inf" {
                                assert!(h.inf_cum.is_none(), "duplicate +Inf bucket: {line}");
                                h.inf_cum = Some(cum);
                            } else {
                                let bound = le.parse::<f64>().expect("finite le bound");
                                assert!(h.inf_cum.is_none(), "finite bucket after +Inf: {line}");
                                assert!(
                                    h.buckets == 0 || bound > h.last_le,
                                    "bucket bounds not ascending: {line}"
                                );
                                h.last_le = bound;
                            }
                            h.buckets += 1;
                            h.last_cum = cum;
                        }
                        "_sum" => {
                            assert!(exemplar.is_none(), "exemplar on a _sum line: {line}");
                            value.parse::<f64>().expect("histogram sum");
                            hists.entry(key).or_default().sum_seen = true;
                        }
                        "_count" => {
                            assert!(exemplar.is_none(), "exemplar on a _count line: {line}");
                            let count = value.parse::<u64>().expect("histogram count");
                            let h = hists.entry(key).or_default();
                            assert_eq!(
                                h.inf_cum,
                                Some(count),
                                "histogram _count must equal its +Inf bucket ({fam})"
                            );
                            h.count = Some(count);
                        }
                        _ => panic!("unexpected sample '{line}' in histogram family '{fam}'"),
                    }
                }
                other => panic!("unreachable kind {other}"),
            }
        }
    }
    assert!(!seen_families.is_empty(), "document announced no families");
    for (key, h) in &hists {
        assert!(h.inf_cum.is_some(), "histogram series {key} missing +Inf bucket");
        assert!(h.sum_seen, "histogram series {key} missing _sum");
        assert!(h.count.is_some(), "histogram series {key} missing _count");
    }
}

/// Assert one exemplar annotation matches the promised shape:
/// `{request_id="N"} V` with a u64 id and a parseable value.
fn validate_exemplar(ex: &str, line: &str) {
    let rest = ex
        .strip_prefix("{request_id=\"")
        .unwrap_or_else(|| panic!("exemplar must open with request_id: {line}"));
    let (id, value) = rest
        .split_once("\"} ")
        .unwrap_or_else(|| panic!("exemplar must close its label set and carry a value: {line}"));
    id.parse::<u64>().unwrap_or_else(|_| panic!("exemplar request id must be a u64: {line}"));
    value.parse::<f64>().unwrap_or_else(|_| panic!("exemplar value must parse: {line}"));
}

/// A registry exercising every family kind, labels, non-finite values and
/// out-of-range histogram observations.
fn populated_registry() -> MetricsRegistry {
    let reg = MetricsRegistry::new();
    reg.counter("obs_requests_total", "Total requests").add(7);
    reg.counter_with("obs_decisions_total", "Verdicts", &[("decision", "expand")]).inc();
    reg.counter_with("obs_decisions_total", "Verdicts", &[("decision", "continue")]).add(3);
    reg.gauge("obs_queue_depth", "Queued requests").set(2.5);
    reg.gauge("obs_headroom", "help text with \\ and\nnewline").set(f64::INFINITY);
    let h = reg.histogram("obs_lat_ms", "Latency", &LATENCY_MS_BOUNDS);
    for v in [0.02, 0.3, 4.0, 40.0, 900.0, 20_000.0] {
        h.observe(v);
    }
    let hl =
        reg.histogram_with("obs_phase_ms", "Phase cost", &[1.0, 5.0, 25.0], &[("phase", "decode")]);
    hl.observe(0.5);
    hl.observe(3.0);
    hl.observe(100.0);
    reg
}

#[test]
fn rendered_exposition_parses_back_valid() {
    let reg = populated_registry();
    let text = render(&reg);
    validate_exposition(&text);
    assert!(text.contains("obs_requests_total 7\n"), "{text}");
    assert!(text.contains("obs_decisions_total{decision=\"expand\"} 1\n"), "{text}");
    assert!(text.contains("obs_headroom +Inf\n"), "{text}");
    assert!(text.contains("# HELP obs_headroom help text with \\\\ and\\nnewline\n"), "{text}");
    assert!(text.contains("obs_lat_ms_count 6\n"), "{text}");
    // 20000 ms exceeds the last finite bound: +Inf bucket only
    assert!(text.contains("obs_lat_ms_bucket{le=\"5000\"} 5\n"), "{text}");
    assert!(text.contains("obs_lat_ms_bucket{le=\"+Inf\"} 6\n"), "{text}");
}

#[test]
fn label_escaping_round_trips() {
    let reg = MetricsRegistry::new();
    let original = "a\\b \"q\"\nend";
    reg.counter_with("obs_esc_total", "escapes", &[("path", original)]).inc();
    let text = render(&reg);
    assert!(text.contains("obs_esc_total{path=\"a\\\\b \\\"q\\\"\\nend\"} 1\n"), "{text}");
    let start = text.find("path=\"").unwrap() + "path=\"".len();
    let end = text.rfind("\"} 1").unwrap();
    let unescaped =
        text[start..end].replace("\\n", "\n").replace("\\\"", "\"").replace("\\\\", "\\");
    assert_eq!(unescaped, original, "label value must survive an escape round-trip");
}

#[test]
fn metrics_server_serves_valid_exposition_over_tcp() {
    let reg = Arc::new(populated_registry());
    let srv = MetricsServer::bind("127.0.0.1:0", reg.clone()).unwrap();
    let addr = srv.local_addr().to_string();
    let (status, body) = http_get(&addr, "/healthz", Duration::from_secs(5)).unwrap();
    assert_eq!((status, body.as_str()), (200, "ok\n"));
    let (status, body) = http_get(&addr, "/metrics", Duration::from_secs(5)).unwrap();
    assert_eq!(status, 200);
    validate_exposition(&body);
    assert!(body.contains("obs_requests_total 7\n"), "{body}");
    // live updates are visible to the next scrape
    reg.counter("obs_requests_total", "Total requests").add(2);
    let (_, body) = http_get(&addr, "/metrics", Duration::from_secs(5)).unwrap();
    assert!(body.contains("obs_requests_total 9\n"), "{body}");
    srv.shutdown();
}

#[test]
fn exemplar_annotations_round_trip_through_the_validator() {
    let reg = MetricsRegistry::new();
    let h = reg.histogram("obs_ex_ms", "Exemplified latency", &[1.0, 10.0, 100.0]);
    h.observe_with_exemplar(0.5, 41);
    h.observe_with_exemplar(0.7, 42); // same bucket: latest id wins
    h.observe_with_exemplar(50.0, 7);
    h.observe(5.0); // no exemplar recorded for the middle bucket... yet
    let text = render(&reg);
    validate_exposition(&text);
    assert!(text.contains("obs_ex_ms_bucket{le=\"1\"} 2 # {request_id=\"42\"} 0.7\n"), "{text}");
    assert!(text.contains("obs_ex_ms_bucket{le=\"100\"} 4 # {request_id=\"7\"} 50\n"), "{text}");
    // the plain observe left its bucket annotation-free
    assert!(text.contains("obs_ex_ms_bucket{le=\"10\"} 3\n"), "{text}");
}

#[test]
fn malformed_exemplar_annotations_are_rejected() {
    let cases = [
        // exemplar on a counter sample
        "# HELP bad_total t\n# TYPE bad_total counter\nbad_total 1 # {request_id=\"1\"} 2\n",
        // wrong label name
        "# HELP bad_ms t\n# TYPE bad_ms histogram\nbad_ms_bucket{le=\"+Inf\"} 1 # {trace=\"1\"} 2\n",
        // non-numeric id
        "# HELP bad_ms t\n# TYPE bad_ms histogram\nbad_ms_bucket{le=\"+Inf\"} 1 # {request_id=\"x\"} 2\n",
        // annotation with no value
        "# HELP bad_ms t\n# TYPE bad_ms histogram\nbad_ms_bucket{le=\"+Inf\"} 1 # {request_id=\"1\"}\n",
    ];
    for doc in cases {
        let result = std::panic::catch_unwind(|| validate_exposition(doc));
        assert!(result.is_err(), "validator accepted malformed exemplar doc:\n{doc}");
    }
}

#[test]
fn spans_route_streams_live_and_survives_midstream_disconnect() {
    let reg = Arc::new(MetricsRegistry::new());
    reg.counter("obs_alive_total", "liveness witness").inc();
    let ring = Arc::new(SpanRing::new(64));
    let srv = MetricsServer::bind_with_spans("127.0.0.1:0", reg.clone(), Some(ring.clone())).unwrap();
    let addr = srv.local_addr().to_string();
    for i in 0..4u64 {
        ring.push(format!("{{\"id\":{i}}}"));
    }
    // client 1: read two spans, then disconnect mid-stream (the server
    // still holds spans 2 and 3 for this cursor when we hang up)
    let mut got = Vec::new();
    let n = http_stream_lines(&addr, "/spans", Duration::from_secs(5), Some(2), &mut |l| {
        got.push(l.to_string());
    })
    .unwrap();
    assert_eq!((n, got.as_slice()), (2, &["{\"id\":0}".to_string(), "{\"id\":1}".to_string()][..]));
    // the accept loop must not be wedged by the dangling stream thread:
    // /metrics still answers...
    let (status, body) = http_get(&addr, "/metrics", Duration::from_secs(5)).unwrap();
    assert_eq!(status, 200);
    assert!(body.contains("obs_alive_total 1\n"), "{body}");
    // ...and a fresh /spans client gets the full backlog plus a span
    // pushed while it is connected (live delivery, not just replay)
    let pusher = {
        let ring = Arc::clone(&ring);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            ring.push("{\"id\":99}".to_string());
        })
    };
    let mut got = Vec::new();
    let n = http_stream_lines(&addr, "/spans", Duration::from_secs(5), Some(5), &mut |l| {
        got.push(l.to_string());
    })
    .unwrap();
    pusher.join().unwrap();
    assert_eq!(n, 5, "4 backlog + 1 live span: {got:?}");
    assert_eq!(got.last().map(String::as_str), Some("{\"id\":99}"));
    srv.shutdown();
}

#[test]
fn engine_publishes_counters_histograms_and_spans() {
    let reg = MetricsRegistry::new();
    let cfg =
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 };
    let params = ParamStore::init(&cfg, &mut Pcg32::seeded(11), 0.05);
    let opts = EngineOptions { max_slots: 2, parallel: false, ..Default::default() };
    let mut engine = Engine::with_registry(params, opts, &reg);
    let sampler = Sampler { temperature: 0.0, top_k: None, seed: 0 };
    for i in 0..4u32 {
        engine.submit(vec![i % 16, (i + 3) % 16], 5, sampler).unwrap();
    }
    engine.run_until_idle().unwrap();

    let spans = engine.take_spans();
    assert_eq!(spans.len(), 4, "one span per completed request");
    for s in &spans {
        assert_eq!((s.finish, s.generated), ("max_tokens", 5));
        assert!(s.queue_ms >= 0.0 && s.prefill_ms >= 0.0 && s.decode_ms >= 0.0);
        assert!(s.total_ms + 1e-6 >= s.decode_ms);
        assert!(s.finished_tick >= s.admitted_tick);
    }
    assert!(engine.take_spans().is_empty(), "take_spans drains");

    let text = render(&reg);
    validate_exposition(&text);
    assert!(text.contains("texpand_serve_completed_total 4\n"), "{text}");
    assert!(text.contains("texpand_serve_tokens_generated_total 20\n"), "{text}");
    assert!(text.contains("texpand_serve_decode_latency_ms_count 4\n"), "{text}");
    let c = engine.counters();
    assert!(c.total_latency.p50_ms <= c.total_latency.p95_ms + 1e-9);
    assert!(c.total_latency.p95_ms <= c.total_latency.p99_ms + 1e-9);

    // hot-swap instrumentation: a committed swap bumps the swap counter
    // and lands one swap-duration observation
    engine.submit(vec![1, 2], 4, sampler).unwrap();
    engine.tick().unwrap();
    let plan = ExpansionPlan::new(engine.config(), vec![GrowthOp::Mlp { p: 32 }]).unwrap();
    engine.hot_swap(&plan, &mut Pcg32::seeded(5), &ExpandOptions::default()).unwrap();
    engine.run_until_idle().unwrap();
    let text = render(&reg);
    validate_exposition(&text);
    assert!(text.contains("texpand_serve_swaps_total 1\n"), "{text}");
    assert!(text.contains("texpand_serve_swap_ms_count 1\n"), "{text}");
    assert_eq!(engine.take_spans().len(), 1, "the post-swap request gets a span too");
}

#[test]
fn histogram_quantiles_match_sorted_oracle_within_one_bucket() {
    Runner::new("histogram quantile vs sorted oracle", 60).run(
        |rng| {
            let n = 1 + rng.below(200);
            // uniform in [0, 4000) ms — strictly below the last finite
            // bound, so the oracle bucket always has a finite upper edge
            (0..n).map(|_| rng.below(4_000_000) as f64 / 1000.0).collect::<Vec<f64>>()
        },
        |samples| {
            let reg = MetricsRegistry::new();
            let h = reg.histogram("obs_oracle_ms", "oracle", &LATENCY_MS_BOUNDS);
            for &v in samples {
                h.observe(v);
            }
            let snap = h.snapshot();
            let mut sorted = samples.clone();
            sorted.sort_by(f64::total_cmp);
            for q in [0.5, 0.95, 0.99] {
                let est = snap.quantile(q);
                let n = sorted.len();
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let exact = sorted[rank - 1];
                let i = LATENCY_MS_BOUNDS.partition_point(|b| exact > *b);
                let lo = if i == 0 { 0.0 } else { LATENCY_MS_BOUNDS[i - 1] };
                let hi = LATENCY_MS_BOUNDS[i];
                if (est - exact).abs() > (hi - lo) + 1e-9 {
                    return Err(format!(
                        "q={q}: estimate {est} vs oracle {exact} off by more than bucket [{lo}, {hi}]"
                    ));
                }
            }
            Ok(())
        },
    );
}
