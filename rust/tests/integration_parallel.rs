//! Integration: data-parallel native training, end to end.
//!
//! The unit suite proves `loss_and_grads` is bit-identical across thread
//! counts for a single step; these tests push the same claim through the
//! whole training stack — batcher, gradient clip, Adam — over multiple
//! steps, where any nondeterminism would compound, and pin the
//! micro-batch accumulation contract at the `train_stage` level.

mod common;

use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::config::TrainConfig;
use texpand::data::{Batcher, CorpusKind};
use texpand::metrics::RunLogger;
use texpand::optim::Optimizer;
use texpand::params::ParamStore;
use texpand::rng::Pcg32;
use texpand::train::{train_stage, TrainState};

/// Train `steps` steps of the tiny schedule's stage0 on a fresh backend
/// and return the resulting parameters. `tag` keeps each caller's temp
/// run directory unique — tests run concurrently in one process, and two
/// tests asking for the same (threads, micro_batch) must not race on
/// create/remove of a shared directory.
fn train_final_params(
    tag: &str,
    threads: usize,
    micro_batch: Option<usize>,
    steps: usize,
) -> ParamStore {
    let manifest = common::tiny_manifest();
    let mut backend = NativeBackend::with_threads(threads);
    backend.set_micro_batch(micro_batch);
    assert_eq!(backend.threads(), threads.max(1));
    let stage = backend.load_stage(&manifest, "stage0").unwrap();
    let cfg = stage.meta.config;
    let tcfg = TrainConfig { seed: 5, log_every: 1000, ..Default::default() };
    let mut params = ParamStore::init(&cfg, &mut Pcg32::seeded(tcfg.seed), 0.05);
    let mut opt = Optimizer::new(&tcfg, &params);
    let mut batcher = Batcher::from_corpus(
        CorpusKind::MarkovText,
        20_000,
        cfg.vocab,
        cfg.seq,
        manifest.batch,
        7,
    )
    .unwrap();
    let dir = std::env::temp_dir().join(format!(
        "texpand-par-{}-{}-{}-{}",
        std::process::id(),
        tag,
        threads,
        micro_batch.unwrap_or(0)
    ));
    let mut logger =
        RunLogger::create(dir.to_str().unwrap(), "par").unwrap().quiet();
    let mut state = TrainState::new();
    train_stage(
        &backend,
        &stage,
        &mut params,
        &mut opt,
        &mut batcher,
        &tcfg,
        &mut logger,
        &mut state,
        steps,
    )
    .unwrap();
    std::fs::remove_dir_all(&dir).ok();
    params
}

#[test]
fn multi_step_training_is_bit_identical_across_thread_counts() {
    // 6 full optimizer steps: if any step's grads depended on scheduling,
    // the divergence would compound through Adam's moments — demand exact
    // equality of every final parameter instead
    let serial = train_final_params("multistep", 1, None, 6);
    for threads in [2usize, 4] {
        let parallel = train_final_params("multistep", threads, None, 6);
        assert_eq!(
            serial.max_abs_diff(&parallel).unwrap(),
            0.0,
            "trajectory diverged at {threads} threads"
        );
    }
}

#[test]
fn micro_batched_training_tracks_full_batch_training() {
    // accumulation reassociates chunk sums (~1e-7 per step); through a few
    // Adam steps the trajectories must stay within loose tolerance
    let full = train_final_params("micro", 2, None, 3);
    let micro = train_final_params("micro", 2, Some(1), 3);
    let diff = full.max_abs_diff(&micro).unwrap();
    assert!(diff <= 1e-3, "micro-batched trajectory drifted {diff}");
    // and micro-batching must itself be thread-count deterministic
    let micro_serial = train_final_params("micro", 1, Some(1), 3);
    assert_eq!(micro.max_abs_diff(&micro_serial).unwrap(), 0.0);
}

#[test]
fn backend_step_agrees_with_itself_under_env_pool() {
    // NativeBackend::new() (env-sized pool) and an explicit 1-thread
    // backend must produce the same step — the TEXPAND_THREADS setting can
    // never change results, only wall-clock
    let manifest = common::tiny_manifest();
    let mut be_env = NativeBackend::new();
    let mut be_one = NativeBackend::with_threads(1);
    let stage = be_env.load_stage(&manifest, "stage0").unwrap();
    let stage1 = be_one.load_stage(&manifest, "stage0").unwrap();
    let cfg = stage.meta.config;
    let params = ParamStore::init(&cfg, &mut Pcg32::seeded(11), 0.05);
    let batch = common::random_batch(&cfg, manifest.batch, 13);
    let (loss_env, grads_env) = be_env.step(&stage, &params, &batch).unwrap();
    let (loss_one, grads_one) = be_one.step(&stage1, &params, &batch).unwrap();
    assert_eq!(loss_env.to_bits(), loss_one.to_bits());
    assert_eq!(grads_env, grads_one);
}
