//! Integration: durable checkpoint/resume under crash-recovery fault
//! injection (DESIGN.md §16).
//!
//! The crash tests spawn the real `texpand` binary as a child process
//! armed with `TEXPAND_FAULT=<site>:<nth>` (see `texpand::faults`), kill
//! it at an exact program point, resume with `--resume`, and assert the
//! resumed run is **bit-identical** — final params byte-for-byte, loss
//! curve row-for-row — to an oracle run that was never interrupted. That
//! is the contract the checkpoint subsystem exists to keep: a crash plus
//! a resume must be indistinguishable from no crash at all.
//!
//! Everything runs offline on `--backend native` with the tiny schedule
//! (3 stages, 2 expansion boundaries, 18 optimizer steps at scale 0.2).

mod common;

use std::path::{Path, PathBuf};

/// 0.2 × (30,30,30) steps = 6 per stage, 18 total; boundaries after
/// global steps 6 and 12.
const SCALE: &str = "0.2";
const TOTAL_STEPS: usize = 18;

fn setup(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("texpand-ckpt-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One `texpand train` invocation rooted at `dir` (run lands in
/// `dir/runs/run`), optionally armed with a fault site.
fn train(dir: &Path, extra: &[&str], fault: Option<(String, String)>) -> std::process::Output {
    let mut cmd = common::texpand_cmd(dir);
    cmd.args([
        "train",
        "--backend",
        "native",
        "--schedule",
        common::TINY_SCHEDULE,
        "--steps-scale",
        SCALE,
        "--seed",
        "11",
        "--log-every",
        "100",
        "--runs",
        "runs",
        "--run-name",
        "run",
    ]);
    cmd.args(extra);
    if let Some((k, v)) = fault {
        cmd.env(k, v);
    }
    cmd.output().expect("spawn texpand")
}

/// Final trained weights, byte for byte (the bit-identicality witness).
fn final_params(dir: &Path) -> Vec<u8> {
    let p = dir.join("runs/run/stage2.txpd");
    std::fs::read(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// loss.csv with the wall-clock column stripped (wall_ms is the one
/// legitimately nondeterministic field).
fn loss_prefix(dir: &Path) -> Vec<String> {
    let text = std::fs::read_to_string(dir.join("runs/run/loss.csv")).unwrap();
    text.lines()
        .map(|l| l.split(',').take(4).collect::<Vec<_>>().join(","))
        .collect()
}

fn events(dir: &Path) -> String {
    std::fs::read_to_string(dir.join("runs/run/events.jsonl")).unwrap()
}

/// A crash at a random optimizer step resumes to the exact same final
/// weights and loss curve as a run that was never interrupted.
#[test]
fn kill_at_random_step_then_resume_matches_uninterrupted_oracle() {
    let oracle_dir = setup("oracle");
    let out = train(&oracle_dir, &[], None);
    assert!(out.status.success(), "oracle: {}", String::from_utf8_lossy(&out.stderr));
    let want_params = final_params(&oracle_dir);
    let want_loss = loss_prefix(&oracle_dir);
    assert_eq!(want_loss.len(), TOTAL_STEPS + 1, "header + one row per step");

    // pick the kill step from the clock: every run of the suite probes a
    // different point in [2, TOTAL_STEPS-1] — including steps right after
    // an expansion boundary, where resume must rebuild the grown
    // architecture and its expanded Adam moments
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap()
        .subsec_nanos() as usize;
    let nth = 2 + nanos % (TOTAL_STEPS - 2);

    let crash_dir = setup("crash");
    let out = train(
        &crash_dir,
        &["--checkpoint-every", "1"],
        Some(common::fault_env("train_step", nth)),
    );
    assert!(!out.status.success(), "fault at step {nth} should abort the child");

    let out = train(&crash_dir, &["--checkpoint-every", "1", "--resume"], None);
    assert!(
        out.status.success(),
        "resume after kill at step {nth}: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("resuming from checkpoint"), "kill at step {nth}: {stdout}");

    assert_eq!(
        final_params(&crash_dir),
        want_params,
        "resumed params diverged from oracle (killed at step {nth})"
    );
    assert_eq!(
        loss_prefix(&crash_dir),
        want_loss,
        "resumed loss curve diverged from oracle (killed at step {nth})"
    );
    // the evidence trail survives: checkpoint rows from before the crash,
    // a resume row from after
    let ev = events(&crash_dir);
    assert!(ev.contains(r#""event":"checkpoint""#), "killed at step {nth}: {ev}");
    assert!(ev.contains(r#""event":"resume""#), "killed at step {nth}: {ev}");

    std::fs::remove_dir_all(&oracle_dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

/// A crash in the middle of writing a checkpoint file leaves a torn
/// `.tmp` behind — never a corrupt generation. Resume picks up the last
/// completed generation and still converges to the oracle bit for bit.
#[test]
fn crash_mid_checkpoint_write_leaves_a_recoverable_chain() {
    let oracle_dir = setup("midw-oracle");
    let out = train(&oracle_dir, &[], None);
    assert!(out.status.success(), "oracle: {}", String::from_utf8_lossy(&out.stderr));

    let crash_dir = setup("midw-crash");
    let out = train(
        &crash_dir,
        &["--checkpoint-every", "1"],
        Some(common::fault_env("ckpt_mid_write", 3)),
    );
    assert!(!out.status.success(), "mid-write fault should abort the child");
    // the torn write is a .tmp, not a gen-*.txck: atomicity held
    let ckpt_dir = crash_dir.join("runs/run/ckpt");
    let torn: Vec<String> = std::fs::read_dir(&ckpt_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".tmp"))
        .collect();
    assert!(!torn.is_empty(), "expected a torn .tmp from the mid-write crash");

    let out = train(&crash_dir, &["--checkpoint-every", "1", "--resume"], None);
    assert!(out.status.success(), "resume: {}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(final_params(&crash_dir), final_params(&oracle_dir));
    // the completed run swept the stale tmp
    let leftover = std::fs::read_dir(&ckpt_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .any(|e| e.file_name().to_string_lossy().ends_with(".tmp"));
    assert!(!leftover, "completed resume left a stale .tmp in the chain dir");

    std::fs::remove_dir_all(&oracle_dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

/// A crash just before the atomic rename publishes the checkpoint: the
/// fully-written tmp is not a generation either, and resume recovers.
#[test]
fn crash_before_rename_is_equivalent_to_crash_before_write() {
    let oracle_dir = setup("ren-oracle");
    let out = train(&oracle_dir, &[], None);
    assert!(out.status.success(), "oracle: {}", String::from_utf8_lossy(&out.stderr));

    let crash_dir = setup("ren-crash");
    let out = train(
        &crash_dir,
        &["--checkpoint-every", "1"],
        Some(common::fault_env("ckpt_pre_rename", 2)),
    );
    assert!(!out.status.success(), "pre-rename fault should abort the child");

    let out = train(&crash_dir, &["--checkpoint-every", "1", "--resume"], None);
    assert!(out.status.success(), "resume: {}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(final_params(&crash_dir), final_params(&oracle_dir));

    std::fs::remove_dir_all(&oracle_dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

/// Bit-flip the newest generation after a crash: resume must fall back
/// to the previous good generation (with a warning) and still reproduce
/// the oracle exactly.
#[test]
fn corrupted_latest_generation_falls_back_on_resume() {
    let oracle_dir = setup("corr-oracle");
    let out = train(&oracle_dir, &[], None);
    assert!(out.status.success(), "oracle: {}", String::from_utf8_lossy(&out.stderr));

    let crash_dir = setup("corr-crash");
    let out = train(
        &crash_dir,
        &["--checkpoint-every", "1"],
        Some(common::fault_env("train_step", 10)),
    );
    assert!(!out.status.success());

    // corrupt the newest retained generation mid-payload
    let ckpt_dir = crash_dir.join("runs/run/ckpt");
    let mut gens: Vec<PathBuf> = std::fs::read_dir(&ckpt_dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "txck"))
        .collect();
    gens.sort();
    assert!(gens.len() >= 2, "need at least two generations to test fallback: {gens:?}");
    let newest = gens.last().unwrap();
    let mut bytes = std::fs::read(newest).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(newest, &bytes).unwrap();

    let out = train(&crash_dir, &["--checkpoint-every", "1", "--resume"], None);
    assert!(out.status.success(), "resume: {}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("falling back to the previous generation"),
        "expected a corrupt-generation warning: {stderr}"
    );
    assert_eq!(final_params(&crash_dir), final_params(&oracle_dir));
    assert_eq!(loss_prefix(&crash_dir), loss_prefix(&oracle_dir));

    std::fs::remove_dir_all(&oracle_dir).ok();
    std::fs::remove_dir_all(&crash_dir).ok();
}

/// Resuming under different run inputs (a different seed here) is
/// rejected up front via the stored fingerprint — never a silent
/// divergence.
#[test]
fn resume_under_different_inputs_is_rejected() {
    let dir = setup("fpr");
    let out = train(
        &dir,
        &["--checkpoint-every", "1"],
        Some(common::fault_env("train_step", 4)),
    );
    assert!(!out.status.success());

    let mut cmd = common::texpand_cmd(&dir);
    cmd.args([
        "train",
        "--backend",
        "native",
        "--schedule",
        common::TINY_SCHEDULE,
        "--steps-scale",
        SCALE,
        "--seed",
        "12", // != 11
        "--runs",
        "runs",
        "--run-name",
        "run",
        "--checkpoint-every",
        "1",
        "--resume",
    ]);
    let out = cmd.output().expect("spawn texpand");
    assert!(!out.status.success(), "resume under a different seed must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("resume rejected"), "{stderr}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite 4 (in-process): a boundary checkpoint captures the
/// post-surgery optimizer in canonical order — restored Adam moments
/// validate against the restored params, and the *next* expansion plan
/// applies cleanly on top of the restored pair.
#[test]
fn boundary_checkpoint_restores_optimizer_across_expansion() {
    use texpand::autodiff::NativeBackend;
    use texpand::ckpt::{Chain, RunCheckpoint};
    use texpand::config::TrainConfig;
    use texpand::coordinator::{Coordinator, CoordinatorOptions};
    use texpand::expand::{ExpandOptions, ExpansionPlan};

    let root = setup("boundary");
    let tcfg = TrainConfig { log_every: 1000, ..Default::default() };
    let opts = CoordinatorOptions {
        steps_scale: 0.1, // 3 steps per stage
        save_checkpoints: false,
        corpus_len: 50_000,
        // huge interval: only the forced boundary writes fire
        checkpoint_every: 100_000,
        ..Default::default()
    };
    let schedule = common::tiny_schedule();
    let mut coord = Coordinator::new(
        schedule.clone(),
        common::tiny_manifest(),
        Box::new(NativeBackend::new()),
        tcfg.clone(),
        opts,
    )
    .unwrap();
    let root_str = root.to_str().unwrap();
    coord.run(root_str, "run").unwrap();

    let chain = Chain::open(&root.join("run/ckpt"), 3).unwrap();
    let gens = chain.generations().unwrap();
    assert_eq!(gens.len(), 2, "one forced checkpoint per expansion boundary");

    // first boundary: the run has just grown into stage1
    let first = chain.path_of(gens[0]);
    let ck = RunCheckpoint::load(first.to_str().unwrap()).unwrap();
    assert_eq!(ck.segment, 1);
    assert_eq!(ck.local_step, 0, "boundary checkpoints restart the segment");
    assert_eq!(ck.opt_kind, "adam");
    assert!(ck.last_plan.is_some(), "boundary checkpoint records the applied plan");
    assert_eq!(ck.params.config(), &schedule.stages[1].config);

    // the restored moment stores line up with the restored params...
    let mut params = ck.params.clone();
    let mut opt = ck.to_optimizer(&tcfg).unwrap();
    opt.validate_against(&params).unwrap();

    // ...and survive the *next* scheduled surgery on top of them
    let plan = ExpansionPlan::new(params.config(), schedule.stages[2].apply.clone()).unwrap();
    let mut rng = texpand::rng::Pcg32::seeded(99);
    plan.apply_train(&mut params, &mut opt, &ExpandOptions::default(), &mut rng).unwrap();
    opt.validate_against(&params).unwrap();
    assert_eq!(params.config(), &schedule.stages[2].config);

    std::fs::remove_dir_all(&root).ok();
}

/// Satellite 3's I/O half: a logger over failing writers surfaces the
/// injected error through `take_write_error` and counts dropped lines —
/// it never panics or aborts the run.
#[test]
fn injected_write_failures_surface_through_the_run_logger() {
    use texpand::growth::{Decision, TrainObs};
    use texpand::metrics::RunLogger;

    let mut log = RunLogger::with_writers(
        Box::new(common::FailingWriter::after(0)),
        Box::new(common::FailingWriter::after(0)),
    );
    log.event("a", vec![]);
    assert_eq!(log.dropped_lines(), 1);

    let obs = TrainObs {
        global_step: 1,
        arch_step: 1,
        train_loss: 2.0,
        eval_loss: None,
        tokens_seen: 64,
        est_flops: 0.0,
        params: 10,
    };
    log.decision("fixed", &obs, &Decision::Continue);
    let err = log.take_write_error().expect("failing writer must surface an error");
    assert!(err.to_string().contains("injected"), "{err}");
    assert!(log.dropped_lines() >= 2);
}

/// `texpand serve --checkpoint <run>/ckpt` warm-starts the engine from
/// the newest valid generation's trained weights.
#[test]
fn serve_warm_starts_from_run_checkpoint() {
    let dir = setup("serve");
    let out = train(&dir, &["--checkpoint-every", "4"], None);
    assert!(out.status.success(), "train: {}", String::from_utf8_lossy(&out.stderr));

    let mut cmd = common::texpand_cmd(&dir);
    cmd.args([
        "serve",
        "--checkpoint",
        "runs/run/ckpt",
        "--requests",
        "2",
        "--tokens",
        "6",
        "--slots",
        "2",
        "--seed",
        "3",
    ]);
    let out = cmd.output().expect("spawn texpand");
    assert!(out.status.success(), "serve: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("warm-start"), "{stdout}");

    std::fs::remove_dir_all(&dir).ok();
}
