//! Integration: the `texpand` binary end to end (spawned as a subprocess).
//!
//! The train/inspect/generate/info flows run un-ignored through
//! `--backend native` on the tiny schedule — the full offline
//! grow-as-you-train loop through the real CLI. Only the default
//! PJRT-backed flow (which needs `make artifacts`) stays gated.

mod common;

use std::process::Command;

fn texpand(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_texpand"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn texpand")
}

#[test]
fn no_args_prints_usage() {
    let out = texpand(&[]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = texpand(&["frobnicate"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown subcommand"), "{err}");
    assert!(err.contains("USAGE"), "{err}");
}

#[test]
fn unknown_flag_rejected() {
    let out = texpand(&["info", "--bogus-flag", "1"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--bogus-flag"));
}

#[test]
fn unknown_backend_rejected() {
    let out = texpand(&["train", "--backend", "tpu-v9"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("tpu-v9"));
}

#[test]
fn info_prints_manifest_summary() {
    let out = texpand(&["info", "--backend", "native", "--schedule", "configs/growth_tiny.json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage0"), "{text}");
    assert!(text.contains("growth_tiny"), "{text}");
    assert!(text.contains("native"), "{text}");
}

#[test]
fn train_smoke_then_inspect_and_generate() {
    let runs = std::env::temp_dir().join(format!("texpand-cli-{}", std::process::id()));
    let runs = runs.to_str().unwrap();
    let out = texpand(&[
        "train",
        "--backend", "native",
        "--schedule", "configs/growth_tiny.json",
        "--run-name", "cli-smoke",
        "--runs", runs,
        "--steps-scale", "0.2",
        "--log-every", "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("run summary"), "{text}");
    assert!(text.contains("final eval loss"), "{text}");

    let ckpt = format!("{runs}/cli-smoke/stage2.txpd");
    let out = texpand(&["inspect", "--ckpt", &ckpt]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("w_out"), "{text}");
    assert!(text.contains("layer_1"), "{text}"); // stage2 has 2 layers

    let out = texpand(&[
        "generate",
        "--backend", "native",
        "--schedule", "configs/growth_tiny.json",
        "--ckpt", &ckpt,
        "--tokens", "20",
        "--seed", "7",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage2"), "{text}");
    std::fs::remove_dir_all(runs).ok();
}

#[test]
fn verify_native_reports_preserving_boundaries() {
    // `verify` logs under runs/verify in the repo cwd (append-safe); the
    // assertion target is its stdout report
    let out = texpand(&["verify", "--backend", "native", "--schedule", "configs/growth_tiny.json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("preservation verification"), "{text}");
    assert!(text.contains("PASS"), "{text}");
    assert!(!text.contains("FAIL"), "{text}");
}

#[test]
fn inspect_missing_checkpoint_fails_cleanly() {
    let out = texpand(&["inspect", "--ckpt", "/nonexistent.txpd"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error"));
}

#[test]
#[ignore = "PJRT-specific: the default --backend pjrt flow needs real xla bindings + `make artifacts` (stub xla build in-tree); the native flow runs un-ignored in train_smoke_then_inspect_and_generate"]
fn train_smoke_then_inspect_and_generate_pjrt() {
    let runs = std::env::temp_dir().join(format!("texpand-cli-pjrt-{}", std::process::id()));
    let runs = runs.to_str().unwrap();
    let out = texpand(&[
        "train",
        "--run-name", "cli-smoke",
        "--runs", runs,
        "--steps-scale", "0.02",
        "--log-every", "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ckpt = format!("{runs}/cli-smoke/stage3.txpd");
    let out = texpand(&["inspect", "--ckpt", &ckpt]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = texpand(&["generate", "--ckpt", &ckpt, "--tokens", "20", "--seed", "7"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(runs).ok();
}
