//! Integration: the `texpand` binary end to end (spawned as a subprocess).
//!
//! The train/inspect/generate/info flows run un-ignored through
//! `--backend native` on the tiny schedule — the full offline
//! grow-as-you-train loop through the real CLI. Only the default
//! PJRT-backed flow (which needs `make artifacts`) stays gated.

mod common;

use std::process::Command;

fn texpand(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_texpand"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn texpand")
}

#[test]
fn no_args_prints_usage() {
    let out = texpand(&[]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = texpand(&["frobnicate"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown subcommand"), "{err}");
    assert!(err.contains("USAGE"), "{err}");
}

#[test]
fn unknown_flag_rejected() {
    let out = texpand(&["info", "--bogus-flag", "1"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--bogus-flag"));
}

#[test]
fn unknown_backend_rejected() {
    let out = texpand(&["train", "--backend", "tpu-v9"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("tpu-v9"));
}

#[test]
fn info_prints_manifest_summary() {
    let out = texpand(&["info", "--backend", "native", "--schedule", "configs/growth_tiny.json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage0"), "{text}");
    assert!(text.contains("growth_tiny"), "{text}");
    assert!(text.contains("native"), "{text}");
}

#[test]
fn plan_dry_run_prints_exact_final_params() {
    let out = texpand(&["plan", "--schedule", "configs/growth_tiny.json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // the trajectory table names every stage and its ops
    assert!(text.contains("stage0"), "{text}");
    assert!(text.contains("mlp+layers_add"), "{text}");
    assert!(text.contains("attn_expand+hidden"), "{text}");
    // the machine-greppable final line matches the schedule's final config
    // exactly (param predictions are plan postconditions, not estimates)
    let want = texpand::config::GrowthSchedule::load(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/configs/growth_tiny.json"
    ))
    .unwrap()
    .final_config()
    .num_params();
    assert!(text.contains(&format!("final params: {want}")), "{text}");
}

#[test]
fn plan_json_emits_roundtrippable_ops() {
    let out = texpand(&["plan", "--schedule", "configs/growth_tiny.json", "--json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // --json mode's stdout is exactly one valid JSON document
    let doc = texpand::json::Value::parse(text.trim()).unwrap();
    let want = texpand::config::GrowthSchedule::load(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/configs/growth_tiny.json"
    ))
    .unwrap()
    .final_config()
    .num_params();
    assert_eq!(doc.req("final_params").unwrap().as_i64().unwrap() as usize, want);
    let plans = doc.req("plans").unwrap().as_arr().unwrap();
    assert_eq!(plans.len(), 2, "two boundaries in the tiny schedule");
    for p in plans {
        for op in p.req("ops").unwrap().as_arr().unwrap() {
            // every emitted op must parse back through the schedule parser
            texpand::config::GrowthOp::from_json(op).unwrap();
        }
        assert!(p.req("param_delta").unwrap().as_i64().unwrap() > 0);
    }
}

#[test]
fn train_smoke_then_inspect_and_generate() {
    let runs = std::env::temp_dir().join(format!("texpand-cli-{}", std::process::id()));
    let runs = runs.to_str().unwrap();
    let out = texpand(&[
        "train",
        "--backend", "native",
        "--schedule", "configs/growth_tiny.json",
        "--run-name", "cli-smoke",
        "--runs", runs,
        "--steps-scale", "0.2",
        "--log-every", "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("run summary"), "{text}");
    assert!(text.contains("final eval loss"), "{text}");

    let ckpt = format!("{runs}/cli-smoke/stage2.txpd");
    let out = texpand(&["inspect", "--ckpt", &ckpt]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("w_out"), "{text}");
    assert!(text.contains("layer_1"), "{text}"); // stage2 has 2 layers

    let out = texpand(&[
        "generate",
        "--backend", "native",
        "--schedule", "configs/growth_tiny.json",
        "--ckpt", &ckpt,
        "--tokens", "20",
        "--seed", "7",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage2"), "{text}");
    std::fs::remove_dir_all(runs).ok();
}

#[test]
fn verify_native_reports_preserving_boundaries() {
    // `verify` logs under runs/verify in the repo cwd (append-safe); the
    // assertion target is its stdout report
    let out = texpand(&["verify", "--backend", "native", "--schedule", "configs/growth_tiny.json"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("preservation verification"), "{text}");
    assert!(text.contains("PASS"), "{text}");
    assert!(!text.contains("FAIL"), "{text}");
}

#[test]
fn pjrt_backend_rejects_adaptive_policy_with_clear_error() {
    // must fail up front with guidance, NOT with a missing-artifacts error
    let out = texpand(&["train", "--backend", "pjrt", "--policy", "plateau"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--backend native"), "{err}");
    assert!(!err.contains("manifest.json"), "policy check must precede artifact resolution: {err}");
}

#[test]
fn unknown_policy_value_rejected() {
    let out = texpand(&["train", "--backend", "native", "--policy", "bandit"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fixed|plateau|greedy"), "{err}");
}

#[test]
fn policy_flag_rejected_on_non_train_subcommands() {
    // verify proves fixed-schedule boundaries; an adaptive-policy flag
    // there would be silently meaningless, so it must be an unknown flag
    let out = texpand(&["verify", "--backend", "native", "--schedule", "configs/growth_tiny.json", "--policy", "plateau"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--policy"));
}

#[test]
fn train_plateau_policy_logs_decisions() {
    let runs = std::env::temp_dir().join(format!("texpand-cli-policy-{}", std::process::id()));
    let runs = runs.to_str().unwrap();
    let out = texpand(&[
        "train",
        "--backend", "native",
        "--schedule", "configs/growth_tiny.json",
        "--policy", "plateau",
        "--run-name", "cli-plateau",
        "--runs", runs,
        "--steps-scale", "0.4",
        "--no-checkpoints",
        "--log-every", "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("policy plateau"), "{text}");
    let events = std::fs::read_to_string(format!("{runs}/cli-plateau/events.jsonl")).unwrap();
    assert!(events.contains(r#""event":"decision""#), "no decision rows logged");
    std::fs::remove_dir_all(runs).ok();
}

#[test]
fn inspect_missing_checkpoint_fails_cleanly() {
    let out = texpand(&["inspect", "--ckpt", "/nonexistent.txpd"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error"));
}

#[test]
#[ignore = "PJRT-specific: the default --backend pjrt flow needs real xla bindings + `make artifacts` (stub xla build in-tree); the native flow runs un-ignored in train_smoke_then_inspect_and_generate"]
fn train_smoke_then_inspect_and_generate_pjrt() {
    let runs = std::env::temp_dir().join(format!("texpand-cli-pjrt-{}", std::process::id()));
    let runs = runs.to_str().unwrap();
    let out = texpand(&[
        "train",
        "--run-name", "cli-smoke",
        "--runs", runs,
        "--steps-scale", "0.02",
        "--log-every", "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let ckpt = format!("{runs}/cli-smoke/stage3.txpd");
    let out = texpand(&["inspect", "--ckpt", &ckpt]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let out = texpand(&["generate", "--ckpt", &ckpt, "--tokens", "20", "--seed", "7"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(runs).ok();
}
