//! Integration: the `texpand` binary end to end (spawned as a subprocess).

mod common;

use std::process::Command;

fn texpand(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_texpand"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn texpand")
}

#[test]
fn no_args_prints_usage() {
    let out = texpand(&[]);
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE"));
}

#[test]
fn unknown_subcommand_fails_with_usage() {
    let out = texpand(&["frobnicate"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown subcommand"), "{err}");
    assert!(err.contains("USAGE"), "{err}");
}

#[test]
fn unknown_flag_rejected() {
    let out = texpand(&["info", "--bogus-flag", "1"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--bogus-flag"));
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn info_prints_manifest_summary() {
    let out = texpand(&["info"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage0"), "{text}");
    assert!(text.contains("schedule"), "{text}");
}

#[test]
#[ignore = "needs AOT artifacts + real PJRT bindings, absent from this repo (stub xla build); run `make artifacts` with the real bindings to enable — tracked in ROADMAP.md"]
fn train_smoke_then_inspect_and_generate() {
    let runs = std::env::temp_dir().join(format!("texpand-cli-{}", std::process::id()));
    let runs = runs.to_str().unwrap();
    let out = texpand(&[
        "train",
        "--run-name", "cli-smoke",
        "--runs", runs,
        "--steps-scale", "0.02",
        "--log-every", "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("run summary"), "{text}");
    assert!(text.contains("final eval loss"), "{text}");

    let ckpt = format!("{runs}/cli-smoke/stage3.txpd");
    let out = texpand(&["inspect", "--ckpt", &ckpt]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("w_out"), "{text}");
    assert!(text.contains("401536") || text.contains("401,536"), "{text}");

    let out = texpand(&["generate", "--ckpt", &ckpt, "--tokens", "20", "--seed", "7"]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stage3"), "{text}");
    std::fs::remove_dir_all(runs).ok();
}

#[test]
fn inspect_missing_checkpoint_fails_cleanly() {
    let out = texpand(&["inspect", "--ckpt", "/nonexistent.txpd"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error"));
}
