//! Architecture configs, growth schedules and training configuration (S3).
//!
//! This module mirrors `python/compile/configs.py` — the two sides share
//! the growth-schedule JSON files in `configs/` and the canonical parameter
//! order, and the Rust side re-validates the AOT manifest against its own
//! `param_specs` at load time (see [`crate::runtime`]).

use crate::error::{Error, Result};
use crate::json::Value;

/// Hyper-parameters of one architecture stage (paper Section 2 notation:
/// `layers`=N, `hidden`=h, `heads`=E, `k`, `v`, `mlp`=p, `seq`=s, `vocab`=o).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModelConfig {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub k: usize,
    pub v: usize,
    pub mlp: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl ModelConfig {
    /// Validate positivity of every dimension.
    pub fn validate(&self) -> Result<()> {
        let fields = [
            ("layers", self.layers),
            ("hidden", self.hidden),
            ("heads", self.heads),
            ("k", self.k),
            ("v", self.v),
            ("mlp", self.mlp),
            ("seq", self.seq),
            ("vocab", self.vocab),
        ];
        for (name, val) in fields {
            if val == 0 {
                return Err(Error::Config(format!("ModelConfig.{name} must be positive")));
            }
        }
        Ok(())
    }

    /// Parse from a JSON object with exactly the Python field names.
    pub fn from_json(v: &Value) -> Result<ModelConfig> {
        let f = |k: &str| -> Result<usize> { v.req(k)?.as_usize() };
        let cfg = ModelConfig {
            layers: f("layers")?,
            hidden: f("hidden")?,
            heads: f("heads")?,
            k: f("k")?,
            v: f("v")?,
            mlp: f("mlp")?,
            seq: f("seq")?,
            vocab: f("vocab")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to a JSON object (field order matches Python's asdict).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("layers", Value::num(self.layers as f64)),
            ("hidden", Value::num(self.hidden as f64)),
            ("heads", Value::num(self.heads as f64)),
            ("k", Value::num(self.k as f64)),
            ("v", Value::num(self.v as f64)),
            ("mlp", Value::num(self.mlp as f64)),
            ("seq", Value::num(self.seq as f64)),
            ("vocab", Value::num(self.vocab as f64)),
        ])
    }

    /// Total scalar parameter count (must agree with the Python formula).
    pub fn num_params(&self) -> usize {
        let per_layer = self.hidden
            + self.heads * self.hidden * (2 * self.k + self.v)
            + self.heads * self.v * self.hidden
            + self.hidden
            + self.hidden * self.mlp
            + self.mlp
            + self.mlp * self.hidden
            + self.hidden;
        self.vocab * self.hidden + self.seq * self.hidden + self.layers * per_layer + self.hidden * self.vocab
    }
}

/// One named parameter in the canonical order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Canonical `(name, shape)` parameter order — must match
/// `python/compile/configs.py::param_specs` exactly (DESIGN.md §7).
pub fn param_specs(cfg: &ModelConfig) -> Vec<ParamSpec> {
    let mut specs = Vec::with_capacity(3 + cfg.layers * (3 * cfg.heads + 7));
    let mut push = |name: String, shape: Vec<usize>| specs.push(ParamSpec { name, shape });
    push("embed".into(), vec![cfg.vocab, cfg.hidden]);
    push("pos".into(), vec![cfg.seq, cfg.hidden]);
    for n in 0..cfg.layers {
        push(format!("layer_{n}.g_mha"), vec![cfg.hidden]);
        for e in 0..cfg.heads {
            push(format!("layer_{n}.head_{e}.wq"), vec![cfg.hidden, cfg.k]);
            push(format!("layer_{n}.head_{e}.wk"), vec![cfg.hidden, cfg.k]);
            push(format!("layer_{n}.head_{e}.wv"), vec![cfg.hidden, cfg.v]);
        }
        push(format!("layer_{n}.wo"), vec![cfg.heads * cfg.v, cfg.hidden]);
        push(format!("layer_{n}.g_mlp"), vec![cfg.hidden]);
        push(format!("layer_{n}.w1"), vec![cfg.hidden, cfg.mlp]);
        push(format!("layer_{n}.b1"), vec![cfg.mlp]);
        push(format!("layer_{n}.w2"), vec![cfg.mlp, cfg.hidden]);
        push(format!("layer_{n}.b2"), vec![cfg.hidden]);
    }
    push("w_out".into(), vec![cfg.hidden, cfg.vocab]);
    specs
}

// ---------------------------------------------------------------------------
// Growth ops
// ---------------------------------------------------------------------------

/// Where to insert new layers (Def. 3.6 allows any position in `[0, N]`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerPosition {
    Top,
    Bottom,
    At(usize),
}

/// One growth-schedule transformation op — the shared vocabulary with
/// `python/compile/configs.py` (`OP_KINDS`) and `python/compile/transforms.py`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GrowthOp {
    /// Def. 3.1 — grow MLP internal width to `p`.
    Mlp { p: usize },
    /// Def. 3.2 — add `count` attention heads.
    HeadsAdd { count: usize },
    /// Def. 3.3 — grow per-head value width to `v`.
    HeadsExpand { v: usize },
    /// Def. 3.4 — grow key/query width to `k`.
    AttnExpand { k: usize },
    /// Def. 3.5 — grow hidden width to `h`.
    Hidden { h: usize },
    /// Def. 3.6 — insert `count` layers at `position`.
    LayersAdd { count: usize, position: LayerPosition },
}

impl GrowthOp {
    /// Parse from the schedule JSON object form.
    pub fn from_json(v: &Value) -> Result<GrowthOp> {
        let kind = v.req("op")?.as_str()?;
        match kind {
            "mlp" => Ok(GrowthOp::Mlp { p: v.req("p")?.as_usize()? }),
            "heads_add" => Ok(GrowthOp::HeadsAdd {
                count: v.get("count").map(|c| c.as_usize()).transpose()?.unwrap_or(1),
            }),
            "heads_expand" => Ok(GrowthOp::HeadsExpand { v: v.req("v")?.as_usize()? }),
            "attn_expand" => Ok(GrowthOp::AttnExpand { k: v.req("k")?.as_usize()? }),
            "hidden" => Ok(GrowthOp::Hidden { h: v.req("h")?.as_usize()? }),
            "layers_add" => {
                let count = v.get("count").map(|c| c.as_usize()).transpose()?.unwrap_or(1);
                let position = match v.get("position") {
                    None => LayerPosition::Top,
                    Some(Value::Str(s)) if s == "top" => LayerPosition::Top,
                    Some(Value::Str(s)) if s == "bottom" => LayerPosition::Bottom,
                    Some(Value::Num(_)) => LayerPosition::At(v.get("position").unwrap().as_usize()?),
                    Some(other) => {
                        return Err(Error::Config(format!("bad layers_add position: {other:?}")))
                    }
                };
                Ok(GrowthOp::LayersAdd { count, position })
            }
            other => Err(Error::Config(format!("unknown transformation op kind: {other:?}"))),
        }
    }

    /// Apply the op at the *dimension* level (the surgery lives in
    /// [`crate::expand`]); validates strict growth like the Python side.
    pub fn apply_to_config(&self, cfg: &ModelConfig) -> Result<ModelConfig> {
        let mut out = *cfg;
        match *self {
            GrowthOp::Mlp { p } => {
                if p <= cfg.mlp {
                    return Err(Error::Config(format!("mlp expansion must grow p: {} -> {p}", cfg.mlp)));
                }
                out.mlp = p;
            }
            GrowthOp::HeadsAdd { count } => {
                if count < 1 {
                    return Err(Error::Config("heads_add count must be >= 1".into()));
                }
                out.heads = cfg.heads + count;
            }
            GrowthOp::HeadsExpand { v } => {
                if v <= cfg.v {
                    return Err(Error::Config(format!("heads expansion must grow v: {} -> {v}", cfg.v)));
                }
                out.v = v;
            }
            GrowthOp::AttnExpand { k } => {
                if k <= cfg.k {
                    return Err(Error::Config(format!("attention expansion must grow k: {} -> {k}", cfg.k)));
                }
                out.k = k;
            }
            GrowthOp::Hidden { h } => {
                if h <= cfg.hidden {
                    return Err(Error::Config(format!("hidden expansion must grow h: {} -> {h}", cfg.hidden)));
                }
                out.hidden = h;
            }
            GrowthOp::LayersAdd { count, position } => {
                if count < 1 {
                    return Err(Error::Config("layers_add count must be >= 1".into()));
                }
                if let LayerPosition::At(p) = position {
                    if p > cfg.layers {
                        return Err(Error::Config(format!(
                            "layers_add position {p} out of range [0, {}]",
                            cfg.layers
                        )));
                    }
                }
                out.layers = cfg.layers + count;
            }
        }
        Ok(out)
    }

    /// Serialize to the schedule JSON object form — the exact inverse of
    /// [`GrowthOp::from_json`], so plans and policy decision logs can emit
    /// schedules that parse back losslessly (`texpand plan --json`).
    pub fn to_json(&self) -> Value {
        match *self {
            GrowthOp::Mlp { p } => Value::obj(vec![
                ("op", Value::str("mlp")),
                ("p", Value::num(p as f64)),
            ]),
            GrowthOp::HeadsAdd { count } => Value::obj(vec![
                ("op", Value::str("heads_add")),
                ("count", Value::num(count as f64)),
            ]),
            GrowthOp::HeadsExpand { v } => Value::obj(vec![
                ("op", Value::str("heads_expand")),
                ("v", Value::num(v as f64)),
            ]),
            GrowthOp::AttnExpand { k } => Value::obj(vec![
                ("op", Value::str("attn_expand")),
                ("k", Value::num(k as f64)),
            ]),
            GrowthOp::Hidden { h } => Value::obj(vec![
                ("op", Value::str("hidden")),
                ("h", Value::num(h as f64)),
            ]),
            GrowthOp::LayersAdd { count, position } => {
                let pos = match position {
                    LayerPosition::Top => Value::str("top"),
                    LayerPosition::Bottom => Value::str("bottom"),
                    LayerPosition::At(p) => Value::num(p as f64),
                };
                Value::obj(vec![
                    ("op", Value::str("layers_add")),
                    ("count", Value::num(count as f64)),
                    ("position", pos),
                ])
            }
        }
    }

    /// Human-readable op name (metrics, logs, bench rows).
    pub fn kind(&self) -> &'static str {
        match self {
            GrowthOp::Mlp { .. } => "mlp",
            GrowthOp::HeadsAdd { .. } => "heads_add",
            GrowthOp::HeadsExpand { .. } => "heads_expand",
            GrowthOp::AttnExpand { .. } => "attn_expand",
            GrowthOp::Hidden { .. } => "hidden",
            GrowthOp::LayersAdd { .. } => "layers_add",
        }
    }
}

// ---------------------------------------------------------------------------
// Growth policy configuration
// ---------------------------------------------------------------------------

/// Which growth policy drives the run (see [`crate::growth`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// Replay the schedule's stage table verbatim (the default; exactly the
    /// pre-policy coordinator behaviour).
    Fixed,
    /// Fire the next staged expansion when the eval loss plateaus.
    Plateau,
    /// Branch-probe candidate expansions and commit the best loss-per-FLOP.
    Greedy,
}

impl PolicyKind {
    pub fn parse(name: &str) -> Result<PolicyKind> {
        match name {
            "fixed" => Ok(PolicyKind::Fixed),
            "plateau" => Ok(PolicyKind::Plateau),
            "greedy" => Ok(PolicyKind::Greedy),
            other => Err(Error::Cli(format!("unknown policy '{other}' (fixed|plateau|greedy)"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fixed => "fixed",
            PolicyKind::Plateau => "plateau",
            PolicyKind::Greedy => "greedy",
        }
    }
}

/// Knobs for the adaptive growth policies, parsed from the schedule JSON's
/// optional `policy` block. All fields have defaults, so `{"policy": {}}`
/// and an absent block are equivalent; the CLI `--policy` flag overrides
/// only `kind`.
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    pub kind: PolicyKind,
    /// Steps between eval-loss probes feeding the plateau detector.
    pub eval_every: usize,
    /// Number of consecutive evals the plateau slope is measured over.
    pub window: usize,
    /// Minimum mean per-eval loss improvement; below it the loss counts as
    /// plateaued.
    pub min_slope: f32,
    /// Steps after entering an architecture during which no expansion may
    /// fire (lets the optimizer re-equilibrate before judging progress).
    pub cooldown: usize,
    /// Fire the pending expansion no later than `deadline_scale` × the
    /// current stage's scheduled steps even without a detected plateau
    /// (`0` disables the deadline).
    pub deadline_scale: f64,
    /// Probe-training steps per candidate branch (greedy policy).
    pub probe_budget: usize,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            kind: PolicyKind::Fixed,
            eval_every: 5,
            window: 4,
            min_slope: 0.01,
            cooldown: 10,
            deadline_scale: 2.0,
            probe_budget: 8,
        }
    }
}

impl PolicyConfig {
    /// Parse from the schedule JSON's `policy` value (`None` = defaults).
    pub fn from_json(v: Option<&Value>) -> Result<PolicyConfig> {
        let mut cfg = PolicyConfig::default();
        let Some(v) = v else { return Ok(cfg) };
        if let Some(kind) = v.get("kind") {
            cfg.kind = PolicyKind::parse(kind.as_str()?)?;
        }
        if let Some(n) = v.get("eval_every") {
            cfg.eval_every = n.as_usize()?;
        }
        if let Some(n) = v.get("window") {
            cfg.window = n.as_usize()?;
        }
        if let Some(n) = v.get("min_slope") {
            cfg.min_slope = n.as_f64()? as f32;
        }
        if let Some(n) = v.get("cooldown") {
            cfg.cooldown = n.as_usize()?;
        }
        if let Some(n) = v.get("deadline_scale") {
            cfg.deadline_scale = n.as_f64()?;
        }
        if let Some(n) = v.get("probe_budget") {
            cfg.probe_budget = n.as_usize()?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.eval_every == 0 {
            return Err(Error::Config("policy.eval_every must be >= 1".into()));
        }
        if self.window < 2 {
            return Err(Error::Config("policy.window must be >= 2 (slope needs two points)".into()));
        }
        if !self.min_slope.is_finite() || self.min_slope < 0.0 {
            return Err(Error::Config("policy.min_slope must be finite and >= 0".into()));
        }
        if !self.deadline_scale.is_finite() || self.deadline_scale < 0.0 {
            return Err(Error::Config("policy.deadline_scale must be finite and >= 0".into()));
        }
        if self.probe_budget == 0 {
            return Err(Error::Config("policy.probe_budget must be >= 1".into()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Growth schedule
// ---------------------------------------------------------------------------

/// One stage: train `steps` under `config`; `apply` ran at stage entry.
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    pub config: ModelConfig,
    pub steps: usize,
    pub apply: Vec<GrowthOp>,
}

/// A full growth schedule (mirrors `GrowthSchedule.from_dict` in Python).
#[derive(Clone, Debug)]
pub struct GrowthSchedule {
    pub name: String,
    pub batch: usize,
    /// Optional gradient-accumulation chunk size for the native backend:
    /// a step still consumes `batch` rows, but only `micro_batch` of them
    /// are resident (tape + per-row grad store) at a time, so the
    /// effective batch can exceed memory. `None` = whole batch at once.
    /// CLI `--micro-batch` overrides.
    pub micro_batch: Option<usize>,
    /// Growth-policy selection + knobs (`policy` block; defaults = fixed).
    pub policy: PolicyConfig,
    pub stages: Vec<Stage>,
}

impl GrowthSchedule {
    /// Parse from the schedule JSON document.
    pub fn from_json(v: &Value) -> Result<GrowthSchedule> {
        let seq = v.req("seq")?.as_usize()?;
        let vocab = v.req("vocab")?.as_usize()?;
        let base_obj = v.req("base")?;
        let mut cfg = ModelConfig {
            layers: base_obj.req("layers")?.as_usize()?,
            hidden: base_obj.req("hidden")?.as_usize()?,
            heads: base_obj.req("heads")?.as_usize()?,
            k: base_obj.req("k")?.as_usize()?,
            v: base_obj.req("v")?.as_usize()?,
            mlp: base_obj.req("mlp")?.as_usize()?,
            seq,
            vocab,
        };
        cfg.validate()?;
        let stages_json = v.req("stages")?.as_arr()?;
        if stages_json.is_empty() {
            return Err(Error::Config("schedule must have at least one stage".into()));
        }
        let mut stages = Vec::new();
        for (i, sj) in stages_json.iter().enumerate() {
            let ops: Vec<GrowthOp> = match sj.get("apply") {
                None => vec![],
                Some(a) => a.as_arr()?.iter().map(GrowthOp::from_json).collect::<Result<_>>()?,
            };
            if i == 0 && !ops.is_empty() {
                return Err(Error::Config("stage 0 cannot have `apply` ops".into()));
            }
            for op in &ops {
                cfg = op.apply_to_config(&cfg)?;
            }
            stages.push(Stage {
                name: format!("stage{i}"),
                config: cfg,
                steps: sj.req("steps")?.as_usize()?,
                apply: ops,
            });
        }
        let micro_batch = v.get("micro_batch").map(|m| m.as_usize()).transpose()?;
        if micro_batch == Some(0) {
            return Err(Error::Config("micro_batch must be >= 1".into()));
        }
        Ok(GrowthSchedule {
            name: v.get("name").map(|n| n.as_str().map(String::from)).transpose()?.unwrap_or_else(|| "unnamed".into()),
            batch: v.get("batch").map(|b| b.as_usize()).transpose()?.unwrap_or(8),
            micro_batch,
            policy: PolicyConfig::from_json(v.get("policy"))?,
            stages,
        })
    }

    /// Load a schedule from a JSON file.
    pub fn load(path: &str) -> Result<GrowthSchedule> {
        GrowthSchedule::from_json(&Value::load(path)?)
    }

    /// Total scheduled training steps across all stages.
    pub fn total_steps(&self) -> usize {
        self.stages.iter().map(|s| s.steps).sum()
    }

    /// The final (largest) stage config.
    pub fn final_config(&self) -> &ModelConfig {
        &self.stages.last().expect("validated non-empty").config
    }
}

// ---------------------------------------------------------------------------
// Training config
// ---------------------------------------------------------------------------

/// Optimizer selection for the trainer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Adam,
}

/// Training hyper-parameters (CLI-overridable; defaults suit the synthetic
/// corpus at the shipped schedule's scale).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub optimizer: OptimKind,
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub grad_clip: Option<f32>,
    pub seed: u64,
    pub log_every: usize,
    /// Probe-batch preservation tolerance at expansion boundaries.
    pub preserve_tol: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            optimizer: OptimKind::Adam,
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            grad_clip: Some(1.0),
            seed: 0,
            log_every: 10,
            preserve_tol: 1e-4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
    }

    #[test]
    fn validate_rejects_zero_dims() {
        let mut c = cfg();
        c.heads = 0;
        assert!(c.validate().is_err());
        assert!(cfg().validate().is_ok());
    }

    #[test]
    fn json_roundtrip() {
        let c = cfg();
        assert_eq!(ModelConfig::from_json(&c.to_json()).unwrap(), c);
    }

    #[test]
    fn from_json_requires_all_fields() {
        let v = Value::parse(r#"{"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":8,"seq":8}"#).unwrap();
        assert!(ModelConfig::from_json(&v).is_err()); // missing vocab
    }

    #[test]
    fn param_specs_match_python_layout() {
        let specs = param_specs(&cfg());
        assert_eq!(specs.len(), 2 + 2 * (3 * 2 + 7) + 1);
        assert_eq!(specs[0].name, "embed");
        assert_eq!(specs[0].shape, vec![32, 16]);
        assert_eq!(specs[1].name, "pos");
        assert_eq!(specs[2].name, "layer_0.g_mha");
        assert_eq!(specs[3].name, "layer_0.head_0.wq");
        assert_eq!(specs[3].shape, vec![16, 8]);
        assert_eq!(specs.last().unwrap().name, "w_out");
        assert_eq!(specs.last().unwrap().shape, vec![16, 32]);
    }

    #[test]
    fn num_params_matches_specs_sum() {
        let total: usize = param_specs(&cfg()).iter().map(|s| s.shape.iter().product::<usize>()).sum();
        assert_eq!(cfg().num_params(), total);
    }

    #[test]
    fn ops_parse_and_apply() {
        let cases = [
            (r#"{"op":"mlp","p":64}"#, GrowthOp::Mlp { p: 64 }),
            (r#"{"op":"heads_add"}"#, GrowthOp::HeadsAdd { count: 1 }),
            (r#"{"op":"heads_add","count":3}"#, GrowthOp::HeadsAdd { count: 3 }),
            (r#"{"op":"heads_expand","v":16}"#, GrowthOp::HeadsExpand { v: 16 }),
            (r#"{"op":"attn_expand","k":16}"#, GrowthOp::AttnExpand { k: 16 }),
            (r#"{"op":"hidden","h":32}"#, GrowthOp::Hidden { h: 32 }),
            (
                r#"{"op":"layers_add","count":2,"position":"bottom"}"#,
                GrowthOp::LayersAdd { count: 2, position: LayerPosition::Bottom },
            ),
            (
                r#"{"op":"layers_add","position":1}"#,
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(1) },
            ),
        ];
        for (text, want) in cases {
            let got = GrowthOp::from_json(&Value::parse(text).unwrap()).unwrap();
            assert_eq!(got, want, "{text}");
            assert!(got.apply_to_config(&cfg()).is_ok(), "{text}");
        }
    }

    #[test]
    fn op_json_roundtrips_all_six_kinds() {
        // to_json must be the exact inverse of from_json over every op
        // kind and every layers_add position form
        let ops = [
            GrowthOp::Mlp { p: 64 },
            GrowthOp::HeadsAdd { count: 3 },
            GrowthOp::HeadsExpand { v: 16 },
            GrowthOp::AttnExpand { k: 16 },
            GrowthOp::Hidden { h: 32 },
            GrowthOp::LayersAdd { count: 2, position: LayerPosition::Top },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Bottom },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(1) },
        ];
        for op in ops {
            let round = GrowthOp::from_json(&op.to_json()).unwrap();
            assert_eq!(round, op, "{op:?} did not round-trip");
            // and through a serialize -> parse cycle (text form)
            let reparsed =
                GrowthOp::from_json(&Value::parse(&op.to_json().to_string()).unwrap()).unwrap();
            assert_eq!(reparsed, op, "{op:?} did not survive text round-trip");
        }
    }

    #[test]
    fn op_application_changes_only_target_dim() {
        let base = cfg();
        let out = GrowthOp::Hidden { h: 32 }.apply_to_config(&base).unwrap();
        assert_eq!(out.hidden, 32);
        assert_eq!(
            (out.layers, out.heads, out.k, out.v, out.mlp, out.seq, out.vocab),
            (base.layers, base.heads, base.k, base.v, base.mlp, base.seq, base.vocab)
        );
    }

    #[test]
    fn non_growth_ops_rejected() {
        for op in [
            GrowthOp::Mlp { p: 32 },
            GrowthOp::HeadsExpand { v: 8 },
            GrowthOp::AttnExpand { k: 4 },
            GrowthOp::Hidden { h: 16 },
            GrowthOp::HeadsAdd { count: 0 },
            GrowthOp::LayersAdd { count: 0, position: LayerPosition::Top },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(3) },
        ] {
            assert!(op.apply_to_config(&cfg()).is_err(), "{op:?}");
        }
    }

    #[test]
    fn unknown_op_kind_rejected() {
        let v = Value::parse(r#"{"op":"shrink","h":4}"#).unwrap();
        assert!(GrowthOp::from_json(&v).is_err());
    }

    fn sched_json() -> String {
        r#"{
            "name": "t", "batch": 4, "seq": 16, "vocab": 32,
            "base": {"layers":1,"hidden":16,"heads":2,"k":8,"v":8,"mlp":32},
            "stages": [
                {"steps": 10},
                {"steps": 20, "apply": [{"op":"mlp","p":64},{"op":"heads_add","count":1}]}
            ]
        }"#
        .to_string()
    }

    #[test]
    fn schedule_micro_batch_parses_and_validates() {
        // absent -> None
        let s = GrowthSchedule::from_json(&Value::parse(&sched_json()).unwrap()).unwrap();
        assert_eq!(s.micro_batch, None);
        // present -> Some
        let text = sched_json().replace(r#""batch": 4,"#, r#""batch": 4, "micro_batch": 2,"#);
        let s = GrowthSchedule::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(s.micro_batch, Some(2));
        // zero -> rejected
        let text = sched_json().replace(r#""batch": 4,"#, r#""batch": 4, "micro_batch": 0,"#);
        let err = GrowthSchedule::from_json(&Value::parse(&text).unwrap()).unwrap_err().to_string();
        assert!(err.contains("micro_batch"), "{err}");
    }

    #[test]
    fn schedule_parses_and_accumulates() {
        let s = GrowthSchedule::from_json(&Value::parse(&sched_json()).unwrap()).unwrap();
        assert_eq!(s.name, "t");
        assert_eq!(s.batch, 4);
        assert_eq!(s.stages.len(), 2);
        assert_eq!(s.stages[0].name, "stage0");
        assert_eq!(s.stages[0].config.mlp, 32);
        assert_eq!(s.stages[1].config.mlp, 64);
        assert_eq!(s.stages[1].config.heads, 3);
        assert_eq!(s.total_steps(), 30);
        assert_eq!(s.final_config().heads, 3);
    }

    #[test]
    fn schedule_rejects_stage0_apply() {
        let text = sched_json().replace(r#"{"steps": 10}"#, r#"{"steps":10,"apply":[{"op":"mlp","p":64}]}"#);
        // stage1's mlp->64 now collides (64 -> 64 not growing), but the
        // stage0 check fires first:
        let err = GrowthSchedule::from_json(&Value::parse(&text).unwrap()).unwrap_err().to_string();
        assert!(err.contains("stage 0"), "{err}");
    }

    #[test]
    fn schedule_rejects_empty_stages() {
        let v = Value::parse(&sched_json().replace(
            r#"[
                {"steps": 10},
                {"steps": 20, "apply": [{"op":"mlp","p":64},{"op":"heads_add","count":1}]}
            ]"#,
            "[]",
        ))
        .unwrap();
        // fallback if replace failed to match formatting: build directly
        let v = if v.req("stages").map(|s| s.as_arr().map(|a| a.is_empty()).unwrap_or(false)).unwrap_or(false) {
            v
        } else {
            let mut obj = v.as_obj().unwrap().to_vec();
            for f in &mut obj {
                if f.0 == "stages" {
                    f.1 = Value::Arr(vec![]);
                }
            }
            Value::Obj(obj)
        };
        assert!(GrowthSchedule::from_json(&v).is_err());
    }

    #[test]
    fn shipped_default_schedule_loads() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/growth_default.json");
        let s = GrowthSchedule::load(path).unwrap();
        assert!(s.stages.len() >= 2);
        let counts: Vec<usize> = s.stages.iter().map(|st| st.config.num_params()).collect();
        let mut sorted = counts.clone();
        sorted.sort_unstable();
        assert_eq!(counts, sorted, "stages must grow monotonically");
    }

    #[test]
    fn policy_block_defaults_and_parses() {
        // absent -> fixed defaults
        let s = GrowthSchedule::from_json(&Value::parse(&sched_json()).unwrap()).unwrap();
        assert_eq!(s.policy.kind, PolicyKind::Fixed);
        assert_eq!(s.policy.window, 4);
        // present -> overrides merge with defaults
        let text = sched_json().replace(
            r#""batch": 4,"#,
            r#""batch": 4, "policy": {"kind": "plateau", "window": 3, "min_slope": 0.05},"#,
        );
        let s = GrowthSchedule::from_json(&Value::parse(&text).unwrap()).unwrap();
        assert_eq!(s.policy.kind, PolicyKind::Plateau);
        assert_eq!(s.policy.window, 3);
        assert!((s.policy.min_slope - 0.05).abs() < 1e-6);
        assert_eq!(s.policy.eval_every, 5); // untouched default
    }

    #[test]
    fn policy_block_rejects_bad_knobs() {
        for bad in [
            r#"{"kind": "shrinky"}"#,
            r#"{"window": 1}"#,
            r#"{"eval_every": 0}"#,
            r#"{"probe_budget": 0}"#,
            r#"{"min_slope": -0.5}"#,
            r#"{"deadline_scale": -1}"#,
        ] {
            let v = Value::parse(bad).unwrap();
            assert!(PolicyConfig::from_json(Some(&v)).is_err(), "{bad}");
        }
        assert_eq!(PolicyConfig::from_json(None).unwrap().kind, PolicyKind::Fixed);
    }

    #[test]
    fn policy_kind_parse_roundtrip() {
        for kind in [PolicyKind::Fixed, PolicyKind::Plateau, PolicyKind::Greedy] {
            assert_eq!(PolicyKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(PolicyKind::parse("bandit").is_err());
    }

    #[test]
    fn train_config_defaults_sane() {
        let t = TrainConfig::default();
        assert!(t.lr > 0.0 && t.beta1 < 1.0 && t.beta2 < 1.0);
        assert_eq!(t.optimizer, OptimKind::Adam);
    }
}
