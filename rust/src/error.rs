//! Crate-wide error type.
//!
//! One enum covers every subsystem so that errors compose across the
//! coordinator's phases (config parsing → artifact loading → PJRT execution
//! → surgery → checkpointing) without boxing at each boundary.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error enum.
#[derive(Error, Debug)]
pub enum Error {
    /// JSON syntax or structural error (path-annotated where possible).
    #[error("json error: {0}")]
    Json(String),

    /// Config / growth-schedule validation failure.
    #[error("config error: {0}")]
    Config(String),

    /// Tensor shape mismatch or invalid operation.
    #[error("shape error: {0}")]
    Shape(String),

    /// Parameter-store inconsistency (missing param, spec mismatch...).
    #[error("param store error: {0}")]
    Params(String),

    /// Checkpoint codec error.
    #[error("checkpoint error: {0}")]
    Checkpoint(String),

    /// Artifact manifest problem (missing stage, spec drift vs config...).
    #[error("manifest error: {0}")]
    Manifest(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Training-loop failure (non-finite loss, schedule violation...).
    #[error("train error: {0}")]
    Train(String),

    /// Expansion surgery failure (dimension not growing, bad position...).
    #[error("expand error: {0}")]
    Expand(String),

    /// CLI usage error.
    #[error("usage error: {0}")]
    Cli(String),

    /// I/O with path context.
    #[error("io error on {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Attach a file path to an `std::io::Error`.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("bad heads".into());
        assert_eq!(e.to_string(), "config error: bad heads");
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "nope"));
        assert!(e.to_string().contains("/tmp/x"));
    }

    #[test]
    fn xla_error_converts() {
        let e: Error = xla::Error::WrongElementCount { dims: vec![2], element_count: 3 }.into();
        assert!(matches!(e, Error::Runtime(_)));
    }
}
