//! Crate-wide error type.
//!
//! One enum covers every subsystem so that errors compose across the
//! coordinator's phases (config parsing → artifact loading → PJRT execution
//! → surgery → checkpointing) without boxing at each boundary.
//!
//! `Display`/`Error` are implemented by hand — the offline crate set has no
//! `thiserror`, and the derive buys nothing at one enum's worth of match
//! arms.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error enum.
#[derive(Debug)]
pub enum Error {
    /// JSON syntax or structural error (path-annotated where possible).
    Json(String),

    /// Config / growth-schedule validation failure.
    Config(String),

    /// Tensor shape mismatch or invalid operation.
    Shape(String),

    /// Parameter-store inconsistency (missing param, spec mismatch...).
    Params(String),

    /// Checkpoint codec error.
    Checkpoint(String),

    /// Artifact manifest problem (missing stage, spec drift vs config...).
    Manifest(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Training-loop failure (non-finite loss, schedule violation...).
    Train(String),

    /// Expansion surgery failure (dimension not growing, bad position...).
    Expand(String),

    /// Serving-engine failure (bad request, rejected hot-swap...).
    Serve(String),

    /// CLI usage error.
    Cli(String),

    /// I/O with path context.
    Io { path: String, source: std::io::Error },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Json(msg) => write!(f, "json error: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Shape(msg) => write!(f, "shape error: {msg}"),
            Error::Params(msg) => write!(f, "param store error: {msg}"),
            Error::Checkpoint(msg) => write!(f, "checkpoint error: {msg}"),
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Train(msg) => write!(f, "train error: {msg}"),
            Error::Expand(msg) => write!(f, "expand error: {msg}"),
            Error::Serve(msg) => write!(f, "serve error: {msg}"),
            Error::Cli(msg) => write!(f, "usage error: {msg}"),
            Error::Io { path, source } => write!(f, "io error on {path}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Attach a file path to an `std::io::Error`.
    pub fn io(path: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { path: path.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Config("bad heads".into());
        assert_eq!(e.to_string(), "config error: bad heads");
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "nope"));
        assert!(e.to_string().contains("/tmp/x"));
    }

    #[test]
    fn io_error_exposes_source() {
        use std::error::Error as _;
        let e = Error::io("/tmp/x", std::io::Error::new(std::io::ErrorKind::NotFound, "nope"));
        assert!(e.source().is_some());
        assert!(Error::Serve("queue full".into()).source().is_none());
    }

    #[test]
    fn xla_error_converts() {
        let e: Error = xla::Error::WrongElementCount { dims: vec![2], element_count: 3 }.into();
        assert!(matches!(e, Error::Runtime(_)));
    }
}
