//! The six function-preserving expansions (S6) — paper Section 3.
//!
//! Each function consumes a [`ParamStore`] for config `C` and produces the
//! store for the expanded config, performing exactly the parameter surgery
//! of Defs. 3.1–3.6 with the zero-init constraints of Thms. 3.1–3.6.
//! This is the *runtime* implementation used at stage boundaries by the
//! growth coordinator (Python's `transforms.py` is the build-time /
//! cross-check twin; integration tests assert the two agree).
//!
//! ## Options
//!
//! [`ExpandOptions`] exposes the same three knobs as the Python side:
//! * `init` — initializer for the matrices the theorems leave
//!   *unconstrained* (`Zeros` for maximum caution, `Normal(std)` to give
//!   new capacity gradient signal immediately);
//! * `zero_constrained` — set `false` to deliberately violate the theorem
//!   (E6 ablation: demonstrates the constraint set is not vacuous);
//! * `scale_factors` — set `false` to drop the paper's two novel scaling
//!   factors (Eq. 19 `sqrt(k_hat/k)` on W^K, Eq. 24 `sqrt(h/h_hat)` on the
//!   RMSNorm gains; E6/E7 ablations).
//!
//! Optimizer-moment surgery follows the *same* geometric surgery with
//! all-zero new slices (a freshly added parameter has no gradient
//! history); it is dispatched through the plan API like everything else.
//!
//! ## Entry point
//!
//! The surgery cores in this module are **crate-internal mechanism**. The
//! one public way to expand anything — parameters, optimizer moments,
//! live KV caches — is an [`ExpansionPlan`] ([`plan`]): validate the op
//! composition up front, inspect the predicted deltas, then
//! [`Expandable::apply_plan`] transactionally.

pub mod plan;

pub use plan::{ApplyOutcome, ConstraintNote, Expandable, ExpansionPlan, StagedKv};

use std::collections::HashMap;

use crate::config::{GrowthOp, LayerPosition, ModelConfig};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

/// Initializer for unconstrained new parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    /// Zero-fill (new capacity starts inert even where the theorem allows
    /// arbitrary values).
    Zeros,
    /// `std * N(0,1)` — the default, matching `transforms.default_init`.
    Normal(f32),
}

impl Init {
    fn sample(&self, shape: &[usize], rng: &mut Pcg32) -> Tensor {
        match *self {
            Init::Zeros => Tensor::zeros(shape),
            Init::Normal(std) => Tensor::randn(shape, rng, std),
        }
    }
}

/// Knobs shared by all six transformations (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct ExpandOptions {
    pub init: Init,
    pub zero_constrained: bool,
    pub scale_factors: bool,
    /// Exponent applied to the Eq. 19 / Eq. 24 scaling factors. `1.0` for
    /// parameters. Optimizer moments transform with the *inverse* of the
    /// reparametrization: a param scaled by `c` has gradients scaled by
    /// `1/c`, so Adam's first moment uses `-1.0` and the second (squared)
    /// moment uses `-2.0` (see `optim::expand_moments`).
    pub scale_power: f32,
}

impl Default for ExpandOptions {
    fn default() -> Self {
        ExpandOptions {
            init: Init::Normal(0.02),
            zero_constrained: true,
            scale_factors: true,
            scale_power: 1.0,
        }
    }
}

impl ExpandOptions {
    /// Options for optimizer-moment surgery: all-new slices zero, kept
    /// slices rescaled with `factor^power` (see `scale_power`).
    pub fn for_moments(power: f32) -> ExpandOptions {
        ExpandOptions { init: Init::Zeros, zero_constrained: true, scale_factors: true, scale_power: power }
    }

    /// Constrained-matrix initializer: zeros per the theorems, or the
    /// violation initializer for ablations.
    fn constrained(&self, shape: &[usize], rng: &mut Pcg32) -> Tensor {
        if self.zero_constrained {
            Tensor::zeros(shape)
        } else {
            self.init.sample(shape, rng)
        }
    }
}

fn to_map(store: &ParamStore) -> HashMap<String, Tensor> {
    store.iter().map(|(s, t)| (s.name.clone(), t.clone())).collect()
}

/// Take a tensor out of the surgery map (it must exist — the map is always
/// seeded from a validated ParamStore).
fn take(map: &mut HashMap<String, Tensor>, name: &str) -> Result<Tensor> {
    map.remove(name).ok_or_else(|| Error::Expand(format!("missing param '{name}' during surgery")))
}

// ---------------------------------------------------------------------------
// Map-based surgery cores
//
// All six transformations operate on an owned name->Tensor map so that a
// composed op sequence pays ONE full-store copy (to_map) and ONE canonical
// rebuild (from_map) total, instead of one of each per op. Untouched
// tensors flow through the whole chain without being copied — at ~11M
// params this is the difference between ~800ms and ~100ms per boundary
// (EXPERIMENTS.md §Perf).
// ---------------------------------------------------------------------------

fn mlp_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    new_p: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    let new_cfg = GrowthOp::Mlp { p: new_p }.apply_to_config(cfg).map_err(wrap_expand)?;
    let d = new_p - cfg.mlp;
    for n in 0..cfg.layers {
        let w1 = take(map, &format!("layer_{n}.w1"))?;
        let b1 = take(map, &format!("layer_{n}.b1"))?;
        let w2 = take(map, &format!("layer_{n}.w2"))?;
        map.insert(format!("layer_{n}.w1"), w1.concat_cols(&opts.init.sample(&[cfg.hidden, d], rng))?);
        map.insert(format!("layer_{n}.b1"), b1.concat_1d(&opts.init.sample(&[d], rng))?);
        map.insert(format!("layer_{n}.w2"), w2.concat_rows(&opts.constrained(&[d, cfg.hidden], rng))?);
    }
    Ok(new_cfg)
}

fn heads_add_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    count: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    let new_cfg = GrowthOp::HeadsAdd { count }.apply_to_config(cfg).map_err(wrap_expand)?;
    for n in 0..cfg.layers {
        let mut wo = take(map, &format!("layer_{n}.wo"))?;
        for e in cfg.heads..new_cfg.heads {
            map.insert(format!("layer_{n}.head_{e}.wq"), opts.init.sample(&[cfg.hidden, cfg.k], rng));
            map.insert(format!("layer_{n}.head_{e}.wk"), opts.init.sample(&[cfg.hidden, cfg.k], rng));
            map.insert(format!("layer_{n}.head_{e}.wv"), opts.init.sample(&[cfg.hidden, cfg.v], rng));
            wo = wo.concat_rows(&opts.constrained(&[cfg.v, cfg.hidden], rng))?;
        }
        map.insert(format!("layer_{n}.wo"), wo);
    }
    Ok(new_cfg)
}

fn heads_expand_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    new_v: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    let new_cfg = GrowthOp::HeadsExpand { v: new_v }.apply_to_config(cfg).map_err(wrap_expand)?;
    let d = new_v - cfg.v;
    for n in 0..cfg.layers {
        let wo = take(map, &format!("layer_{n}.wo"))?;
        let mut new_wo: Option<Tensor> = None;
        for e in 0..cfg.heads {
            let wv = take(map, &format!("layer_{n}.head_{e}.wv"))?;
            map.insert(
                format!("layer_{n}.head_{e}.wv"),
                wv.concat_cols(&opts.init.sample(&[cfg.hidden, d], rng))?,
            );
            let split = wo.slice_rows(e * cfg.v, (e + 1) * cfg.v)?;
            let grown = split.concat_rows(&opts.constrained(&[d, cfg.hidden], rng))?;
            new_wo = Some(match new_wo {
                None => grown,
                Some(acc) => acc.concat_rows(&grown)?,
            });
        }
        map.insert(format!("layer_{n}.wo"), new_wo.expect("heads >= 1"));
    }
    Ok(new_cfg)
}

fn attn_expand_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    new_k: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    let new_cfg = GrowthOp::AttnExpand { k: new_k }.apply_to_config(cfg).map_err(wrap_expand)?;
    let d = new_k - cfg.k;
    let factor = if opts.scale_factors {
        ((new_k as f32) / (cfg.k as f32)).sqrt().powf(opts.scale_power)
    } else {
        1.0
    };
    for n in 0..cfg.layers {
        for e in 0..cfg.heads {
            let wq = take(map, &format!("layer_{n}.head_{e}.wq"))?;
            let mut wk = take(map, &format!("layer_{n}.head_{e}.wk"))?;
            map.insert(
                format!("layer_{n}.head_{e}.wq"),
                wq.concat_cols(&opts.init.sample(&[cfg.hidden, d], rng))?,
            );
            wk.scale(factor);
            map.insert(
                format!("layer_{n}.head_{e}.wk"),
                wk.concat_cols(&opts.constrained(&[cfg.hidden, d], rng))?,
            );
        }
    }
    Ok(new_cfg)
}

fn hidden_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    new_h: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    let new_cfg = GrowthOp::Hidden { h: new_h }.apply_to_config(cfg).map_err(wrap_expand)?;
    let d = new_h - cfg.hidden;
    let g_factor = if opts.scale_factors {
        ((cfg.hidden as f32) / (new_h as f32)).sqrt().powf(opts.scale_power)
    } else {
        1.0
    };

    // embed [vocab, h]: new cols zero (M^I, Eq. 37)
    let embed = take(map, "embed")?;
    map.insert("embed".into(), embed.concat_cols(&opts.constrained(&[cfg.vocab, d], rng))?);
    // pos [s, h]: new cols zero (Eq. 33)
    let pos = take(map, "pos")?;
    map.insert("pos".into(), pos.concat_cols(&opts.constrained(&[cfg.seq, d], rng))?);
    // w_out [h, o]: new rows unconstrained (Eq. 23)
    let w_out = take(map, "w_out")?;
    map.insert("w_out".into(), w_out.concat_rows(&opts.init.sample(&[d, cfg.vocab], rng))?);

    for n in 0..cfg.layers {
        for c in ["g_mha", "g_mlp"] {
            let mut g = take(map, &format!("layer_{n}.{c}"))?;
            g.scale(g_factor);
            map.insert(
                format!("layer_{n}.{c}"),
                g.concat_1d(&if opts.zero_constrained {
                    Tensor::zeros(&[d])
                } else {
                    opts.init.sample(&[d], rng)
                })?,
            );
        }
        for e in 0..cfg.heads {
            for mat in ["wq", "wk", "wv"] {
                let w = take(map, &format!("layer_{n}.head_{e}.{mat}"))?;
                let cols = w.cols();
                map.insert(
                    format!("layer_{n}.head_{e}.{mat}"),
                    w.concat_rows(&opts.init.sample(&[d, cols], rng))?,
                );
            }
        }
        // wo [E*v, h]: new cols zero (Eq. 36)
        let wo = take(map, &format!("layer_{n}.wo"))?;
        map.insert(format!("layer_{n}.wo"), wo.concat_cols(&opts.constrained(&[cfg.heads * cfg.v, d], rng))?);
        // w1 [h, p]: new rows unconstrained (Eq. 25)
        let w1 = take(map, &format!("layer_{n}.w1"))?;
        map.insert(format!("layer_{n}.w1"), w1.concat_rows(&opts.init.sample(&[d, cfg.mlp], rng))?);
        // w2 [p, h]: new cols zero (Eq. 34)
        let w2 = take(map, &format!("layer_{n}.w2"))?;
        map.insert(format!("layer_{n}.w2"), w2.concat_cols(&opts.constrained(&[cfg.mlp, d], rng))?);
        // b2 [h]: new entries zero (Eq. 35)
        let b2 = take(map, &format!("layer_{n}.b2"))?;
        map.insert(
            format!("layer_{n}.b2"),
            b2.concat_1d(&if opts.zero_constrained {
                Tensor::zeros(&[d])
            } else {
                opts.init.sample(&[d], rng)
            })?,
        );
    }
    Ok(new_cfg)
}

fn layers_add_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    count: usize,
    position: LayerPosition,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    let new_cfg =
        GrowthOp::LayersAdd { count, position }.apply_to_config(cfg).map_err(wrap_expand)?;
    let pos = match position {
        LayerPosition::Top => cfg.layers,
        LayerPosition::Bottom => 0,
        LayerPosition::At(p) => p,
    };

    // pull out per-layer groups (moves, no copies), insert fresh groups, renumber
    let layer_keys: Vec<Vec<String>> = (0..cfg.layers)
        .map(|n| {
            let prefix = format!("layer_{n}.");
            map.keys().filter(|k| k.starts_with(&prefix)).cloned().collect()
        })
        .collect();
    let mut layers: Vec<HashMap<String, Tensor>> = Vec::with_capacity(cfg.layers + count);
    for (n, keys) in layer_keys.iter().enumerate() {
        let prefix_len = format!("layer_{n}.").len();
        let mut group = HashMap::new();
        for key in keys {
            let t = take(map, key)?;
            group.insert(key[prefix_len..].to_string(), t);
        }
        layers.push(group);
    }

    for _ in 0..count {
        let mut lp: HashMap<String, Tensor> = HashMap::new();
        lp.insert("g_mha".into(), Tensor::ones(&[cfg.hidden]));
        lp.insert("g_mlp".into(), Tensor::ones(&[cfg.hidden]));
        for e in 0..cfg.heads {
            lp.insert(format!("head_{e}.wq"), opts.init.sample(&[cfg.hidden, cfg.k], rng));
            lp.insert(format!("head_{e}.wk"), opts.init.sample(&[cfg.hidden, cfg.k], rng));
            lp.insert(format!("head_{e}.wv"), opts.init.sample(&[cfg.hidden, cfg.v], rng));
        }
        lp.insert("wo".into(), opts.constrained(&[cfg.heads * cfg.v, cfg.hidden], rng));
        lp.insert("w1".into(), opts.init.sample(&[cfg.hidden, cfg.mlp], rng));
        lp.insert("b1".into(), opts.init.sample(&[cfg.mlp], rng));
        lp.insert("w2".into(), opts.constrained(&[cfg.mlp, cfg.hidden], rng));
        lp.insert(
            "b2".into(),
            if opts.zero_constrained { Tensor::zeros(&[cfg.hidden]) } else { opts.init.sample(&[cfg.hidden], rng) },
        );
        layers.insert(pos, lp);
    }

    for (n, lp) in layers.into_iter().enumerate() {
        for (k, t) in lp {
            map.insert(format!("layer_{n}.{k}"), t);
        }
    }
    Ok(new_cfg)
}

fn apply_op_map(
    cfg: &ModelConfig,
    map: &mut HashMap<String, Tensor>,
    op: &GrowthOp,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ModelConfig> {
    match *op {
        GrowthOp::Mlp { p } => mlp_map(cfg, map, p, rng, opts),
        GrowthOp::HeadsAdd { count } => heads_add_map(cfg, map, count, rng, opts),
        GrowthOp::HeadsExpand { v } => heads_expand_map(cfg, map, v, rng, opts),
        GrowthOp::AttnExpand { k } => attn_expand_map(cfg, map, k, rng, opts),
        GrowthOp::Hidden { h } => hidden_map(cfg, map, h, rng, opts),
        GrowthOp::LayersAdd { count, position } => layers_add_map(cfg, map, count, position, rng, opts),
    }
}

// ---------------------------------------------------------------------------
// Per-transformation API (paper Defs. 3.1-3.6) — test-only wrappers over
// the map cores, kept for the per-theorem unit suites below. Production
// paths (and everything outside this subsystem) compose ops through an
// [`ExpansionPlan`] instead, which drives `apply_ops_owned`.
// ---------------------------------------------------------------------------

#[cfg(test)]
macro_rules! single_op {
    ($store:expr, $rng:expr, $opts:expr, $core:expr) => {{
        let cfg = *$store.config();
        let mut map = to_map($store);
        let new_cfg = $core(&cfg, &mut map, $rng, $opts)?;
        ParamStore::from_map(&new_cfg, map)
    }};
}

/// Def. 3.1: grow the MLP internal width `p -> new_p` in every layer.
///
/// Surgery per layer: `W1 [h,p] -> [h,p̂]` (new columns unconstrained,
/// Eq. 6), `b1 [p] -> [p̂]` (unconstrained, Eq. 7), `W2 [p,h] -> [p̂,h]`
/// (new rows **zero**, Thm 3.1 / Eq. 9).
#[cfg(test)]
pub(crate) fn expand_mlp(
    store: &ParamStore,
    new_p: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    single_op!(store, rng, opts, |cfg: &ModelConfig, map: &mut HashMap<String, Tensor>, rng: &mut Pcg32, opts: &ExpandOptions| {
        mlp_map(cfg, map, new_p, rng, opts)
    })
}

/// Def. 3.2: add `count` attention heads to every layer.
///
/// Per new head: fresh `W^Q/W^K/W^V` (unconstrained) and `v` **zero** rows
/// appended to `W^O` (Thm 3.2 / Eq. 12).
#[cfg(test)]
pub(crate) fn add_heads(
    store: &ParamStore,
    count: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    single_op!(store, rng, opts, |cfg: &ModelConfig, map: &mut HashMap<String, Tensor>, rng: &mut Pcg32, opts: &ExpandOptions| {
        heads_add_map(cfg, map, count, rng, opts)
    })
}

/// Def. 3.3: grow each head's value/output width `v -> new_v`.
///
/// `W^V` gains unconstrained columns (Eq. 13); `W^O`, viewed as `E` stacked
/// `(v, h)` splits (Eq. 15), gains `(new_v - v)` **zero** rows inside each
/// split (Thm 3.3 / Eq. 16) — an interleaved insertion, not an append.
#[cfg(test)]
pub(crate) fn expand_heads(
    store: &ParamStore,
    new_v: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    single_op!(store, rng, opts, |cfg: &ModelConfig, map: &mut HashMap<String, Tensor>, rng: &mut Pcg32, opts: &ExpandOptions| {
        heads_expand_map(cfg, map, new_v, rng, opts)
    })
}

/// Def. 3.4: grow the key/query width `k -> new_k`.
///
/// `W^Q` gains unconstrained columns (Eq. 18). `W^K`'s pre-existing columns
/// are scaled by `sqrt(new_k)/sqrt(k)` (Eq. 19) — compensating attention's
/// `1/sqrt(k)` — and its new columns are **zero** (Thm 3.4 / Eq. 20).
#[cfg(test)]
pub(crate) fn expand_attention(
    store: &ParamStore,
    new_k: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    single_op!(store, rng, opts, |cfg: &ModelConfig, map: &mut HashMap<String, Tensor>, rng: &mut Pcg32, opts: &ExpandOptions| {
        attn_expand_map(cfg, map, new_k, rng, opts)
    })
}

/// Def. 3.5: grow the transformer hidden width `h -> new_h` (all layers —
/// the residual stream forces uniformity).
///
/// Zero-init set (Thm 3.5): new columns of the embedding table (`M^I`,
/// Eq. 37), positional embedding (Eq. 33), `W2` (Eq. 34), `b2` (Eq. 35)
/// and `W^O` (Eq. 36). RMSNorm gains are scaled by `sqrt(h)/sqrt(new_h)`
/// (Eq. 24); new gain entries are zeroed (conservative — they multiply
/// zero activations either way; must match `transforms.py`). Everything
/// else (`W^out` rows, `W1` rows, `W^{Q,K,V}` rows) is unconstrained.
#[cfg(test)]
pub(crate) fn expand_hidden(
    store: &ParamStore,
    new_h: usize,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    single_op!(store, rng, opts, |cfg: &ModelConfig, map: &mut HashMap<String, Tensor>, rng: &mut Pcg32, opts: &ExpandOptions| {
        hidden_map(cfg, map, new_h, rng, opts)
    })
}

/// Def. 3.6: insert `count` identity-initialized layers at `position`.
///
/// The new layers' `W^O`, `W2` and `b2` are **zero** (Thm 3.6), making each
/// inserted block compute `I_n + 0`; norm gains start at 1 and `W^{Q,K,V}`,
/// `W1`, `b1` are unconstrained. Downstream layer indices shift up.
#[cfg(test)]
pub(crate) fn add_layers(
    store: &ParamStore,
    count: usize,
    position: LayerPosition,
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    single_op!(store, rng, opts, |cfg: &ModelConfig, map: &mut HashMap<String, Tensor>, rng: &mut Pcg32, opts: &ExpandOptions| {
        layers_add_map(cfg, map, count, position, rng, opts)
    })
}

// ---------------------------------------------------------------------------
// Op dispatch / composition
// ---------------------------------------------------------------------------

/// Apply a composed op sequence (Section 3: the transformations compose).
///
/// The whole sequence shares one owned tensor map: one full-store copy in,
/// one canonical rebuild out, untouched tensors never copied in between.
/// Test-only convenience; non-test callers go through `ExpansionPlan`,
/// whose apply uses the owned variant below.
#[cfg(test)]
pub(crate) fn apply_ops(
    store: &ParamStore,
    ops: &[GrowthOp],
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    let cfg = *store.config();
    let map = to_map(store);
    apply_ops_map(cfg, map, ops, rng, opts)
}

/// Owned variant of the composed-sequence surgery: consumes the store, so
/// even the initial full-store copy is avoided — `ExpansionPlan` applies
/// drive this (the pre-surgery store is dead after a boundary anyway).
pub(crate) fn apply_ops_owned(
    store: ParamStore,
    ops: &[GrowthOp],
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    let cfg = *store.config();
    let map = store.into_map();
    apply_ops_map(cfg, map, ops, rng, opts)
}

fn apply_ops_map(
    mut cfg: ModelConfig,
    mut map: HashMap<String, Tensor>,
    ops: &[GrowthOp],
    rng: &mut Pcg32,
    opts: &ExpandOptions,
) -> Result<ParamStore> {
    for op in ops {
        cfg = apply_op_map(&cfg, &mut map, op, rng, opts)?;
    }
    ParamStore::from_map(&cfg, map)
}

// ---------------------------------------------------------------------------
// Alternative function-preserving init (paper §5: "there exist alternative
// definitions to such transformations that achieve function-preservation
// without requiring zero initialization")
// ---------------------------------------------------------------------------

/// Net2Net-style (Chen et al. 2016, cited by the paper) MLP widening:
/// instead of appending inert zero-W2 units (Def. 3.1), *duplicate*
/// randomly chosen existing hidden units and halve the outgoing W2 rows of
/// each {original, duplicate} pair. Also exactly function-preserving —
/// `ReLU` is applied per unit, so `relu(u)·w + relu(u)·w == relu(u)·2w` —
/// but the new capacity starts with *live* weights (nonzero gradients from
/// step one), at the cost of pairwise-tied directions at birth. The
/// `split_noise` jitter breaks the tie on W1 (which does NOT affect the
/// forward output only when zero; nonzero noise trades exactness for
/// symmetry breaking — pass 0.0 for exact preservation).
pub fn split_mlp_neurons(
    store: &ParamStore,
    new_p: usize,
    rng: &mut Pcg32,
    split_noise: f32,
) -> Result<ParamStore> {
    let cfg = *store.config();
    let new_cfg = GrowthOp::Mlp { p: new_p }.apply_to_config(&cfg).map_err(wrap_expand)?;
    let d = new_p - cfg.mlp;
    let mut map = to_map(store);
    for n in 0..cfg.layers {
        let w1 = take(&mut map, &format!("layer_{n}.w1"))?; // [h, p]
        let b1 = take(&mut map, &format!("layer_{n}.b1"))?; // [p]
        let mut w2 = take(&mut map, &format!("layer_{n}.w2"))?; // [p, h]
        // choose d source units to split (with replacement is fine: a unit
        // split twice is halved twice, each copy carrying 1/4 of the output)
        let sources: Vec<usize> = (0..d).map(|_| rng.below(cfg.mlp)).collect();

        // new W1 columns / b1 entries: copies of the source unit (+ jitter)
        let mut w1_new = Tensor::zeros(&[cfg.hidden, d]);
        for (j, &src) in sources.iter().enumerate() {
            for i in 0..cfg.hidden {
                w1_new.set(i, j, w1.at(i, src) + rng.normal_f32(split_noise));
            }
        }
        let mut b1_new = Tensor::zeros(&[d]);
        for (j, &src) in sources.iter().enumerate() {
            b1_new.data_mut()[j] = b1.data()[src];
        }
        // outgoing rows: halve source row, duplicate gets the other half
        let mut w2_new = Tensor::zeros(&[d, cfg.hidden]);
        for (j, &src) in sources.iter().enumerate() {
            for c in 0..cfg.hidden {
                let half = w2.at(src, c) / 2.0;
                w2.set(src, c, half);
                w2_new.set(j, c, half);
            }
        }
        map.insert(format!("layer_{n}.w1"), w1.concat_cols(&w1_new)?);
        map.insert(format!("layer_{n}.b1"), b1.concat_1d(&b1_new)?);
        map.insert(format!("layer_{n}.w2"), w2.concat_rows(&w2_new)?);
    }
    ParamStore::from_map(&new_cfg, map)
}

fn wrap_expand(e: Error) -> Error {
    match e {
        Error::Config(msg) => Error::Expand(msg),
        other => other,
    }
}

// ---------------------------------------------------------------------------
// Candidate enumeration (growth-policy search)
// ---------------------------------------------------------------------------

/// Candidate *next* expansions for growth-policy search: one modest,
/// strictly-growing proposal per op family, derived from the current
/// dimensions (widths grow geometrically, counts by one — the paper's §5
/// NAS direction needs a finite action set, not the full op lattice).
/// Every returned op is valid: `op.apply_to_config(cfg)` succeeds.
pub fn candidate_ops(cfg: &ModelConfig) -> Vec<GrowthOp> {
    vec![
        GrowthOp::Mlp { p: cfg.mlp * 2 },
        GrowthOp::HeadsAdd { count: 1 },
        GrowthOp::HeadsExpand { v: cfg.v * 2 },
        GrowthOp::AttnExpand { k: cfg.k * 2 },
        // gentler than doubling: hidden width multiplies almost every tensor
        GrowthOp::Hidden { h: (cfg.hidden + cfg.hidden / 2).max(cfg.hidden + 1) },
        GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::model::{forward, max_logit_delta};
    use crate::prop::Runner;

    const PRESERVE_TOL: f32 = 1e-4; // DESIGN.md §8
    const BREAK_TOL: f32 = 1e-2;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
    }

    fn setup(seed: u64, scale: f32) -> (ModelConfig, ParamStore, Vec<Vec<u32>>, Vec<Tensor>) {
        let c = cfg();
        let mut rng = Pcg32::seeded(seed);
        let params = ParamStore::init(&c, &mut rng, scale);
        let toks: Vec<Vec<u32>> =
            (0..2).map(|_| (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect()).collect();
        let base = forward(&c, &params, &toks).unwrap();
        (c, params, toks, base)
    }

    fn delta(store: &ParamStore, toks: &[Vec<u32>], base: &[Tensor]) -> f32 {
        let out = forward(store.config(), store, toks).unwrap();
        max_logit_delta(&out, base).unwrap()
    }

    fn big() -> ExpandOptions {
        // aggressive unconstrained init: exercises the theorems' freedom
        ExpandOptions { init: Init::Normal(0.5), ..Default::default() }
    }

    fn violate() -> ExpandOptions {
        ExpandOptions { init: Init::Normal(0.5), zero_constrained: false, ..Default::default() }
    }

    // ---- Thm 3.1 ----------------------------------------------------------

    #[test]
    fn thm31_mlp_preserves() {
        let (_, params, toks, base) = setup(1, 0.02);
        let out = expand_mlp(&params, 64, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.config().mlp, 64);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn thm31_violation_breaks() {
        let (_, params, toks, base) = setup(1, 0.02);
        let out = expand_mlp(&params, 64, &mut Pcg32::seeded(9), &violate()).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    #[test]
    fn thm31_old_slices_untouched() {
        let (c, params, _, _) = setup(1, 0.02);
        let out = expand_mlp(&params, 64, &mut Pcg32::seeded(9), &big()).unwrap();
        let old = params.get("layer_0.w1").unwrap();
        let new = out.get("layer_0.w1").unwrap();
        assert_eq!(&new.slice_cols(0, c.mlp).unwrap(), old);
        let old2 = params.get("layer_0.w2").unwrap();
        let new2 = out.get("layer_0.w2").unwrap();
        assert_eq!(&new2.slice_rows(0, c.mlp).unwrap(), old2);
        assert_eq!(new2.slice_rows(c.mlp, 64).unwrap().max_abs(), 0.0);
    }

    #[test]
    fn thm31_rejects_shrink() {
        let (_, params, _, _) = setup(1, 0.02);
        assert!(expand_mlp(&params, 32, &mut Pcg32::seeded(0), &big()).is_err());
    }

    // ---- Thm 3.2 ----------------------------------------------------------

    #[test]
    fn thm32_head_addition_preserves() {
        let (_, params, toks, base) = setup(2, 0.02);
        let out = add_heads(&params, 2, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.config().heads, 4);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn thm32_violation_breaks() {
        let (_, params, toks, base) = setup(2, 0.02);
        let out = add_heads(&params, 1, &mut Pcg32::seeded(9), &violate()).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    #[test]
    fn thm32_wo_gains_zero_rows_below() {
        let (c, params, _, _) = setup(2, 0.02);
        let out = add_heads(&params, 1, &mut Pcg32::seeded(9), &big()).unwrap();
        let wo = out.get("layer_0.wo").unwrap();
        assert_eq!(wo.shape(), &[(c.heads + 1) * c.v, c.hidden]);
        assert_eq!(&wo.slice_rows(0, c.heads * c.v).unwrap(), params.get("layer_0.wo").unwrap());
        assert_eq!(wo.slice_rows(c.heads * c.v, (c.heads + 1) * c.v).unwrap().max_abs(), 0.0);
    }

    // ---- Thm 3.3 ----------------------------------------------------------

    #[test]
    fn thm33_heads_expansion_preserves() {
        let (_, params, toks, base) = setup(3, 0.02);
        let out = expand_heads(&params, 16, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.config().v, 16);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn thm33_violation_breaks() {
        let (_, params, toks, base) = setup(3, 0.02);
        let out = expand_heads(&params, 16, &mut Pcg32::seeded(9), &violate()).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    #[test]
    fn thm33_wo_interleaved_structure() {
        let (c, params, _, _) = setup(3, 0.02);
        let new_v = 16;
        let out = expand_heads(&params, new_v, &mut Pcg32::seeded(9), &big()).unwrap();
        let wo_old = params.get("layer_1.wo").unwrap();
        let wo_new = out.get("layer_1.wo").unwrap();
        for e in 0..c.heads {
            let kept = wo_new.slice_rows(e * new_v, e * new_v + c.v).unwrap();
            assert_eq!(&kept, &wo_old.slice_rows(e * c.v, (e + 1) * c.v).unwrap(), "split {e}");
            let inserted = wo_new.slice_rows(e * new_v + c.v, (e + 1) * new_v).unwrap();
            assert_eq!(inserted.max_abs(), 0.0, "split {e} zeros");
        }
    }

    // ---- Thm 3.4 ----------------------------------------------------------

    #[test]
    fn thm34_attention_expansion_preserves() {
        let (_, params, toks, base) = setup(4, 0.02);
        let out = expand_attention(&params, 16, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.config().k, 16);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn thm34_violation_breaks() {
        let (_, params, toks, base) = setup(4, 0.3);
        let out = expand_attention(&params, 16, &mut Pcg32::seeded(9), &violate()).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    #[test]
    fn thm34_key_scaling_applied_query_untouched() {
        let (c, params, _, _) = setup(4, 0.02);
        let new_k = 32;
        let out = expand_attention(&params, new_k, &mut Pcg32::seeded(9), &big()).unwrap();
        let factor = ((new_k as f32) / (c.k as f32)).sqrt();
        let wk_old = params.get("layer_0.head_0.wk").unwrap();
        let wk_new = out.get("layer_0.head_0.wk").unwrap();
        let mut expected = wk_old.clone();
        expected.scale(factor);
        assert!(wk_new.slice_cols(0, c.k).unwrap().max_abs_diff(&expected).unwrap() < 1e-6);
        let wq_old = params.get("layer_0.head_0.wq").unwrap();
        assert_eq!(&out.get("layer_0.head_0.wq").unwrap().slice_cols(0, c.k).unwrap(), wq_old);
    }

    #[test]
    fn thm34_missing_scale_factor_breaks() {
        // E7: the paper's novel sqrt(k_hat/k) factor is load-bearing
        let (_, params, toks, base) = setup(4, 0.3);
        let opts = ExpandOptions { scale_factors: false, ..big() };
        let out = expand_attention(&params, 32, &mut Pcg32::seeded(9), &opts).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    // ---- Thm 3.5 ----------------------------------------------------------

    #[test]
    fn thm35_hidden_expansion_preserves() {
        let (_, params, toks, base) = setup(5, 0.02);
        let out = expand_hidden(&params, 24, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.config().hidden, 24);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn thm35_violation_breaks() {
        let (_, params, toks, base) = setup(5, 0.02);
        let out = expand_hidden(&params, 24, &mut Pcg32::seeded(9), &violate()).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    #[test]
    fn thm35_norm_scaling_and_zero_sets() {
        let (c, params, _, _) = setup(5, 0.02);
        let new_h = 32;
        let out = expand_hidden(&params, new_h, &mut Pcg32::seeded(9), &big()).unwrap();
        let factor = ((c.hidden as f32) / (new_h as f32)).sqrt();
        let g_old = params.get("layer_0.g_mha").unwrap();
        let g_new = out.get("layer_0.g_mha").unwrap();
        for j in 0..c.hidden {
            assert!((g_new.data()[j] - factor * g_old.data()[j]).abs() < 1e-6);
        }
        // zero sets: embed/pos/wo/w2/b2 extensions
        assert_eq!(out.get("embed").unwrap().slice_cols(c.hidden, new_h).unwrap().max_abs(), 0.0);
        assert_eq!(out.get("pos").unwrap().slice_cols(c.hidden, new_h).unwrap().max_abs(), 0.0);
        assert_eq!(out.get("layer_0.wo").unwrap().slice_cols(c.hidden, new_h).unwrap().max_abs(), 0.0);
        assert_eq!(out.get("layer_0.w2").unwrap().slice_cols(c.hidden, new_h).unwrap().max_abs(), 0.0);
        assert_eq!(out.get("layer_0.b2").unwrap().data()[c.hidden..].iter().map(|x| x.abs()).fold(0.0f32, f32::max), 0.0);
        // unconstrained sets actually randomized (big init, so nonzero)
        assert!(out.get("w_out").unwrap().slice_rows(c.hidden, new_h).unwrap().max_abs() > 0.0);
        assert!(out.get("layer_0.w1").unwrap().slice_rows(c.hidden, new_h).unwrap().max_abs() > 0.0);
    }

    #[test]
    fn thm35_missing_norm_scale_breaks() {
        // E7: the sqrt(h/h_hat) RMSNorm factor is load-bearing
        let (_, params, toks, base) = setup(5, 0.3);
        let opts = ExpandOptions { scale_factors: false, ..big() };
        let out = expand_hidden(&params, 32, &mut Pcg32::seeded(9), &opts).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    // ---- Thm 3.6 ----------------------------------------------------------

    #[test]
    fn thm36_layer_addition_preserves_all_positions() {
        let (c, params, toks, base) = setup(6, 0.02);
        for position in [LayerPosition::Top, LayerPosition::Bottom, LayerPosition::At(1)] {
            let out = add_layers(&params, 1, position, &mut Pcg32::seeded(9), &big()).unwrap();
            assert_eq!(out.config().layers, c.layers + 1);
            assert!(delta(&out, &toks, &base) <= PRESERVE_TOL, "{position:?}");
        }
    }

    #[test]
    fn thm36_multi_layer_preserves() {
        let (_, params, toks, base) = setup(6, 0.02);
        let out = add_layers(&params, 3, LayerPosition::Bottom, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.config().layers, 5);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn thm36_violation_breaks() {
        let (_, params, toks, base) = setup(6, 0.02);
        let out = add_layers(&params, 1, LayerPosition::Top, &mut Pcg32::seeded(9), &violate()).unwrap();
        assert!(delta(&out, &toks, &base) > BREAK_TOL);
    }

    #[test]
    fn thm36_downstream_layers_shift() {
        let (_, params, _, _) = setup(6, 0.02);
        let out = add_layers(&params, 1, LayerPosition::Bottom, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(out.get("layer_1.w1").unwrap(), params.get("layer_0.w1").unwrap());
        assert_eq!(out.get("layer_2.w1").unwrap(), params.get("layer_1.w1").unwrap());
        assert_eq!(out.get("layer_0.wo").unwrap().max_abs(), 0.0);
    }

    #[test]
    fn thm36_rejects_bad_position() {
        let (c, params, _, _) = setup(6, 0.02);
        assert!(add_layers(&params, 1, LayerPosition::At(c.layers + 1), &mut Pcg32::seeded(0), &big()).is_err());
    }

    // ---- composition -------------------------------------------------------

    #[test]
    fn all_six_composed_preserve() {
        let (_, params, toks, base) = setup(7, 0.02);
        let ops = vec![
            GrowthOp::Mlp { p: 64 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::HeadsExpand { v: 16 },
            GrowthOp::AttnExpand { k: 16 },
            GrowthOp::Hidden { h: 32 },
            GrowthOp::LayersAdd { count: 2, position: LayerPosition::Top },
        ];
        let out = apply_ops(&params, &ops, &mut Pcg32::seeded(9), &big()).unwrap();
        assert_eq!(
            (out.config().mlp, out.config().heads, out.config().v, out.config().k, out.config().hidden, out.config().layers),
            (64, 3, 16, 16, 32, 4)
        );
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }

    #[test]
    fn prop_random_sequences_preserve() {
        // E2 property test: any random op sequence preserves the function.
        let base_cfg = ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 8, seq: 8, vocab: 16 };
        Runner::new("expansion-composability", 15).run(
            |rng| {
                let n_ops = 1 + rng.below(3);
                let mut cfg = base_cfg;
                let mut ops = Vec::new();
                for _ in 0..n_ops {
                    let op = match rng.below(6) {
                        0 => GrowthOp::Mlp { p: cfg.mlp + 4 + rng.below(8) },
                        1 => GrowthOp::HeadsAdd { count: 1 },
                        2 => GrowthOp::HeadsExpand { v: cfg.v + 2 + rng.below(4) },
                        3 => GrowthOp::AttnExpand { k: cfg.k + 2 + rng.below(4) },
                        4 => GrowthOp::Hidden { h: cfg.hidden + 4 + rng.below(8) },
                        _ => GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(rng.below(cfg.layers + 1)) },
                    };
                    cfg = op.apply_to_config(&cfg).unwrap();
                    ops.push(op);
                }
                let seed = rng.next_u64();
                (ops, seed)
            },
            |(ops, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                let params = ParamStore::init(&base_cfg, &mut rng, 0.05);
                let toks: Vec<Vec<u32>> =
                    vec![(0..base_cfg.seq).map(|_| rng.below(base_cfg.vocab) as u32).collect()];
                let base = forward(&base_cfg, &params, &toks).map_err(|e| e.to_string())?;
                let out = apply_ops(&params, ops, &mut rng, &big()).map_err(|e| e.to_string())?;
                let d = delta(&out, &toks, &base);
                if d <= PRESERVE_TOL {
                    Ok(())
                } else {
                    Err(format!("max|Δ| = {d}"))
                }
            },
        );
    }

    #[test]
    fn zeros_init_option_gives_inert_new_capacity() {
        let (c, params, toks, base) = setup(8, 0.02);
        let opts = ExpandOptions { init: Init::Zeros, ..Default::default() };
        let out = expand_mlp(&params, 64, &mut Pcg32::seeded(9), &opts).unwrap();
        assert_eq!(out.get("layer_0.w1").unwrap().slice_cols(c.mlp, 64).unwrap().max_abs(), 0.0);
        assert!(delta(&out, &toks, &base) <= PRESERVE_TOL);
    }
}

#[cfg(test)]
mod net2net_tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::{forward, max_logit_delta};

    fn setup() -> (ModelConfig, ParamStore, Vec<Vec<u32>>, Vec<Tensor>) {
        let c = ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 };
        let mut rng = Pcg32::seeded(41);
        let params = ParamStore::init(&c, &mut rng, 0.1);
        let toks: Vec<Vec<u32>> =
            (0..2).map(|_| (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect()).collect();
        let base = forward(&c, &params, &toks).unwrap();
        (c, params, toks, base)
    }

    #[test]
    fn split_is_function_preserving_at_zero_noise() {
        let (_, params, toks, base) = setup();
        let out = split_mlp_neurons(&params, 64, &mut Pcg32::seeded(1), 0.0).unwrap();
        assert_eq!(out.config().mlp, 64);
        let after = forward(out.config(), &out, &toks).unwrap();
        assert!(max_logit_delta(&base, &after).unwrap() <= 1e-4);
    }

    #[test]
    fn split_gives_live_weights_unlike_def31() {
        // the paper's Def 3.1 leaves new W2 rows zero; the Net2Net variant
        // must produce nonzero outgoing weights for the new units.
        let (c, params, _, _) = setup();
        let out = split_mlp_neurons(&params, 64, &mut Pcg32::seeded(2), 0.0).unwrap();
        let w2_new_rows = out.get("layer_0.w2").unwrap().slice_rows(c.mlp, 64).unwrap();
        assert!(w2_new_rows.max_abs() > 0.0);
        // and the W2 column sums are preserved (split halves re-sum)
        let w2_old = params.get("layer_0.w2").unwrap();
        let w2_all = out.get("layer_0.w2").unwrap();
        // compare total contribution per hidden unit under an all-active relu
        // pattern by checking column sums weighted by duplicated w1 columns'
        // coincidence: simpler — sum of rows mapped back per source is checked
        // implicitly by the preservation test; here verify total mass:
        let sum_old: f32 = w2_old.data().iter().sum();
        let sum_new: f32 = w2_all.data().iter().sum();
        assert!((sum_old - sum_new).abs() < 1e-3);
    }

    #[test]
    fn split_noise_breaks_exactness_gracefully() {
        let (_, params, toks, base) = setup();
        let out = split_mlp_neurons(&params, 64, &mut Pcg32::seeded(3), 0.05).unwrap();
        let after = forward(out.config(), &out, &toks).unwrap();
        let d = max_logit_delta(&base, &after).unwrap();
        assert!(d > 1e-4, "noise should perturb: {d}");
        assert!(d < 1.0, "but only slightly: {d}");
    }

    #[test]
    fn split_double_split_of_same_unit_still_preserves() {
        // with replacement, a unit can be chosen twice; quarters must re-sum.
        let (_, params, toks, base) = setup();
        for seed in 0..5 {
            let out = split_mlp_neurons(&params, 96, &mut Pcg32::seeded(seed), 0.0).unwrap();
            let after = forward(out.config(), &out, &toks).unwrap();
            assert!(max_logit_delta(&base, &after).unwrap() <= 1e-4, "seed {seed}");
        }
    }

    #[test]
    fn split_rejects_shrink() {
        let (_, params, _, _) = setup();
        assert!(split_mlp_neurons(&params, 16, &mut Pcg32::seeded(0), 0.0).is_err());
    }
}

#[cfg(test)]
mod candidate_tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::{forward, max_logit_delta};

    #[test]
    fn candidates_all_apply_and_strictly_grow() {
        for cfg in [
            ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 },
            ModelConfig { layers: 2, hidden: 1, heads: 2, k: 1, v: 1, mlp: 1, seq: 8, vocab: 16 },
        ] {
            let cands = candidate_ops(&cfg);
            assert_eq!(cands.len(), 6, "one candidate per op family");
            for op in cands {
                let grown = op.apply_to_config(&cfg).unwrap_or_else(|e| panic!("{op:?}: {e}"));
                assert!(grown.num_params() > cfg.num_params(), "{op:?} did not grow");
            }
        }
    }

    #[test]
    fn candidates_are_function_preserving_branch_points() {
        // the property greedy search relies on: every candidate branch
        // starts from the same function as the base checkpoint
        let cfg = ModelConfig { layers: 1, hidden: 8, heads: 2, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 };
        let mut rng = Pcg32::seeded(77);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let toks: Vec<Vec<u32>> =
            (0..2).map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect()).collect();
        let base = forward(&cfg, &params, &toks).unwrap();
        for op in candidate_ops(&cfg) {
            let branched = apply_ops(
                &params,
                std::slice::from_ref(&op),
                &mut Pcg32::seeded(5),
                &Default::default(),
            )
            .unwrap();
            let after = forward(branched.config(), &branched, &toks).unwrap();
            let d = max_logit_delta(&base, &after).unwrap();
            assert!(d <= 1e-4, "{op:?}: max|Δ| = {d}");
        }
    }
}
