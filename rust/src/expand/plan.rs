//! `ExpansionPlan` (S18) — the one transactional expansion entry point.
//!
//! The paper's six ops *compose* (Section 3), but composition used to live
//! in three separate per-op `match` ladders: `expand::apply_ops` for
//! parameters, `Optimizer::expand` for Adam moments, and the serve-side
//! KV remap — each re-validating (or not) the same op sequence. This
//! module reifies an op sequence into a first-class, inspectable **plan**,
//! in the spirit of LEMON's "expansion as a mapping object":
//!
//! * [`ExpansionPlan::new`] validates the whole composition against the
//!   *intermediate* config after each op, before anything mutates — an
//!   invalid third op is rejected while params, moments and caches are all
//!   still untouched;
//! * the plan carries the predicted post-plan [`ModelConfig`], the
//!   **exact** parameter-count delta, an **estimated** FLOPs delta, and
//!   the zero-init preservation constraints of Thms. 3.1–3.6 as
//!   inspectable metadata ([`ConstraintNote`]);
//! * [`Expandable::apply_plan`] is the single dispatch seam: `ParamStore`
//!   (surgery), [`Optimizer`] (moment surgery) and [`StagedKv`] (in-flight
//!   KV cache remap) all consume the same plan object;
//! * applies are **transactional**: validation happens before mutation,
//!   and each apply post-checks that it landed exactly on the plan's
//!   predicted config and parameter count. [`ExpansionPlan::apply_probed`]
//!   additionally gates on a preservation probe with copy-on-apply
//!   semantics — the caller's store is untouched unless the probe passes —
//!   which is what the serve hot-swap runs under live traffic.
//!
//! ## Why the param delta is exact but the FLOPs delta is an estimate
//!
//! The post-plan parameter count is pure shape arithmetic
//! ([`ModelConfig::num_params`]) over the validated config trajectory —
//! every apply asserts it to the scalar. Forward FLOPs depend on context
//! length, kernel blocking and cache behaviour; [`est_fwd_flops_per_token`]
//! counts matmul multiply-accumulates at full-`seq` attention context plus
//! leading-order vector work, which is the right *ranking* currency for
//! growth policies but not a wall-clock promise. DESIGN.md §13.

use crate::config::{GrowthOp, ModelConfig};
use crate::error::{Error, Result};
use crate::expand::{apply_ops_owned, ExpandOptions};
use crate::json::Value;
use crate::metrics::Timer;
use crate::model;
use crate::optim::Optimizer;
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::serve::kv::{GrowBuf, KvCacheImpl, KvStorage};

/// Estimated forward FLOPs per token for one architecture, at full-`seq`
/// attention context (a multiply-accumulate counts as 2 FLOPs). Matmuls
/// are exact at that context length; norms/softmax/residuals are counted
/// at leading order. This is a cost *model* — see the module docs for why
/// plans treat it as an estimate while the param delta is exact.
pub fn est_fwd_flops_per_token(cfg: &ModelConfig) -> f64 {
    let h = cfg.hidden as f64;
    let k = cfg.k as f64;
    let v = cfg.v as f64;
    let e = cfg.heads as f64;
    let p = cfg.mlp as f64;
    let s = cfg.seq as f64;
    let o = cfg.vocab as f64;
    let per_layer = 2.0 * h * e * (2.0 * k + v)   // W^Q / W^K / W^V projections
        + 2.0 * e * s * (k + v)                   // q·K^T scores + probs·V
        + 2.0 * e * v * h                         // W^O
        + 4.0 * h * p                             // W1 + W2
        + 8.0 * h + 5.0 * e * s + p; // rmsnorms, residual adds, softmax, relu
    cfg.layers as f64 * per_layer + 2.0 * h * o + h // unembed + pos add
}

/// The zero-init / scaling constraints one op's preservation theorem
/// imposes, as inspectable plan metadata (what the surgery will pin to
/// zero, and which kept slices it will rescale).
#[derive(Clone, Debug, PartialEq)]
pub struct ConstraintNote {
    /// Index of the op this note describes, in plan order.
    pub op_index: usize,
    /// `GrowthOp::kind()` of that op.
    pub op_kind: &'static str,
    /// Parameter families whose **new** slices the theorem pins to zero.
    pub zero_init: Vec<&'static str>,
    /// Reparametrization factor applied to **kept** slices, if the op has
    /// one (Eq. 19 / Eq. 24).
    pub scaling: Option<String>,
}

fn constraint_note(op_index: usize, op: &GrowthOp, before: &ModelConfig) -> ConstraintNote {
    let (zero_init, scaling) = match *op {
        GrowthOp::Mlp { .. } => (vec!["w2 new rows (Thm 3.1, Eq. 9)"], None),
        GrowthOp::HeadsAdd { .. } => (vec!["wo rows of new heads (Thm 3.2, Eq. 12)"], None),
        GrowthOp::HeadsExpand { .. } => {
            (vec!["wo inserted rows inside each head split (Thm 3.3, Eq. 16)"], None)
        }
        GrowthOp::AttnExpand { k } => (
            vec!["wk new cols (Thm 3.4, Eq. 20)"],
            Some(format!("wk kept cols *= sqrt({k}/{}) (Eq. 19)", before.k)),
        ),
        GrowthOp::Hidden { h } => (
            vec![
                "embed new cols (Thm 3.5, Eq. 37)",
                "pos new cols (Eq. 33)",
                "wo new cols (Eq. 36)",
                "w2 new cols (Eq. 34)",
                "b2 new entries (Eq. 35)",
            ],
            Some(format!("norm gains *= sqrt({}/{h}) (Eq. 24)", before.hidden)),
        ),
        GrowthOp::LayersAdd { .. } => {
            (vec!["inserted layers' wo, w2, b2 (Thm 3.6: each new block computes I + 0)"], None)
        }
    };
    ConstraintNote { op_index, op_kind: op.kind(), zero_init, scaling }
}

/// A validated, inspectable expansion: op sequence + predicted outcome.
/// See the module docs. Construction is the validation point; apply is
/// transactional against the prediction.
#[derive(Clone, Debug, PartialEq)]
pub struct ExpansionPlan {
    from: ModelConfig,
    ops: Vec<GrowthOp>,
    /// Config after each op (same length as `ops`; empty for an identity
    /// plan). The last entry — or `from` — is the predicted target.
    trajectory: Vec<ModelConfig>,
    params_before: usize,
    params_after: usize,
    flops_before: f64,
    flops_after: f64,
    constraints: Vec<ConstraintNote>,
}

impl ExpansionPlan {
    /// Validate `ops` as a composition starting from `from`: each op is
    /// checked against the *intermediate* config produced by its
    /// predecessors, so e.g. a `LayersAdd` at a position only valid after
    /// an earlier `LayersAdd` is accepted, and a shrink anywhere in the
    /// chain is rejected before anything mutates.
    pub fn new(from: &ModelConfig, ops: Vec<GrowthOp>) -> Result<ExpansionPlan> {
        from.validate()?;
        let mut trajectory = Vec::with_capacity(ops.len());
        let mut constraints = Vec::with_capacity(ops.len());
        let mut cfg = *from;
        for (i, op) in ops.iter().enumerate() {
            constraints.push(constraint_note(i, op, &cfg));
            cfg = op.apply_to_config(&cfg).map_err(|e| {
                Error::Expand(format!("plan op {i} ({}) invalid: {e}", op.kind()))
            })?;
            trajectory.push(cfg);
        }
        Ok(ExpansionPlan {
            from: *from,
            params_before: from.num_params(),
            params_after: cfg.num_params(),
            flops_before: est_fwd_flops_per_token(from),
            flops_after: est_fwd_flops_per_token(&cfg),
            ops,
            trajectory,
            constraints,
        })
    }

    /// The no-op plan: keep the architecture as is. Used by policies to
    /// split segments without surgery and as the greedy control branch.
    pub fn identity(cfg: &ModelConfig) -> ExpansionPlan {
        ExpansionPlan::new(cfg, Vec::new()).expect("identity plan over a valid config")
    }

    pub fn ops(&self) -> &[GrowthOp] {
        &self.ops
    }

    pub fn from_config(&self) -> &ModelConfig {
        &self.from
    }

    /// The predicted post-plan architecture (exact: applies post-check it).
    pub fn target_config(&self) -> &ModelConfig {
        self.trajectory.last().unwrap_or(&self.from)
    }

    /// Config after each op, in plan order (empty for an identity plan).
    pub fn trajectory(&self) -> &[ModelConfig] {
        &self.trajectory
    }

    pub fn params_before(&self) -> usize {
        self.params_before
    }

    pub fn params_after(&self) -> usize {
        self.params_after
    }

    /// Exact scalar-parameter growth (ops only ever grow, so this is the
    /// full delta).
    pub fn param_delta(&self) -> usize {
        self.params_after - self.params_before
    }

    pub fn flops_before(&self) -> f64 {
        self.flops_before
    }

    pub fn flops_after(&self) -> f64 {
        self.flops_after
    }

    /// Estimated per-token forward-FLOPs growth.
    pub fn flops_delta(&self) -> f64 {
        self.flops_after - self.flops_before
    }

    /// Estimated training FLOPs for `tokens` tokens on the post-plan
    /// architecture (forward + backward ≈ 3× forward — the 6ND-style
    /// accounting the policies' compute matching uses).
    pub fn est_train_flops(&self, tokens: f64) -> f64 {
        3.0 * self.flops_after * tokens
    }

    /// The preservation constraints each op's theorem imposes, in order.
    pub fn constraints(&self) -> &[ConstraintNote] {
        &self.constraints
    }

    pub fn is_identity(&self) -> bool {
        self.ops.is_empty()
    }

    /// Check a live object's config is the one this plan was built from —
    /// every apply calls this before touching anything.
    pub fn validate_source(&self, cfg: &ModelConfig) -> Result<()> {
        if cfg != &self.from {
            return Err(Error::Expand(format!(
                "plan was built from {:?} but is being applied to {:?}",
                self.from, cfg
            )));
        }
        Ok(())
    }

    /// One-line human summary (CLI tables, log lines).
    pub fn summary(&self) -> String {
        if self.is_identity() {
            return format!("identity ({} params)", self.params_before);
        }
        let ops: Vec<String> = self.ops.iter().map(|o| o.kind().to_string()).collect();
        format!(
            "{}: {} -> {} params (+{}), ~{:.2}x fwd FLOPs",
            ops.join("+"),
            self.params_before,
            self.params_after,
            self.param_delta(),
            self.flops_after / self.flops_before
        )
    }

    /// Full metadata as JSON — what decision logs and `texpand plan` emit.
    /// `ops` round-trip through [`GrowthOp::from_json`].
    pub fn to_json(&self) -> Value {
        let constraints = self
            .constraints
            .iter()
            .map(|c| {
                Value::obj(vec![
                    ("op_index", Value::num(c.op_index as f64)),
                    ("op", Value::str(c.op_kind)),
                    (
                        "zero_init",
                        Value::Arr(c.zero_init.iter().map(|z| Value::str(*z)).collect()),
                    ),
                    (
                        "scaling",
                        match &c.scaling {
                            Some(s) => Value::str(s.clone()),
                            None => Value::Null,
                        },
                    ),
                ])
            })
            .collect();
        Value::obj(vec![
            ("from", self.from.to_json()),
            ("to", self.target_config().to_json()),
            ("ops", Value::Arr(self.ops.iter().map(|o| o.to_json()).collect())),
            ("params_before", Value::num(self.params_before as f64)),
            ("params_after", Value::num(self.params_after as f64)),
            ("param_delta", Value::num(self.param_delta() as f64)),
            ("fwd_flops_per_tok_before", Value::num(self.flops_before)),
            ("fwd_flops_per_tok_after", Value::num(self.flops_after)),
            ("constraints", Value::Arr(constraints)),
        ])
    }

    /// Rebuild a plan from its [`ExpansionPlan::to_json`] record (run-store
    /// ingestion of `decision`/`boundary` evidence). The `from` config and
    /// `ops` are the source of truth — the plan is re-derived through
    /// [`ExpansionPlan::new`], re-running all validation — and the
    /// recorded `to`/`params_after` are then cross-checked against the
    /// rebuilt prediction, so a tampered or stale log row fails loudly
    /// instead of resurrecting as believable evidence.
    pub fn from_json(v: &Value) -> Result<ExpansionPlan> {
        let from = ModelConfig::from_json(v.req("from")?)?;
        let ops_json = v.req("ops")?.as_arr()?;
        let ops = ops_json.iter().map(GrowthOp::from_json).collect::<Result<Vec<_>>>()?;
        let plan = ExpansionPlan::new(&from, ops)?;
        let to = ModelConfig::from_json(v.req("to")?)?;
        if &to != plan.target_config() {
            return Err(Error::Expand(format!(
                "plan json: recorded target {to:?} != rebuilt prediction {:?}",
                plan.target_config()
            )));
        }
        let params_after = v.req("params_after")?.as_usize()?;
        if params_after != plan.params_after() {
            return Err(Error::Expand(format!(
                "plan json: recorded params_after {params_after} != rebuilt {}",
                plan.params_after()
            )));
        }
        Ok(plan)
    }

    /// Apply to a borrowed store, returning the expanded copy (the
    /// read-only entry for probes, branches, benches and examples).
    pub fn materialize(
        &self,
        store: &ParamStore,
        opts: &ExpandOptions,
        rng: &mut Pcg32,
    ) -> Result<ParamStore> {
        let mut out = store.clone();
        out.apply_plan(self, opts, rng)?;
        Ok(out)
    }

    /// The train-side boundary: expand parameters **and** optimizer
    /// moments as one transaction. All validation (source config,
    /// moment/param layout agreement) runs before either mutates, and the
    /// moment layout is re-validated against the grown params after.
    pub fn apply_train(
        &self,
        params: &mut ParamStore,
        opt: &mut Optimizer,
        opts: &ExpandOptions,
        rng: &mut Pcg32,
    ) -> Result<()> {
        self.validate_source(params.config())?;
        opt.validate_against(params)?;
        if self.is_identity() {
            return Ok(());
        }
        params.apply_plan(self, opts, rng)?;
        opt.apply_plan(self, opts, rng)?;
        opt.validate_against(params)
    }

    /// Probe-gated copy-on-apply (the serve hot-swap gate, now built into
    /// the plan API): surgery on a *copy* of `params`, then a preservation
    /// probe — pure-Rust oracle forward on `probe` rows before vs after;
    /// `max|Δ logits| > tol` rejects the plan with the caller's store
    /// untouched. On success the staged store is returned for the caller
    /// to commit atomically.
    pub fn apply_probed(
        &self,
        params: &ParamStore,
        opts: &ExpandOptions,
        rng: &mut Pcg32,
        probe: &[Vec<u32>],
        tol: f32,
    ) -> Result<ApplyOutcome> {
        self.validate_source(params.config())?;
        let timer = Timer::start();
        let before = model::forward(params.config(), params, probe)?;
        let staged = self.materialize(params, opts, rng)?;
        let after = model::forward(staged.config(), &staged, probe)?;
        let probe_delta = model::max_logit_delta(&before, &after)?;
        if probe_delta > tol {
            return Err(Error::Expand(format!(
                "plan rejected: probe max|Δ logits| = {probe_delta:.3e} > tol {tol:.0e}; \
                 source params unchanged"
            )));
        }
        Ok(ApplyOutcome { params: staged, probe_delta, surgery_ms: timer.ms() })
    }
}

/// Result of [`ExpansionPlan::apply_probed`]: the staged expanded store
/// plus the probe evidence, for the caller to commit.
#[derive(Clone, Debug)]
pub struct ApplyOutcome {
    pub params: ParamStore,
    /// `max|Δ logits|` on the probe batch (≤ the tolerance by construction).
    pub probe_delta: f32,
    /// Wall time of surgery + both probe forwards.
    pub surgery_ms: f64,
}

/// The single expansion dispatch seam: anything that must ride through an
/// architecture change implements this against the *same* plan object, so
/// validation, predicted-outcome checks and preservation semantics cannot
/// drift between parameters, optimizer state and serving state.
pub trait Expandable {
    /// Transform `self` across the plan's boundary. Implementations
    /// validate before mutating and post-check the plan's predictions.
    fn apply_plan(
        &mut self,
        plan: &ExpansionPlan,
        opts: &ExpandOptions,
        rng: &mut Pcg32,
    ) -> Result<()>;
}

/// Shape-only placeholder config for `mem::replace` during owned surgery.
fn dummy_cfg() -> ModelConfig {
    ModelConfig { layers: 1, hidden: 1, heads: 1, k: 1, v: 1, mlp: 1, seq: 1, vocab: 1 }
}

impl Expandable for ParamStore {
    /// Parameter surgery (Defs. 3.1–3.6), on the owned fast path: one map
    /// move in, one canonical rebuild out. All op-composition validation
    /// already ran at plan construction, so the only pre-mutation check
    /// needed is the source config; the post-conditions assert the store
    /// landed exactly on the plan's predicted config and param count.
    fn apply_plan(
        &mut self,
        plan: &ExpansionPlan,
        opts: &ExpandOptions,
        rng: &mut Pcg32,
    ) -> Result<()> {
        plan.validate_source(self.config())?;
        if plan.is_identity() {
            return Ok(());
        }
        let old = std::mem::replace(self, ParamStore::zeros(&dummy_cfg()));
        *self = apply_ops_owned(old, plan.ops(), rng, opts)?;
        if self.config() != plan.target_config() {
            return Err(Error::Expand(format!(
                "plan postcondition violated: surgery produced {:?}, plan predicted {:?}",
                self.config(),
                plan.target_config()
            )));
        }
        if self.num_scalars() != plan.params_after() {
            return Err(Error::Expand(format!(
                "plan postcondition violated: {} scalars after surgery, plan predicted {}",
                self.num_scalars(),
                plan.params_after()
            )));
        }
        Ok(())
    }
}

impl Expandable for Optimizer {
    /// Adam moment surgery: the same geometric surgery as the parameters
    /// with all-new slices zero (fresh capacity has no gradient history),
    /// and the paper's two reparametrizations inverted — a param scaled by
    /// `c` has gradients scaled by `1/c`, so the first moment rescales by
    /// `c^-1` and the second by `c^-2` (`ExpandOptions::for_moments`).
    /// SGD is stateless: identity.
    fn apply_plan(
        &mut self,
        plan: &ExpansionPlan,
        _opts: &ExpandOptions,
        _rng: &mut Pcg32,
    ) -> Result<()> {
        match self {
            Optimizer::Sgd { .. } => Ok(()),
            Optimizer::Adam { m, v, .. } => {
                plan.validate_source(m.config())?;
                if plan.is_identity() {
                    return Ok(());
                }
                // surgery is deterministic under Init::Zeros; rng is unused entropy
                let mut rng = Pcg32::seeded(0);
                let old_m = std::mem::replace(m, ParamStore::zeros(&dummy_cfg()));
                *m = apply_ops_owned(old_m, plan.ops(), &mut rng, &ExpandOptions::for_moments(-1.0))?;
                let old_v = std::mem::replace(v, ParamStore::zeros(&dummy_cfg()));
                *v = apply_ops_owned(old_v, plan.ops(), &mut rng, &ExpandOptions::for_moments(-2.0))?;
                if m.config() != plan.target_config() || v.config() != plan.target_config() {
                    return Err(Error::Expand(
                        "plan postcondition violated: moment configs diverged from plan target"
                            .into(),
                    ));
                }
                Ok(())
            }
        }
    }
}

/// An in-flight KV cache staged through a hot-swap: a clone of the live
/// cache paired with the post-surgery parameters its K/V rows are rebuilt
/// from. The serve-side [`Expandable`] target — the engine stages one per
/// slot, applies the plan to each, and commits all-or-nothing. Generic
/// over the K/V storage backend (defaulting to the exact-f32
/// [`crate::serve::kv::GrowBuf`]) so block-quantized caches ride the same
/// plan seam — the remap reads the exact residual-stream buffers either
/// way and re-encodes K/V rows for whichever backend `S` is.
pub struct StagedKv<'p, S: KvStorage = GrowBuf> {
    pub cache: KvCacheImpl<S>,
    pub new_params: &'p ParamStore,
}

impl<S: KvStorage> Expandable for StagedKv<'_, S> {
    /// Remap the cache through the plan's ops (structural residual-stream
    /// remap + K/V rebuild from the new weights — DESIGN.md §9.3). The new
    /// params must be the plan's target; the remap itself re-checks the op
    /// trajectory against them.
    fn apply_plan(
        &mut self,
        plan: &ExpansionPlan,
        _opts: &ExpandOptions,
        _rng: &mut Pcg32,
    ) -> Result<()> {
        plan.validate_source(self.cache.config())?;
        if plan.is_identity() {
            return Ok(());
        }
        self.cache.remap(plan.ops(), self.new_params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LayerPosition, OptimKind, TrainConfig};
    use crate::expand::{candidate_ops, Init};
    use crate::prop::Runner;

    const PRESERVE_TOL: f32 = 1e-4; // DESIGN.md §8

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
    }

    fn all_six() -> Vec<GrowthOp> {
        vec![
            GrowthOp::Mlp { p: 64 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::HeadsExpand { v: 16 },
            GrowthOp::AttnExpand { k: 16 },
            GrowthOp::Hidden { h: 32 },
            GrowthOp::LayersAdd { count: 2, position: LayerPosition::Top },
        ]
    }

    fn big() -> ExpandOptions {
        ExpandOptions { init: Init::Normal(0.5), ..Default::default() }
    }

    // ---- construction & metadata ---------------------------------------

    #[test]
    fn plan_predicts_config_params_and_flops() {
        let c = cfg();
        let plan = ExpansionPlan::new(&c, all_six()).unwrap();
        assert_eq!(plan.ops().len(), 6);
        assert_eq!(plan.from_config(), &c);
        let t = plan.target_config();
        assert_eq!((t.mlp, t.heads, t.v, t.k, t.hidden, t.layers), (64, 3, 16, 16, 32, 4));
        assert_eq!(plan.params_before(), c.num_params());
        assert_eq!(plan.params_after(), t.num_params());
        assert_eq!(plan.param_delta(), t.num_params() - c.num_params());
        assert!(plan.flops_after() > plan.flops_before());
        assert!(plan.flops_delta() > 0.0);
        assert!(plan.est_train_flops(1000.0) > plan.flops_after() * 1000.0);
        // trajectory: one intermediate per op, monotone param growth
        assert_eq!(plan.trajectory().len(), 6);
        let mut prev = c.num_params();
        for step in plan.trajectory() {
            assert!(step.num_params() > prev);
            prev = step.num_params();
        }
    }

    #[test]
    fn plan_validates_against_intermediate_configs() {
        let c = cfg();
        // LayersAdd At(3) is invalid against the base (2 layers) but valid
        // after an earlier LayersAdd — intermediate validation must accept
        let ok = ExpansionPlan::new(
            &c,
            vec![
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(3) },
            ],
        );
        assert!(ok.is_ok());
        // and reject it when no prior op makes room
        let err = ExpansionPlan::new(
            &c,
            vec![GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(3) }],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("plan op 0"), "{err}");
        // a shrink *later* in the chain is caught before anything mutates:
        // mlp 32 -> 64 -> "64" is not strict growth
        let err = ExpansionPlan::new(
            &c,
            vec![GrowthOp::Mlp { p: 64 }, GrowthOp::Mlp { p: 64 }],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("plan op 1"), "{err}");
    }

    #[test]
    fn constraint_metadata_tracks_intermediate_dims() {
        let c = cfg();
        let plan = ExpansionPlan::new(
            &c,
            vec![GrowthOp::AttnExpand { k: 16 }, GrowthOp::Hidden { h: 32 }],
        )
        .unwrap();
        let notes = plan.constraints();
        assert_eq!(notes.len(), 2);
        assert_eq!(notes[0].op_kind, "attn_expand");
        assert!(notes[0].scaling.as_deref().unwrap().contains("sqrt(16/8)"));
        assert!(!notes[0].zero_init.is_empty());
        assert_eq!(notes[1].op_kind, "hidden");
        // the hidden op's note is computed against the *intermediate*
        // config (hidden still 16 after attn_expand)
        assert!(notes[1].scaling.as_deref().unwrap().contains("sqrt(16/32)"));
        assert_eq!(notes[1].zero_init.len(), 5);
    }

    #[test]
    fn identity_plan_is_inert() {
        let c = cfg();
        let plan = ExpansionPlan::identity(&c);
        assert!(plan.is_identity());
        assert_eq!(plan.target_config(), &c);
        assert_eq!(plan.param_delta(), 0);
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(1), 0.05);
        let before = params.clone();
        let mut opt = Optimizer::new(&TrainConfig::default(), &params);
        plan.apply_train(&mut params, &mut opt, &big(), &mut Pcg32::seeded(2)).unwrap();
        assert_eq!(params, before, "identity apply must not touch the store");
        assert!(plan.summary().contains("identity"));
    }

    #[test]
    fn plan_json_carries_roundtrippable_ops() {
        let plan = ExpansionPlan::new(&cfg(), all_six()).unwrap();
        let j = plan.to_json();
        assert_eq!(
            j.req("param_delta").unwrap().as_i64().unwrap() as usize,
            plan.param_delta()
        );
        let ops_json = j.req("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops_json.len(), 6);
        for (v, op) in ops_json.iter().zip(plan.ops()) {
            assert_eq!(&GrowthOp::from_json(v).unwrap(), op);
        }
        assert_eq!(
            ModelConfig::from_json(j.req("to").unwrap()).unwrap(),
            *plan.target_config()
        );
        assert_eq!(j.req("constraints").unwrap().as_arr().unwrap().len(), 6);
    }

    #[test]
    fn plan_from_json_round_trips_and_cross_checks() {
        let plan = ExpansionPlan::new(&cfg(), all_six()).unwrap();
        let j = plan.to_json();
        let back = ExpansionPlan::from_json(&j).unwrap();
        assert_eq!(back, plan);
        // a tampered target config is rejected, not trusted
        let mut fields: Vec<(&str, Value)> = Vec::new();
        for key in ["from", "ops", "params_before", "params_after", "param_delta"] {
            fields.push((key, j.req(key).unwrap().clone()));
        }
        fields.push(("to", plan.from_config().to_json())); // wrong: claims no growth
        let tampered = Value::obj(fields);
        let err = ExpansionPlan::from_json(&tampered).unwrap_err().to_string();
        assert!(err.contains("recorded target"), "{err}");
        // a tampered param count is rejected too
        let mut fields: Vec<(&str, Value)> = Vec::new();
        for key in ["from", "to", "ops"] {
            fields.push((key, j.req(key).unwrap().clone()));
        }
        fields.push(("params_after", Value::num(1.0)));
        let err = ExpansionPlan::from_json(&Value::obj(fields)).unwrap_err().to_string();
        assert!(err.contains("params_after"), "{err}");
    }

    // ---- apply seam ------------------------------------------------------

    #[test]
    fn apply_plan_rejects_wrong_source_without_mutating() {
        let c = cfg();
        let other = ModelConfig { mlp: 48, ..c };
        let plan = ExpansionPlan::new(&other, vec![GrowthOp::Mlp { p: 96 }]).unwrap();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(3), 0.05);
        let before = params.clone();
        let err =
            params.apply_plan(&plan, &big(), &mut Pcg32::seeded(4)).unwrap_err().to_string();
        assert!(err.contains("built from"), "{err}");
        assert_eq!(params, before, "failed validation must leave the store untouched");
    }

    #[test]
    fn apply_train_expands_params_and_moments_together() {
        let c = cfg();
        let tcfg = TrainConfig { optimizer: OptimKind::Adam, ..Default::default() };
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let mut opt = Optimizer::new(&tcfg, &params);
        // give the moments some history
        let grads: Vec<_> = params.tensors().to_vec();
        opt.step(&mut params, &grads).unwrap();
        let plan = ExpansionPlan::new(&c, all_six()).unwrap();
        plan.apply_train(&mut params, &mut opt, &big(), &mut Pcg32::seeded(6)).unwrap();
        assert_eq!(params.config(), plan.target_config());
        assert_eq!(params.num_scalars(), plan.params_after());
        opt.validate_against(&params).unwrap();
        // and stepping still works post-surgery
        let grads: Vec<_> = params.tensors().to_vec();
        opt.step(&mut params, &grads).unwrap();
    }

    #[test]
    fn apply_probed_gates_on_preservation_and_stages_a_copy() {
        let c = cfg();
        let mut rng = Pcg32::seeded(7);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let probe: Vec<Vec<u32>> =
            (0..2).map(|_| (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect()).collect();
        let plan = ExpansionPlan::new(&c, vec![GrowthOp::Mlp { p: 64 }]).unwrap();

        // theorem-respecting surgery passes, source untouched
        let out = plan.apply_probed(&params, &big(), &mut Pcg32::seeded(8), &probe, 1e-4).unwrap();
        assert!(out.probe_delta <= 1e-4);
        assert_eq!(out.params.config(), plan.target_config());
        assert_eq!(params.config(), &c, "apply_probed must stage, not mutate");
        assert!(out.surgery_ms >= 0.0);

        // constraint-violating surgery is rejected by the built-in probe
        let violate =
            ExpandOptions { init: Init::Normal(0.5), zero_constrained: false, ..Default::default() };
        let err = plan
            .apply_probed(&params, &violate, &mut Pcg32::seeded(8), &probe, 1e-4)
            .unwrap_err()
            .to_string();
        assert!(err.contains("rejected"), "{err}");
        assert_eq!(params.config(), &c);
    }

    #[test]
    fn staged_kv_rides_a_plan() {
        let c = cfg();
        let mut rng = Pcg32::seeded(9);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let mut cache = crate::serve::kv::KvCache::new(&c);
        for t in [1u32, 2, 3] {
            model::forward_incremental(&c, &params, &mut cache, t).unwrap();
        }
        let plan = ExpansionPlan::new(
            &c,
            vec![GrowthOp::Hidden { h: 24 }, GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top }],
        )
        .unwrap();
        let new_params = plan.materialize(&params, &big(), &mut Pcg32::seeded(10)).unwrap();
        let mut staged = StagedKv { cache: cache.clone(), new_params: &new_params };
        staged.apply_plan(&plan, &big(), &mut Pcg32::seeded(11)).unwrap();
        assert_eq!(staged.cache.config(), plan.target_config());
        assert_eq!(staged.cache.len(), cache.len());
        // the original cache is untouched (staging semantics)
        assert_eq!(cache.config(), &c);
    }

    // ---- satellite: composed-plan property test -------------------------

    #[test]
    fn prop_random_candidate_compositions_preserve_and_land_on_prediction() {
        // random valid sequences drawn from expand::candidate_ops at each
        // intermediate config, composed into ONE plan: (a) function is
        // preserved within the probe tolerance, (b) the store lands
        // exactly on the plan's predicted ModelConfig and param count.
        let base = ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 8, seq: 8, vocab: 16 };
        Runner::new("plan-candidate-composability", 12).run(
            |rng| {
                let n_ops = 1 + rng.below(3);
                let mut cfg = base;
                let mut ops = Vec::new();
                for _ in 0..n_ops {
                    let cands = candidate_ops(&cfg);
                    let op = cands[rng.below(cands.len())].clone();
                    cfg = op.apply_to_config(&cfg).unwrap();
                    ops.push(op);
                }
                (ops, rng.next_u64())
            },
            |(ops, seed)| {
                let plan = ExpansionPlan::new(&base, ops.clone()).map_err(|e| e.to_string())?;
                let mut rng = Pcg32::seeded(*seed);
                let params = ParamStore::init(&base, &mut rng, 0.05);
                let toks: Vec<Vec<u32>> =
                    vec![(0..base.seq).map(|_| rng.below(base.vocab) as u32).collect()];
                let before = model::forward(&base, &params, &toks).map_err(|e| e.to_string())?;
                let grown =
                    plan.materialize(&params, &big(), &mut rng).map_err(|e| e.to_string())?;
                // (b) exact landing on the prediction
                if grown.config() != plan.target_config() {
                    return Err(format!(
                        "landed on {:?}, predicted {:?}",
                        grown.config(),
                        plan.target_config()
                    ));
                }
                if grown.num_scalars() != plan.params_after() {
                    return Err(format!(
                        "{} scalars, predicted {}",
                        grown.num_scalars(),
                        plan.params_after()
                    ));
                }
                // (a) preservation within the probe tolerance
                let after =
                    model::forward(grown.config(), &grown, &toks).map_err(|e| e.to_string())?;
                let d = model::max_logit_delta(&before, &after).map_err(|e| e.to_string())?;
                if d <= PRESERVE_TOL {
                    Ok(())
                } else {
                    Err(format!("max|Δ| = {d} over {:?}", ops))
                }
            },
        );
    }

    #[test]
    fn flops_estimate_is_monotone_in_every_dim() {
        let c = cfg();
        let base = est_fwd_flops_per_token(&c);
        for op in candidate_ops(&c) {
            let grown = op.apply_to_config(&c).unwrap();
            assert!(
                est_fwd_flops_per_token(&grown) > base,
                "{op:?} did not grow the FLOPs estimate"
            );
        }
    }
}
