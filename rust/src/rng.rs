//! Deterministic random number generation (PCG32 + Box–Muller normals).
//!
//! One small generator shared by parameter init, synthetic-data synthesis,
//! and the property-testing harness, so that *every* stochastic component
//! of the framework is reproducible from a single `u64` seed. (The offline
//! crate set has no `rand`; `rand_core` alone ships no generator.)

/// PCG32 (O'Neill 2014): 64-bit state, 64-bit stream, 32-bit output.
///
/// Statistically solid for simulation workloads, 16 bytes of state, and
/// trivially portable — the Rust and (hypothetical) Python sides would
/// produce identical streams from identical seeds.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1, spare_normal: None };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (for per-component seeding).
    pub fn fork(&mut self, salt: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(seed ^ salt.wrapping_mul(0x9E3779B97F4A7C15), salt)
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 32 bits of resolution.
    pub fn uniform(&mut self) -> f64 {
        self.next_u32() as f64 / (u32::MAX as f64 + 1.0)
    }

    /// Uniform `usize` in `[0, bound)` (Lemire-style rejection-free modulo
    /// is overkill at our bounds; plain modulo bias is < 2^-32 * bound).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "below(0)");
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range({lo}, {hi})");
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Standard normal via Box–Muller (caches the paired sample).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to keep ln() finite.
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal as f32 with the given standard deviation.
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() as f32) * std
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        // 24 high bits -> exactly representable in f32
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Fill a slice with `std * N(0,1)` samples.
    ///
    /// Hot path for parameter init and expansion surgery (tens of millions
    /// of samples at large stages), so this uses the Marsaglia *polar*
    /// method in f32 — exact normals like Box–Muller, but transcendental
    /// cost is one `ln` + one `sqrt` per *pair* and no sin/cos. Measured
    /// ~6x faster than the scalar f64 Box–Muller path (EXPERIMENTS §Perf).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        let mut i = 0;
        while i + 1 < out.len() {
            let (a, b) = self.polar_pair();
            out[i] = a * std;
            out[i + 1] = b * std;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.polar_pair().0 * std;
        }
    }

    /// One pair of independent standard normals (Marsaglia polar method).
    #[inline]
    fn polar_pair(&mut self) -> (f32, f32) {
        loop {
            let u = 2.0 * self.uniform_f32() - 1.0;
            let v = 2.0 * self.uniform_f32() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                return (u * f, v * f);
            }
        }
    }

    /// Expose the raw generator state for checkpointing. The triple is
    /// everything [`Pcg32`] holds — `(state, inc, spare_normal)` — so
    /// [`Pcg32::from_parts`] reconstructs a generator whose future output
    /// stream is bit-identical to this one's.
    pub fn to_parts(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.spare_normal)
    }

    /// Rebuild a generator from [`Pcg32::to_parts`] output (resume path).
    /// Unlike [`Pcg32::new`] this performs no seeding scramble: the parts
    /// are installed verbatim.
    pub fn from_parts(state: u64, inc: u64, spare_normal: Option<f64>) -> Pcg32 {
        Pcg32 { state, inc, spare_normal }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive mass");
        let mut t = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a: Vec<u32> = (0..8).map({ let mut r = Pcg32::seeded(42); move |_| r.next_u32() }).collect();
        let b: Vec<u32> = (0..8).map({ let mut r = Pcg32::seeded(42); move |_| r.next_u32() }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!((0..4).map(|_| a.next_u32()).collect::<Vec<_>>(), (0..4).map(|_| b.next_u32()).collect::<Vec<_>>());
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(1, 0);
        let mut b = Pcg32::new(1, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Pcg32::seeded(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg32::seeded(4);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Pcg32::seeded(5);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let x = r.range(-2, 2);
            assert!((-2..=2).contains(&x));
            saw_lo |= x == -2;
            saw_hi |= x == 2;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(6);
        let n = 20000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy_bins() {
        let mut r = Pcg32::seeded(8);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] + counts[1]);
    }

    #[test]
    #[should_panic]
    fn below_zero_panics() {
        Pcg32::seeded(0).below(0);
    }

    #[test]
    fn parts_round_trip_is_bit_identical() {
        let mut r = Pcg32::new(42, 7);
        // advance through a normal() so the spare is populated (the
        // round-trip must preserve the Box–Muller cache, not just state)
        let _ = r.normal();
        let (state, inc, spare) = r.to_parts();
        assert!(spare.is_some(), "normal() must leave a cached spare");
        let mut restored = Pcg32::from_parts(state, inc, spare);
        for _ in 0..64 {
            assert_eq!(r.next_u32(), restored.next_u32());
            assert_eq!(r.normal().to_bits(), restored.normal().to_bits());
        }
    }
}
