//! Wall-clock benchmark harness (S14; no `criterion` offline).
//!
//! `cargo bench` targets in `benches/` are plain `harness = false` binaries
//! built on this module: warmup, fixed-iteration or fixed-duration timing,
//! and robust summary statistics (mean / p50 / p95 / p99 / min). Output is both
//! human-readable rows and machine-readable JSONL (consumed by
//! EXPERIMENTS.md tooling).

use std::time::{Duration, Instant};

use crate::json::Value;

/// Summary statistics over per-iteration wall times.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl Stats {
    fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = ns.iter().sum::<f64>() / ns.len() as f64;
        let pct = |p: f64| ns[((ns.len() as f64 - 1.0) * p).round() as usize];
        Stats {
            iters: ns.len(),
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            p99_ns: pct(0.99),
            min_ns: ns[0],
            max_ns: *ns.last().unwrap(),
        }
    }

    /// Mean in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    /// Throughput for `units` work items per iteration.
    pub fn per_second(&self, units: f64) -> f64 {
        units / (self.mean_ns / 1e9)
    }
}

/// One benchmark run: `warmup` untimed iterations then `iters` timed ones.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Stats::from_samples(samples)
}

/// Time-boxed benchmark: at least one iteration, stop after `budget`.
pub fn bench_for<T>(warmup: usize, budget: Duration, mut f: impl FnMut() -> T) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    loop {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed() >= budget {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Row reporter: aligned human output + JSONL side channel.
pub struct Reporter {
    bench_name: String,
    jsonl: Vec<String>,
}

impl Reporter {
    pub fn new(bench_name: impl Into<String>) -> Reporter {
        let name = bench_name.into();
        println!("\n=== bench: {name} ===");
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>10}",
            "case", "mean", "p50", "p95", "p99", "iters"
        );
        Reporter { bench_name: name, jsonl: Vec::new() }
    }

    /// Report a timed case; `extra` lands in the JSONL record.
    pub fn row(&mut self, case: &str, stats: &Stats, extra: Vec<(&str, Value)>) {
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>12} {:>10}",
            case,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
            fmt_ns(stats.p99_ns),
            stats.iters
        );
        let mut fields = vec![
            ("bench", Value::str(self.bench_name.clone())),
            ("case", Value::str(case)),
            ("mean_ns", Value::num(stats.mean_ns)),
            ("p50_ns", Value::num(stats.p50_ns)),
            ("p95_ns", Value::num(stats.p95_ns)),
            ("p99_ns", Value::num(stats.p99_ns)),
            ("iters", Value::num(stats.iters as f64)),
        ];
        fields.extend(extra);
        self.jsonl.push(Value::obj(fields).to_string());
    }

    /// Report a measurement that isn't a timing (e.g. a preservation error).
    pub fn value_row(&mut self, case: &str, metric: &str, value: f64, extra: Vec<(&str, Value)>) {
        println!("{:<44} {metric} = {value:.3e}", case);
        let mut fields = vec![
            ("bench", Value::str(self.bench_name.clone())),
            ("case", Value::str(case)),
            (metric, Value::num(value)),
        ];
        fields.extend(extra);
        self.jsonl.push(Value::obj(fields).to_string());
    }

    /// Append the JSONL records to `runs/bench.jsonl` (best-effort).
    pub fn flush(&self) {
        if self.jsonl.is_empty() {
            return;
        }
        let _ = std::fs::create_dir_all("runs");
        let path = "runs/bench.jsonl";
        let body = self.jsonl.join("\n") + "\n";
        use std::io::Write as _;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = f.write_all(body.as_bytes());
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_iters_and_positive_times() {
        let stats = bench(2, 10, || (0..1000).sum::<u64>());
        assert_eq!(stats.iters, 10);
        assert!(stats.mean_ns > 0.0);
        assert!(stats.min_ns <= stats.p50_ns && stats.p50_ns <= stats.p95_ns);
        assert!(stats.p95_ns <= stats.p99_ns && stats.p99_ns <= stats.max_ns);
    }

    #[test]
    fn bench_for_respects_budget_loosely() {
        let stats = bench_for(0, Duration::from_millis(20), || std::thread::sleep(Duration::from_millis(1)));
        assert!(stats.iters >= 1);
        assert!(stats.iters < 2000);
    }

    #[test]
    fn throughput_math() {
        let stats = Stats {
            iters: 1,
            mean_ns: 1e9,
            p50_ns: 1e9,
            p95_ns: 1e9,
            p99_ns: 1e9,
            min_ns: 1e9,
            max_ns: 1e9,
        };
        assert!((stats.per_second(500.0) - 500.0).abs() < 1e-9);
        assert!((stats.mean_ms() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
