//! Fixed-bucket latency histogram (S19a): the registry's distribution
//! primitive.
//!
//! Buckets are fixed at registration time, so the record path is two
//! relaxed atomic increments plus one CAS loop for the running sum — no
//! locks, no allocation, cheap enough for the decode/train hot paths. The
//! price is estimation error on quantiles: a quantile is interpolated
//! linearly inside the bucket holding its rank, so the estimate is exact
//! to within one bucket width (the property `tests/integration_obs.rs`
//! checks against a sorted-quantile oracle). Bucket counts are
//! *non-cumulative* in memory and cumulated only at snapshot time, which
//! keeps `observe` a single `fetch_add`.
//!
//! Exemplars (S20c): each bucket additionally keeps a *recent* request id
//! and observed value, written by `observe_with_exemplar` with plain
//! relaxed stores. Two racing writers may interleave id and value from
//! different observations; an exemplar is a debugging breadcrumb ("one
//! request that landed here recently"), not an invariant, so last-write
//! -wins per slot is the intended semantics and the cost stays at two
//! stores on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Default latency buckets in milliseconds: log-ish spacing from 50 µs to
/// 5 s, the range a decode tick / prompt prime / hot-swap can plausibly
/// span on this codebase's model sizes.
pub const LATENCY_MS_BOUNDS: [f64; 16] = [
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
    5000.0,
];

/// Lock-free histogram storage shared by every [`crate::obs::Histogram`]
/// handle of one series.
pub(crate) struct HistogramCore {
    /// Finite ascending upper bounds; bucket `i` counts `v <= bounds[i]`
    /// (minus the lower buckets). One extra +Inf bucket lives at the end
    /// of `buckets`.
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Running sum of observed values as `f64::to_bits` (CAS-updated).
    sum_bits: AtomicU64,
    /// Per-bucket recent request id, stored as `id + 1` so 0 means "no
    /// exemplar yet" (ids themselves start at 0).
    exemplar_ids: Vec<AtomicU64>,
    /// Per-bucket recent observed value as `f64::to_bits`.
    exemplar_vals: Vec<AtomicU64>,
}

impl HistogramCore {
    /// Panics on empty, non-finite or non-ascending bounds (registration
    /// is programmer-authored, so a bad bucket layout is a bug, not an
    /// input error).
    pub(crate) fn new(bounds: &[f64]) -> HistogramCore {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        for w in bounds.windows(2) {
            assert!(w[0] < w[1], "histogram bounds must be strictly ascending: {w:?}");
        }
        assert!(bounds.iter().all(|b| b.is_finite()), "histogram bounds must be finite");
        HistogramCore {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            exemplar_ids: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            exemplar_vals: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub(crate) fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Record one observation. NaN is dropped (a NaN latency is a caller
    /// bug; poisoning the sum would corrupt every later export).
    pub(crate) fn observe(&self, v: f64) {
        self.record(v, None);
    }

    /// Record one observation and remember `id` as the bucket's recent
    /// exemplar, linking the bucket back to a concrete request span.
    pub(crate) fn observe_with_exemplar(&self, v: f64, id: u64) {
        self.record(v, Some(id));
    }

    fn record(&self, v: f64, exemplar: Option<u64>) {
        if v.is_nan() {
            return;
        }
        // first bucket whose bound is >= v, i.e. Prometheus `le` semantics
        let idx = self.bounds.partition_point(|b| v > *b);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        if let Some(id) = exemplar {
            self.exemplar_ids[idx].store(id + 1, Ordering::Relaxed);
            self.exemplar_vals[idx].store(v.to_bits(), Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Point-in-time copy (buckets may lag `count` by in-flight
    /// observations; each bucket is individually consistent).
    pub(crate) fn snapshot(&self) -> HistogramSnapshot {
        let exemplars = self
            .exemplar_ids
            .iter()
            .zip(&self.exemplar_vals)
            .map(|(id, val)| {
                let raw = id.load(Ordering::Relaxed);
                (raw != 0).then(|| Exemplar {
                    request_id: raw - 1,
                    value: f64::from_bits(val.load(Ordering::Relaxed)),
                })
            })
            .collect();
        HistogramSnapshot {
            bounds: self.bounds.clone(),
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
            exemplars,
        }
    }
}

/// A recent observation pinned to a bucket: the request id that produced
/// it and the observed value. Rendered as an OpenMetrics-style
/// `# {request_id="..."} value` annotation on the bucket line, linking
/// aggregate tail latency back to one concrete span in the run store.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Exemplar {
    pub request_id: u64,
    pub value: f64,
}

/// Owned copy of a histogram's state: the quantile-estimation and
/// exposition input.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Finite ascending bucket upper bounds.
    pub bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `counts.len() == bounds.len()+1`
    /// with the final entry the +Inf bucket.
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
    /// Recent exemplar per bucket (same indexing as `counts`); `None`
    /// where no exemplar-tagged observation has landed yet.
    pub exemplars: Vec<Option<Exemplar>>,
}

impl HistogramSnapshot {
    /// Cumulative count up to and including bucket `i` (the `le` value the
    /// exposition format wants).
    pub fn cumulative(&self) -> Vec<u64> {
        let mut cum = 0u64;
        self.counts
            .iter()
            .map(|c| {
                cum += c;
                cum
            })
            .collect()
    }

    /// Mean of all observations (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimate the `q`-quantile (`0 < q <= 1`) by locating the bucket
    /// holding rank `max(1, ceil(q*n))` and interpolating linearly inside
    /// it — the same rank convention as a sorted-array oracle
    /// `sorted[max(1, ceil(q*n)) - 1]`, so estimate and oracle always land
    /// in the same bucket and differ by at most that bucket's width.
    /// Ranks falling in the +Inf bucket clamp to the last finite bound.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).max(1).min(self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                if i >= self.bounds.len() {
                    // +Inf bucket: no upper edge to interpolate towards
                    return self.bounds[self.bounds.len() - 1];
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                return lo + (hi - lo) * ((rank - cum) as f64 / c as f64);
            }
            cum += c;
        }
        self.bounds[self.bounds.len() - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_fills_le_buckets_and_sum() {
        let h = HistogramCore::new(&[1.0, 10.0]);
        for v in [0.5, 1.0, 3.0, 100.0] {
            h.observe(v);
        }
        let s = h.snapshot();
        // le semantics: 1.0 lands in the first bucket, 100.0 in +Inf
        assert_eq!(s.counts, vec![2, 1, 1]);
        assert_eq!(s.cumulative(), vec![2, 3, 4]);
        assert_eq!(s.count, 4);
        assert!((s.sum - 104.5).abs() < 1e-12);
        assert!((s.mean() - 26.125).abs() < 1e-12);
    }

    #[test]
    fn nan_observations_are_dropped() {
        let h = HistogramCore::new(&[1.0]);
        h.observe(f64::NAN);
        h.observe(0.5);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        assert!(s.sum.is_finite());
    }

    #[test]
    fn quantile_interpolates_within_the_rank_bucket() {
        let h = HistogramCore::new(&[10.0, 20.0, 30.0]);
        // 10 observations spread 5 in (0,10], 5 in (10,20]
        for _ in 0..5 {
            h.observe(5.0);
        }
        for _ in 0..5 {
            h.observe(15.0);
        }
        let s = h.snapshot();
        // p50 rank = 5 -> last of the first bucket -> its upper edge
        assert!((s.quantile(0.5) - 10.0).abs() < 1e-12);
        // p100 rank = 10 -> last of the second bucket -> 20.0
        assert!((s.quantile(1.0) - 20.0).abs() < 1e-12);
        // monotone in q
        assert!(s.quantile(0.5) <= s.quantile(0.95));
        assert!(s.quantile(0.95) <= s.quantile(0.99));
    }

    #[test]
    fn quantile_edge_cases() {
        let empty = HistogramCore::new(&[1.0]).snapshot();
        assert_eq!(empty.quantile(0.5), 0.0);
        let h = HistogramCore::new(&[1.0, 2.0]);
        h.observe(1e9); // +Inf bucket only
        assert_eq!(h.snapshot().quantile(0.99), 2.0, "+Inf rank clamps to the last finite bound");
    }

    #[test]
    fn concurrent_observe_loses_nothing() {
        let h = std::sync::Arc::new(HistogramCore::new(&LATENCY_MS_BOUNDS));
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    for i in 0..1000 {
                        h.observe((t * 1000 + i) as f64 * 0.01);
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count, 4000);
        assert_eq!(snap.cumulative().last().copied(), Some(4000));
        assert!((snap.sum - (0..4000).map(|i| i as f64 * 0.01).sum::<f64>()).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn rejects_unsorted_bounds() {
        HistogramCore::new(&[2.0, 1.0]);
    }

    #[test]
    fn exemplars_track_recent_id_per_bucket() {
        let h = HistogramCore::new(&[1.0, 10.0]);
        h.observe(0.5); // no exemplar
        h.observe_with_exemplar(0.7, 0); // id 0 is representable (stored as id+1)
        h.observe_with_exemplar(5.0, 41);
        h.observe_with_exemplar(6.0, 42); // same bucket: last write wins
        let s = h.snapshot();
        assert_eq!(s.exemplars.len(), s.counts.len());
        assert_eq!(s.exemplars[0], Some(Exemplar { request_id: 0, value: 0.7 }));
        assert_eq!(s.exemplars[1], Some(Exemplar { request_id: 42, value: 6.0 }));
        assert_eq!(s.exemplars[2], None, "+Inf bucket never hit");
    }
}
