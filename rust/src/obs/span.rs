//! Per-request span records (S19e): one [`Span`] per served request
//! capturing where its wall time went.
//!
//! The serve engine drives a [`SpanTracker`] through the request
//! lifecycle: `on_submit` when a request enters the queue, `on_admit`
//! when the scheduler primes it into a slot (carrying the measured
//! prefill cost), `on_finish` when it completes or times out. The
//! finished [`Span`] is what feeds the phase-latency histograms and is
//! emitted as a `span` event to `events.jsonl`, giving offline tooling
//! the same per-request decomposition the live histograms aggregate.
//!
//! Phase accounting: `queue_ms` is the submit→admit wall time *minus*
//! the prefill cost (the prime happens inside `admit`, so a request's
//! admission timestamp already includes its own prefill), clamped at
//! zero; `decode_ms` is admit→finish; `total_ms` is submit→finish.

use std::collections::HashMap;
use std::time::Instant;

use crate::json::Value;

/// Completed request trace. Tick fields are scheduler tick indices; the
/// `_ms` fields are wall-clock phase durations.
#[derive(Clone, Debug)]
pub struct Span {
    pub id: u64,
    pub queued_tick: u64,
    pub admitted_tick: u64,
    pub finished_tick: u64,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub total_ms: f64,
    pub prompt_tokens: usize,
    pub generated: usize,
    /// Finish reason tag (`"max_tokens"` or `"timed_out"`).
    pub finish: &'static str,
}

impl Span {
    /// Flat field list for `RunLogger::event("span", ...)`.
    pub fn fields(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("id", Value::num(self.id as f64)),
            ("queued_tick", Value::num(self.queued_tick as f64)),
            ("admitted_tick", Value::num(self.admitted_tick as f64)),
            ("finished_tick", Value::num(self.finished_tick as f64)),
            ("queue_ms", Value::num(self.queue_ms)),
            ("prefill_ms", Value::num(self.prefill_ms)),
            ("decode_ms", Value::num(self.decode_ms)),
            ("total_ms", Value::num(self.total_ms)),
            ("prompt_tokens", Value::num(self.prompt_tokens as f64)),
            ("generated", Value::num(self.generated as f64)),
            ("finish", Value::str(self.finish)),
        ]
    }
}

/// In-flight request state between lifecycle callbacks.
struct OpenSpan {
    queued_tick: u64,
    queued_at: Instant,
    admitted_tick: u64,
    admitted_at: Option<Instant>,
    prefill_ms: f64,
    prompt_tokens: usize,
}

/// Tracks open request spans by id; owned by the serve engine.
#[derive(Default)]
pub struct SpanTracker {
    open: HashMap<u64, OpenSpan>,
}

impl SpanTracker {
    pub fn new() -> SpanTracker {
        SpanTracker::default()
    }

    /// Number of requests currently tracked (queued or in flight).
    pub fn open_count(&self) -> usize {
        self.open.len()
    }

    /// Request `id` entered the queue at scheduler tick `tick`.
    pub fn on_submit(&mut self, id: u64, tick: u64) {
        self.open.insert(
            id,
            OpenSpan {
                queued_tick: tick,
                queued_at: Instant::now(),
                admitted_tick: tick,
                admitted_at: None,
                prefill_ms: 0.0,
                prompt_tokens: 0,
            },
        );
    }

    /// Request `id` was primed into a slot; `prefill_ms` is the measured
    /// prime cost, already elapsed by the time this is called.
    pub fn on_admit(&mut self, id: u64, tick: u64, prompt_tokens: usize, prefill_ms: f64) {
        if let Some(open) = self.open.get_mut(&id) {
            open.admitted_tick = tick;
            open.admitted_at = Some(Instant::now());
            open.prefill_ms = prefill_ms;
            open.prompt_tokens = prompt_tokens;
        }
    }

    /// Request `id` finished; returns the completed span, or `None` for
    /// ids this tracker never saw (e.g. metrics were enabled mid-run).
    pub fn on_finish(
        &mut self,
        id: u64,
        tick: u64,
        generated: usize,
        finish: &'static str,
    ) -> Option<Span> {
        let open = self.open.remove(&id)?;
        let now = Instant::now();
        let total_ms = now.duration_since(open.queued_at).as_secs_f64() * 1e3;
        let (admit_ms, decode_ms) = match open.admitted_at {
            Some(at) => {
                let admit_ms = at.duration_since(open.queued_at).as_secs_f64() * 1e3;
                (admit_ms, now.duration_since(at).as_secs_f64() * 1e3)
            }
            // never admitted (timed out in queue): all time is queue time
            None => (total_ms + open.prefill_ms, 0.0),
        };
        Some(Span {
            id,
            queued_tick: open.queued_tick,
            admitted_tick: open.admitted_tick,
            finished_tick: tick,
            queue_ms: (admit_ms - open.prefill_ms).max(0.0),
            prefill_ms: open.prefill_ms,
            decode_ms,
            total_ms,
            prompt_tokens: open.prompt_tokens,
            generated,
            finish,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_produces_consistent_phases() {
        let mut t = SpanTracker::new();
        t.on_submit(7, 3);
        assert_eq!(t.open_count(), 1);
        t.on_admit(7, 5, 12, 0.0);
        let span = t.on_finish(7, 9, 20, "max_tokens").unwrap();
        assert_eq!(t.open_count(), 0);
        assert_eq!(
            (span.id, span.queued_tick, span.admitted_tick, span.finished_tick),
            (7, 3, 5, 9)
        );
        assert_eq!((span.prompt_tokens, span.generated, span.finish), (12, 20, "max_tokens"));
        assert!(span.queue_ms >= 0.0);
        assert!(span.total_ms >= span.decode_ms);
    }

    #[test]
    fn prefill_is_subtracted_from_queue_time() {
        let mut t = SpanTracker::new();
        t.on_submit(1, 0);
        // claim a prefill cost far larger than the real elapsed time:
        // queue_ms must clamp at zero rather than go negative
        t.on_admit(1, 1, 4, 1e6);
        let span = t.on_finish(1, 2, 1, "max_tokens").unwrap();
        assert_eq!(span.queue_ms, 0.0);
        assert_eq!(span.prefill_ms, 1e6);
    }

    #[test]
    fn never_admitted_request_charges_queue_only() {
        let mut t = SpanTracker::new();
        t.on_submit(2, 0);
        let span = t.on_finish(2, 4, 0, "timed_out").unwrap();
        assert_eq!(span.decode_ms, 0.0);
        assert_eq!(span.finish, "timed_out");
        assert!(span.queue_ms >= 0.0);
    }

    #[test]
    fn unknown_id_yields_none() {
        let mut t = SpanTracker::new();
        assert!(t.on_finish(99, 0, 0, "max_tokens").is_none());
    }

    #[test]
    fn span_fields_are_flat_json() {
        let mut t = SpanTracker::new();
        t.on_submit(1, 0);
        t.on_admit(1, 0, 3, 0.1);
        let span = t.on_finish(1, 1, 2, "max_tokens").unwrap();
        let fields = span.fields();
        assert_eq!(fields.len(), 11);
        assert_eq!(fields[0].0, "id");
        assert_eq!(fields[10].0, "finish");
    }
}
