//! Per-request span records (S19e): one [`Span`] per served request
//! capturing where its wall time went.
//!
//! The serve engine drives a [`SpanTracker`] through the request
//! lifecycle: `on_submit` when a request enters the queue, `on_admit`
//! when the scheduler primes it into a slot (carrying the measured
//! prefill cost), `on_finish` when it completes or times out. The
//! finished [`Span`] is what feeds the phase-latency histograms and is
//! emitted as a `span` event to `events.jsonl`, giving offline tooling
//! the same per-request decomposition the live histograms aggregate.
//!
//! Phase accounting: `queue_ms` is the submit→admit wall time *minus*
//! the prefill cost (the prime happens inside `admit`, so a request's
//! admission timestamp already includes its own prefill), clamped at
//! zero; `decode_ms` is admit→finish; `total_ms` is submit→finish.
//!
//! Live export (S20b): a [`SpanRing`] is the bounded hand-off between
//! the engine's span path and the `/spans` chunked-streaming HTTP route
//! ([`crate::obs::http::MetricsServer`]). Finished spans are pushed as
//! JSONL lines; slow or absent consumers cost the *oldest* buffered
//! spans (counted by [`SpanRing::dropped`], surfaced as the
//! `texpand_spans_dropped_total` counter), never the serving loop.

use std::collections::{HashMap, VecDeque};
use std::sync::Mutex;
use std::time::Instant;

use crate::json::Value;

/// Completed request trace. Tick fields are scheduler tick indices; the
/// `_ms` fields are wall-clock phase durations.
#[derive(Clone, Debug)]
pub struct Span {
    pub id: u64,
    pub queued_tick: u64,
    pub admitted_tick: u64,
    pub finished_tick: u64,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    pub total_ms: f64,
    pub prompt_tokens: usize,
    pub generated: usize,
    /// Finish reason tag (`"max_tokens"` or `"timed_out"`).
    pub finish: &'static str,
}

impl Span {
    /// Flat field list for `RunLogger::event("span", ...)`.
    pub fn fields(&self) -> Vec<(&'static str, Value)> {
        vec![
            ("id", Value::num(self.id as f64)),
            ("queued_tick", Value::num(self.queued_tick as f64)),
            ("admitted_tick", Value::num(self.admitted_tick as f64)),
            ("finished_tick", Value::num(self.finished_tick as f64)),
            ("queue_ms", Value::num(self.queue_ms)),
            ("prefill_ms", Value::num(self.prefill_ms)),
            ("decode_ms", Value::num(self.decode_ms)),
            ("total_ms", Value::num(self.total_ms)),
            ("prompt_tokens", Value::num(self.prompt_tokens as f64)),
            ("generated", Value::num(self.generated as f64)),
            ("finish", Value::str(self.finish)),
        ]
    }
}

/// In-flight request state between lifecycle callbacks.
struct OpenSpan {
    queued_tick: u64,
    queued_at: Instant,
    admitted_tick: u64,
    admitted_at: Option<Instant>,
    prefill_ms: f64,
    prompt_tokens: usize,
}

/// Tracks open request spans by id; owned by the serve engine.
#[derive(Default)]
pub struct SpanTracker {
    open: HashMap<u64, OpenSpan>,
}

impl SpanTracker {
    pub fn new() -> SpanTracker {
        SpanTracker::default()
    }

    /// Number of requests currently tracked (queued or in flight).
    pub fn open_count(&self) -> usize {
        self.open.len()
    }

    /// Request `id` entered the queue at scheduler tick `tick`.
    pub fn on_submit(&mut self, id: u64, tick: u64) {
        self.open.insert(
            id,
            OpenSpan {
                queued_tick: tick,
                queued_at: Instant::now(),
                admitted_tick: tick,
                admitted_at: None,
                prefill_ms: 0.0,
                prompt_tokens: 0,
            },
        );
    }

    /// Request `id` was primed into a slot; `prefill_ms` is the measured
    /// prime cost, already elapsed by the time this is called.
    pub fn on_admit(&mut self, id: u64, tick: u64, prompt_tokens: usize, prefill_ms: f64) {
        if let Some(open) = self.open.get_mut(&id) {
            open.admitted_tick = tick;
            open.admitted_at = Some(Instant::now());
            open.prefill_ms = prefill_ms;
            open.prompt_tokens = prompt_tokens;
        }
    }

    /// Request `id` finished; returns the completed span, or `None` for
    /// ids this tracker never saw (e.g. metrics were enabled mid-run).
    pub fn on_finish(
        &mut self,
        id: u64,
        tick: u64,
        generated: usize,
        finish: &'static str,
    ) -> Option<Span> {
        let open = self.open.remove(&id)?;
        let now = Instant::now();
        let total_ms = now.duration_since(open.queued_at).as_secs_f64() * 1e3;
        let (admit_ms, decode_ms) = match open.admitted_at {
            Some(at) => {
                let admit_ms = at.duration_since(open.queued_at).as_secs_f64() * 1e3;
                (admit_ms, now.duration_since(at).as_secs_f64() * 1e3)
            }
            // never admitted (timed out in queue): all time is queue time
            None => (total_ms + open.prefill_ms, 0.0),
        };
        Some(Span {
            id,
            queued_tick: open.queued_tick,
            admitted_tick: open.admitted_tick,
            finished_tick: tick,
            queue_ms: (admit_ms - open.prefill_ms).max(0.0),
            prefill_ms: open.prefill_ms,
            decode_ms,
            total_ms,
            prompt_tokens: open.prompt_tokens,
            generated,
            finish,
        })
    }
}

/// Interior state of a [`SpanRing`]: sequence number of the oldest
/// buffered line plus the lines themselves.
struct RingInner {
    first_seq: u64,
    buf: VecDeque<String>,
}

/// Bounded ring of serialized span lines shared between the serve
/// engine (producer) and `/spans` streaming connections (consumers).
///
/// Each pushed line gets a monotonically increasing sequence number;
/// consumers poll with [`SpanRing::read_from`] holding their own cursor,
/// so any number of readers can tail independently. When the buffer is
/// full the *oldest* line is evicted and the drop counter bumped — the
/// producer never blocks and memory stays bounded regardless of
/// consumer speed.
pub struct SpanRing {
    cap: usize,
    inner: Mutex<RingInner>,
}

impl SpanRing {
    /// A ring holding at most `cap` lines (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> SpanRing {
        SpanRing {
            cap: cap.max(1),
            inner: Mutex::new(RingInner { first_seq: 0, buf: VecDeque::new() }),
        }
    }

    /// Append one span line. Returns `true` if an old line was evicted
    /// to make room (callers count that as a dropped span).
    pub fn push(&self, line: String) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let mut dropped = false;
        if inner.buf.len() == self.cap {
            inner.buf.pop_front();
            inner.first_seq += 1;
            dropped = true;
        }
        inner.buf.push_back(line);
        dropped
    }

    /// Lines with sequence numbers `>= from`, plus the cursor to pass
    /// next time. A reader that fell behind the eviction horizon is
    /// skipped forward to the oldest retained line (the gap is exactly
    /// what the drop counter accounts for).
    pub fn read_from(&self, from: u64) -> (Vec<String>, u64) {
        let inner = self.inner.lock().unwrap();
        let next_seq = inner.first_seq + inner.buf.len() as u64;
        let start = from.max(inner.first_seq);
        let skip = (start - inner.first_seq) as usize;
        let lines = inner.buf.iter().skip(skip).cloned().collect();
        (lines, next_seq)
    }

    /// Number of lines currently buffered.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_produces_consistent_phases() {
        let mut t = SpanTracker::new();
        t.on_submit(7, 3);
        assert_eq!(t.open_count(), 1);
        t.on_admit(7, 5, 12, 0.0);
        let span = t.on_finish(7, 9, 20, "max_tokens").unwrap();
        assert_eq!(t.open_count(), 0);
        assert_eq!(
            (span.id, span.queued_tick, span.admitted_tick, span.finished_tick),
            (7, 3, 5, 9)
        );
        assert_eq!((span.prompt_tokens, span.generated, span.finish), (12, 20, "max_tokens"));
        assert!(span.queue_ms >= 0.0);
        assert!(span.total_ms >= span.decode_ms);
    }

    #[test]
    fn prefill_is_subtracted_from_queue_time() {
        let mut t = SpanTracker::new();
        t.on_submit(1, 0);
        // claim a prefill cost far larger than the real elapsed time:
        // queue_ms must clamp at zero rather than go negative
        t.on_admit(1, 1, 4, 1e6);
        let span = t.on_finish(1, 2, 1, "max_tokens").unwrap();
        assert_eq!(span.queue_ms, 0.0);
        assert_eq!(span.prefill_ms, 1e6);
    }

    #[test]
    fn never_admitted_request_charges_queue_only() {
        let mut t = SpanTracker::new();
        t.on_submit(2, 0);
        let span = t.on_finish(2, 4, 0, "timed_out").unwrap();
        assert_eq!(span.decode_ms, 0.0);
        assert_eq!(span.finish, "timed_out");
        assert!(span.queue_ms >= 0.0);
    }

    #[test]
    fn unknown_id_yields_none() {
        let mut t = SpanTracker::new();
        assert!(t.on_finish(99, 0, 0, "max_tokens").is_none());
    }

    #[test]
    fn span_fields_are_flat_json() {
        let mut t = SpanTracker::new();
        t.on_submit(1, 0);
        t.on_admit(1, 0, 3, 0.1);
        let span = t.on_finish(1, 1, 2, "max_tokens").unwrap();
        let fields = span.fields();
        assert_eq!(fields.len(), 11);
        assert_eq!(fields[0].0, "id");
        assert_eq!(fields[10].0, "finish");
    }

    #[test]
    fn ring_read_from_tracks_cursor() {
        let ring = SpanRing::new(8);
        assert!(!ring.push("a".into()));
        assert!(!ring.push("b".into()));
        let (lines, next) = ring.read_from(0);
        assert_eq!(lines, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(next, 2);
        // cursor points past the end: nothing new
        let (lines, next) = ring.read_from(next);
        assert!(lines.is_empty());
        assert_eq!(next, 2);
        ring.push("c".into());
        let (lines, next) = ring.read_from(next);
        assert_eq!(lines, vec!["c".to_string()]);
        assert_eq!(next, 3);
    }

    #[test]
    fn ring_overflow_evicts_oldest_and_reports_drop() {
        let ring = SpanRing::new(2);
        assert!(!ring.push("a".into()));
        assert!(!ring.push("b".into()));
        assert!(ring.push("c".into())); // evicts "a"
        assert_eq!(ring.len(), 2);
        // a reader still at cursor 0 skips ahead past the eviction
        let (lines, next) = ring.read_from(0);
        assert_eq!(lines, vec!["b".to_string(), "c".to_string()]);
        assert_eq!(next, 3);
    }

    #[test]
    fn ring_cap_clamps_to_one() {
        let ring = SpanRing::new(0);
        assert!(!ring.push("a".into()));
        assert!(ring.push("b".into()));
        let (lines, _) = ring.read_from(0);
        assert_eq!(lines, vec!["b".to_string()]);
    }
}
