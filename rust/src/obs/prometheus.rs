//! Prometheus text exposition (S19c): render a registry snapshot in the
//! text format version 0.0.4.
//!
//! The format contract (what `tests/integration_obs.rs` parses back):
//!
//! * every family emits `# HELP <name> <help>` then `# TYPE <name> <kind>`
//!   before any of its samples;
//! * counters/gauges emit one `<name>{<labels>} <value>` line per series
//!   (no braces when unlabelled);
//! * histograms emit **cumulative** `<name>_bucket{le="<bound>"}` lines in
//!   ascending bound order ending with `le="+Inf"` (== `_count`), then
//!   `<name>_sum` and `<name>_count`;
//! * help text escapes `\` and newline; label values escape `\`, `"` and
//!   newline;
//! * non-finite values render as `NaN` / `+Inf` / `-Inf`;
//! * bucket lines carrying an exemplar append an OpenMetrics-style
//!   annotation ` # {request_id="<id>"} <observed value>` — prometheus
//!   0.0.4 parsers treat everything after `#` on a sample line as a
//!   comment, so plain scrapers stay compatible while the annotation
//!   links a bucket to a concrete span in the run store.
//!
//! Families render in registration order and series in sorted label
//! order, so output is deterministic for golden assertions.

use crate::obs::registry::{FamilySnapshot, MetricsRegistry, SeriesValue};

/// Render the full exposition document for `registry`.
pub fn render(registry: &MetricsRegistry) -> String {
    render_families(&registry.snapshot())
}

/// Render pre-taken family snapshots (split out for tests).
pub fn render_families(families: &[FamilySnapshot]) -> String {
    let mut out = String::new();
    for fam in families {
        out.push_str(&format!("# HELP {} {}\n", fam.name, escape_help(&fam.help)));
        out.push_str(&format!("# TYPE {} {}\n", fam.name, fam.kind.name()));
        for series in &fam.series {
            match &series.value {
                SeriesValue::Counter(v) => {
                    out.push_str(&format!("{}{} {v}\n", fam.name, labels(&series.labels, None)));
                }
                SeriesValue::Gauge(v) => {
                    out.push_str(&format!(
                        "{}{} {}\n",
                        fam.name,
                        labels(&series.labels, None),
                        fmt_value(*v)
                    ));
                }
                SeriesValue::Histogram(h) => {
                    let cum = h.cumulative();
                    for (i, (bound, c)) in h.bounds.iter().zip(&cum).enumerate() {
                        out.push_str(&format!(
                            "{}_bucket{} {c}{}\n",
                            fam.name,
                            labels(&series.labels, Some(&fmt_value(*bound))),
                            exemplar_suffix(h.exemplars.get(i))
                        ));
                    }
                    let total = cum.last().copied().unwrap_or(0);
                    out.push_str(&format!(
                        "{}_bucket{} {total}{}\n",
                        fam.name,
                        labels(&series.labels, Some("+Inf")),
                        exemplar_suffix(h.exemplars.get(h.bounds.len()))
                    ));
                    out.push_str(&format!(
                        "{}_sum{} {}\n",
                        fam.name,
                        labels(&series.labels, None),
                        fmt_value(h.sum)
                    ));
                    out.push_str(&format!(
                        "{}_count{} {}\n",
                        fam.name,
                        labels(&series.labels, None),
                        h.count
                    ));
                }
            }
        }
    }
    out
}

/// OpenMetrics-style exemplar annotation for one bucket (empty when the
/// bucket has none).
fn exemplar_suffix(ex: Option<&Option<crate::obs::histogram::Exemplar>>) -> String {
    match ex {
        Some(Some(e)) => {
            format!(" # {{request_id=\"{}\"}} {}", e.request_id, fmt_value(e.value))
        }
        _ => String::new(),
    }
}

/// Render a label set as `{k="v",...}`, optionally appending the
/// histogram `le` label; empty label sets render as nothing.
fn labels(pairs: &[(String, String)], le: Option<&str>) -> String {
    if pairs.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        pairs.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Escape a HELP line payload: backslash and newline.
fn escape_help(s: &str) -> String {
    s.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Escape a label value: backslash, double quote and newline.
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Format a sample value: non-finite spellings per the format, shortest
/// round-trip `f64` otherwise.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_render_with_type_headers() {
        let reg = MetricsRegistry::new();
        reg.counter("requests_total", "Total requests").add(7);
        reg.gauge("queue_depth", "Queued requests").set(2.5);
        let text = render(&reg);
        assert!(text.contains("# HELP requests_total Total requests\n"));
        assert!(text.contains("# TYPE requests_total counter\n"));
        assert!(text.contains("requests_total 7\n"));
        assert!(text.contains("# TYPE queue_depth gauge\n"));
        assert!(text.contains("queue_depth 2.5\n"));
    }

    #[test]
    fn labels_render_sorted_and_escaped() {
        let reg = MetricsRegistry::new();
        reg.counter_with("c_total", "c", &[("b", "plain"), ("a", "q\"uote\\slash\nline")]).inc();
        let text = render(&reg);
        assert!(
            text.contains("c_total{a=\"q\\\"uote\\\\slash\\nline\",b=\"plain\"} 1\n"),
            "{text}"
        );
    }

    #[test]
    fn histogram_renders_cumulative_buckets_sum_count() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("lat_ms", "Latency", &[1.0, 5.0]);
        h.observe(0.5);
        h.observe(3.0);
        h.observe(100.0);
        let text = render(&reg);
        assert!(text.contains("# TYPE lat_ms histogram\n"));
        assert!(text.contains("lat_ms_bucket{le=\"1\"} 1\n"));
        assert!(text.contains("lat_ms_bucket{le=\"5\"} 2\n"));
        assert!(text.contains("lat_ms_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("lat_ms_sum 104.5\n"));
        assert!(text.contains("lat_ms_count 3\n"));
    }

    #[test]
    fn exemplar_annotations_attach_to_bucket_lines() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("lat_ms", "Latency", &[1.0, 5.0]);
        h.observe(0.5); // no exemplar on the first bucket
        h.observe_with_exemplar(3.0, 17);
        let text = render(&reg);
        assert!(text.contains("lat_ms_bucket{le=\"1\"} 1\n"), "{text}");
        assert!(text.contains("lat_ms_bucket{le=\"5\"} 2 # {request_id=\"17\"} 3\n"), "{text}");
        // cumulative +Inf line carries no exemplar (nothing landed there)
        assert!(text.contains("lat_ms_bucket{le=\"+Inf\"} 2\n"), "{text}");
    }

    #[test]
    fn non_finite_values_use_format_spellings() {
        assert_eq!(fmt_value(f64::NAN), "NaN");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(0.25), "0.25");
    }

    #[test]
    fn help_escapes_newlines() {
        let reg = MetricsRegistry::new();
        reg.gauge("g", "line one\nline two \\ done").set(1.0);
        let text = render(&reg);
        assert!(text.contains("# HELP g line one\\nline two \\\\ done\n"));
    }
}
