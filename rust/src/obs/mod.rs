//! Live observability layer (S19/S20): metrics registry, Prometheus text
//! exposition over HTTP, per-request span tracing, live span streaming,
//! and the durable run store.
//!
//! Everything here is hand-rolled on `std` — no prometheus/hyper/tracing
//! crates — and offline-friendly. The pieces:
//!
//! * [`registry`] — [`MetricsRegistry`] of named counter/gauge/histogram
//!   families with optional labels; handles are `Arc`-backed atomics, so
//!   the record path never takes the registry lock. [`global()`] is the
//!   process-wide instance the CLI exposes.
//! * [`histogram`] — fixed-bucket latency histogram with p50/p95/p99
//!   estimation ([`LATENCY_MS_BOUNDS`] is the shared bucket layout) and
//!   per-bucket [`Exemplar`] request ids.
//! * [`prometheus`] — [`render`] a registry snapshot in text exposition
//!   format 0.0.4, with OpenMetrics-style exemplar annotations.
//! * [`http`] — [`MetricsServer`], a `std::net` listener serving
//!   `/metrics` + `/healthz` + `/spans` (+ `/quitz` for CI), the
//!   matching [`http_get`] client used by `texpand scrape`,
//!   [`http_stream_lines`] for tailing the chunked `/spans` stream, and
//!   the hardened request parser ([`read_http_request`], size caps +
//!   400/413 answers) shared with the serve front-end, plus
//!   [`http_post_stream`], the streaming POST client behind
//!   `texpand loadgen`.
//! * [`span`] — [`SpanTracker`]/[`Span`]: per-request
//!   queued→prefill→decode→finish phase records on the serve path, and
//!   [`SpanRing`], the bounded buffer `/spans` streams from.
//! * [`store`] — [`RunStore`]: append-only ingestion of run event logs
//!   into `runs/.store/` with aggregate [`RunStats`] per run; backs
//!   `texpand runs` and `texpand report`.
//!
//! Design notes live in DESIGN.md §14–§15.

pub mod histogram;
pub mod http;
pub mod prometheus;
pub mod registry;
pub mod span;
pub mod store;

pub use histogram::{Exemplar, HistogramSnapshot, LATENCY_MS_BOUNDS};
pub use http::{
    http_get, http_post_stream, http_stream_lines, read_http_request, HttpParseError, HttpRequest,
    MetricsServer, PostStreamOutcome,
};
pub use prometheus::render;
pub use registry::{
    global, Counter, FamilySnapshot, Gauge, Histogram, MetricKind, MetricsRegistry, SeriesSnapshot,
    SeriesValue,
};
pub use span::{Span, SpanRing, SpanTracker};
pub use store::{CompactReport, IngestReport, RunStats, RunStore};
