//! Live observability layer (S19): metrics registry, Prometheus text
//! exposition over HTTP, and per-request span tracing.
//!
//! Everything here is hand-rolled on `std` — no prometheus/hyper/tracing
//! crates — and offline-friendly. The pieces:
//!
//! * [`registry`] — [`MetricsRegistry`] of named counter/gauge/histogram
//!   families with optional labels; handles are `Arc`-backed atomics, so
//!   the record path never takes the registry lock. [`global()`] is the
//!   process-wide instance the CLI exposes.
//! * [`histogram`] — fixed-bucket latency histogram with p50/p95/p99
//!   estimation ([`LATENCY_MS_BOUNDS`] is the shared bucket layout).
//! * [`prometheus`] — [`render`] a registry snapshot in text exposition
//!   format 0.0.4.
//! * [`http`] — [`MetricsServer`], a `std::net` listener serving
//!   `/metrics` + `/healthz` (+ `/quitz` for CI), and the matching
//!   [`http_get`] client used by `texpand scrape`.
//! * [`span`] — [`SpanTracker`]/[`Span`]: per-request
//!   queued→prefill→decode→finish phase records on the serve path.
//!
//! Design notes live in DESIGN.md §14.

pub mod histogram;
pub mod http;
pub mod prometheus;
pub mod registry;
pub mod span;

pub use histogram::{HistogramSnapshot, LATENCY_MS_BOUNDS};
pub use http::{http_get, MetricsServer};
pub use prometheus::render;
pub use registry::{
    global, Counter, FamilySnapshot, Gauge, Histogram, MetricKind, MetricsRegistry, SeriesSnapshot,
    SeriesValue,
};
pub use span::{Span, SpanTracker};
