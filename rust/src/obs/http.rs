//! Minimal HTTP/1.1 face for the metrics registry (S19d; no hyper/axum in
//! the offline crate set).
//!
//! [`MetricsServer::bind`] spawns one background thread running a
//! nonblocking accept loop; connections are handled serially on that
//! thread (a scrape endpoint has one client — the collector — so
//! per-connection threads would buy nothing). Routes:
//!
//! * `GET /metrics`  — Prometheus text exposition of the bound registry;
//! * `GET /healthz`  — liveness probe, `ok`;
//! * `GET /quitz`    — sets a quit flag the owning process can poll
//!   ([`MetricsServer::wait_for_quit`]) — the hook `ci.sh` uses to release
//!   a lingering smoke run without killing it;
//! * `GET /spans`    — chunked-streaming JSONL tail of the span ring
//!   (only when bound with [`MetricsServer::bind_with_spans`]); each
//!   finished request span is one chunk. Unlike the other routes this one
//!   is long-lived, so it runs on its own detached thread — the serial
//!   accept loop stays free to answer `/metrics` while a tail client is
//!   attached, and a client that stops reading is disconnected by the
//!   write timeout rather than wedging anything;
//! * anything else   — `404` (unknown path) or `405` (non-GET).
//!
//! Binding port `0` picks a free port; [`MetricsServer::local_addr`]
//! reports it. [`http_get`] is the matching `std::net` client (used by
//! `texpand scrape` and the integration tests) so CI needs no curl;
//! [`http_stream_lines`] is the chunked-decoding tail client behind
//! `texpand scrape --spans`; [`http_post_stream`] is the streaming POST
//! client the loadgen drives `POST /v1/generate` with.
//!
//! Request parsing is hardened and shared with the serve front-end
//! ([`read_http_request`]): request-line/header/body sizes are capped
//! ([`MAX_REQUEST_LINE_BYTES`] / [`MAX_HEADER_BYTES`] /
//! [`MAX_BODY_BYTES`]), `Content-Length` must be well-formed and
//! unambiguous, and every rejection is answered with a 400/413 instead of
//! a silently dropped connection.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::obs::prometheus;
use crate::obs::registry::MetricsRegistry;
use crate::obs::span::SpanRing;

/// How long one connection may take to deliver its request / accept our
/// response before being dropped. Scrapes are local and tiny.
const IO_TIMEOUT: Duration = Duration::from_millis(500);
/// Accept-loop poll interval (the listener is nonblocking).
const POLL: Duration = Duration::from_millis(10);

/// Background `/metrics` + `/healthz` HTTP listener over a registry.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    quit: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving `registry` on a background thread. `/spans` answers 404;
    /// use [`MetricsServer::bind_with_spans`] to enable live span export.
    pub fn bind(addr: &str, registry: Arc<MetricsRegistry>) -> Result<MetricsServer> {
        MetricsServer::bind_with_spans(addr, registry, None)
    }

    /// [`MetricsServer::bind`] plus an optional span ring: when `spans`
    /// is `Some`, `GET /spans` streams its contents as chunked JSONL.
    pub fn bind_with_spans(
        addr: &str,
        registry: Arc<MetricsRegistry>,
        spans: Option<Arc<SpanRing>>,
    ) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Serve(format!("metrics listener bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Serve(format!("metrics listener local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Serve(format!("metrics listener nonblocking: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let quit = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            let quit = quit.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // best-effort: a broken scrape connection must
                            // never take the serving process down
                            let _ = handle_conn(stream, &registry, &quit, &spans, &stop);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(POLL);
                        }
                        Err(_) => std::thread::sleep(POLL),
                    }
                }
            })
        };
        Ok(MetricsServer { addr: local, stop, quit, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client has requested `GET /quitz`.
    pub fn quit_requested(&self) -> bool {
        self.quit.load(Ordering::Relaxed)
    }

    /// Block until `/quitz` is hit or `timeout` elapses; returns whether
    /// quit was requested.
    pub fn wait_for_quit(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        while start.elapsed() < timeout {
            if self.quit_requested() {
                return true;
            }
            std::thread::sleep(POLL);
        }
        self.quit_requested()
    }

    /// Stop the accept loop and join the listener thread.
    pub fn shutdown(self) {
        // Drop does the work; the method exists so call sites read as
        // intent rather than as an unused-variable drop.
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// Read one request, route it, write one response, close. The `/spans`
/// route is the exception: it hands the stream to a detached streaming
/// thread and returns immediately so the accept loop stays responsive.
fn handle_conn(
    mut stream: TcpStream,
    registry: &MetricsRegistry,
    quit: &AtomicBool,
    spans: &Option<Arc<SpanRing>>,
    stop: &Arc<AtomicBool>,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let req = match read_http_request(&mut stream)? {
        Ok(req) => req,
        Err(e) => {
            // hardened parse: malformed or oversized requests get an
            // explicit status instead of a silently dropped connection
            return write_response(
                &mut stream,
                e.status_line(),
                "text/plain; charset=utf-8",
                &format!("{}\n", e.message()),
            );
        }
    };
    let method = req.method.as_str();
    let path = req.path.as_str();
    if method == "GET" && path == "/spans" {
        if let Some(ring) = spans {
            let ring = ring.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let _ = stream_spans(stream, &ring, &stop);
            });
            return Ok(());
        }
        // fall through to the 404 arm: this server has no span ring
    }
    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain; charset=utf-8", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" => {
                ("200 OK", "text/plain; version=0.0.4; charset=utf-8", prometheus::render(registry))
            }
            "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
            "/quitz" => {
                quit.store(true, Ordering::Relaxed);
                ("200 OK", "text/plain; charset=utf-8", "bye\n".to_string())
            }
            "/spans" => {
                ("404 Not Found", "text/plain; charset=utf-8", "span export not enabled\n".to_string())
            }
            _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
        }
    };
    write_response(&mut stream, status, content_type, &body)
}

/// Write one complete non-chunked HTTP response and flush.
pub(crate) fn write_response(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Stream the span ring over `stream` as chunked JSONL until the client
/// disconnects (any write error — including the write timeout when the
/// client stops reading) or the server's stop flag is set (clean 0-chunk
/// terminator). Each span line becomes one chunk, so a tail client sees
/// spans as they finish rather than per flush.
fn stream_spans(
    mut stream: TcpStream,
    ring: &SpanRing,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    stream.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: application/jsonl; charset=utf-8\r\n\
          Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
    )?;
    let mut cursor = 0u64; // start at the oldest retained span: tailers see the backlog
    loop {
        if stop.load(Ordering::Relaxed) {
            stream.write_all(b"0\r\n\r\n")?;
            return stream.flush();
        }
        let (lines, next) = ring.read_from(cursor);
        cursor = next;
        if lines.is_empty() {
            std::thread::sleep(POLL);
            continue;
        }
        for line in &lines {
            // chunk payload is the span line plus its newline
            stream.write_all(format!("{:x}\r\n", line.len() + 1).as_bytes())?;
            stream.write_all(line.as_bytes())?;
            stream.write_all(b"\n\r\n")?;
        }
        stream.flush()?;
    }
}

/// Cap on the request line (`GET /path HTTP/1.1`): longer is a 400.
pub const MAX_REQUEST_LINE_BYTES: usize = 8 * 1024;
/// Cap on the whole request head (request line + headers): longer is a 400.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (`Content-Length`): larger is a 413 — read
/// nothing of it, just answer and close.
pub const MAX_BODY_BYTES: usize = 256 * 1024;

/// A fully-read inbound HTTP request: request line, headers and body.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header `(name, value)` pairs in arrival order, names as sent.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header value with this name (ASCII case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Why an inbound request was rejected at the parse layer; maps onto an
/// HTTP status so the connection gets an answer instead of a silent drop.
#[derive(Clone, Debug)]
pub enum HttpParseError {
    /// Malformed or oversized head, malformed `Content-Length`, truncated
    /// request — `400 Bad Request`.
    BadRequest(String),
    /// Declared body larger than [`MAX_BODY_BYTES`] — `413 Payload Too
    /// Large` (answered without reading the body).
    PayloadTooLarge(String),
}

impl HttpParseError {
    pub fn status(&self) -> u16 {
        match self {
            HttpParseError::BadRequest(_) => 400,
            HttpParseError::PayloadTooLarge(_) => 413,
        }
    }

    pub fn status_line(&self) -> &'static str {
        match self {
            HttpParseError::BadRequest(_) => "400 Bad Request",
            HttpParseError::PayloadTooLarge(_) => "413 Payload Too Large",
        }
    }

    pub fn message(&self) -> &str {
        match self {
            HttpParseError::BadRequest(m) | HttpParseError::PayloadTooLarge(m) => m,
        }
    }
}

/// Read and parse one full HTTP request from `stream`, enforcing the
/// size caps. The outer `io::Result` is transport failure (timeout,
/// reset); the inner `Result` is protocol rejection — the caller answers
/// those with [`HttpParseError::status_line`] instead of dropping the
/// connection. Shared by the metrics listener and the serve front-end.
pub fn read_http_request(
    stream: &mut TcpStream,
) -> std::io::Result<std::result::Result<HttpRequest, HttpParseError>> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 2048];
    // 1. the head, up to the blank line
    let head_end = loop {
        if let Some(i) = find_subslice(&buf, b"\r\n\r\n") {
            break i + 4;
        }
        if find_subslice(&buf, b"\r\n").is_none() && buf.len() > MAX_REQUEST_LINE_BYTES {
            return Ok(Err(HttpParseError::BadRequest(format!(
                "request line exceeds {MAX_REQUEST_LINE_BYTES} bytes"
            ))));
        }
        if buf.len() > MAX_HEADER_BYTES {
            return Ok(Err(HttpParseError::BadRequest(format!(
                "request head exceeds {MAX_HEADER_BYTES} bytes"
            ))));
        }
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(Err(HttpParseError::BadRequest(
                "connection closed before a complete request head".into(),
            )));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    if request_line.len() > MAX_REQUEST_LINE_BYTES {
        return Ok(Err(HttpParseError::BadRequest(format!(
            "request line exceeds {MAX_REQUEST_LINE_BYTES} bytes"
        ))));
    }
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(path)) = (parts.next(), parts.next()) else {
        return Ok(Err(HttpParseError::BadRequest(format!(
            "malformed request line '{}'",
            request_line.chars().take(80).collect::<String>()
        ))));
    };
    // 2. headers
    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue; // the blank terminator
        }
        let Some((name, value)) = line.split_once(':') else {
            return Ok(Err(HttpParseError::BadRequest(format!(
                "malformed header line '{}'",
                line.chars().take(80).collect::<String>()
            ))));
        };
        headers.push((name.trim().to_string(), value.trim().to_string()));
    }
    // 3. body, by Content-Length (reject a malformed or ambiguous one)
    let mut content_length = 0usize;
    let mut seen_cl = false;
    for (n, v) in &headers {
        if n.eq_ignore_ascii_case("content-length") {
            let Ok(len) = v.parse::<usize>() else {
                return Ok(Err(HttpParseError::BadRequest(format!(
                    "malformed Content-Length '{v}'"
                ))));
            };
            if seen_cl && len != content_length {
                return Ok(Err(HttpParseError::BadRequest(
                    "conflicting Content-Length headers".into(),
                )));
            }
            content_length = len;
            seen_cl = true;
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Ok(Err(HttpParseError::PayloadTooLarge(format!(
            "declared body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"
        ))));
    }
    let mut body: Vec<u8> = buf[head_end..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Ok(Err(HttpParseError::BadRequest(format!(
                "connection closed mid-body ({} of {content_length} bytes)",
                body.len()
            ))));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(Ok(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body,
    }))
}

/// Tiny blocking HTTP GET returning `(status_code, body)`. `addr` is
/// `host:port`; the server side must close the connection after the
/// response (ours does), which is what bounds the read.
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<(u16, String)> {
    let mut stream = connect(addr, timeout)?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("read timeout: {e}")))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("write timeout: {e}")))?;
    let request = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream
        .write_all(request.as_bytes())
        .map_err(|e| Error::Serve(format!("send GET {path}: {e}")))?;
    let mut raw = String::new();
    stream
        .read_to_string(&mut raw)
        .map_err(|e| Error::Serve(format!("read GET {path} response: {e}")))?;
    let status = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| Error::Serve(format!("malformed HTTP response from {addr}")))?;
    let body = match raw.find("\r\n\r\n") {
        Some(i) => raw[i + 4..].to_string(),
        None => String::new(),
    };
    Ok((status, body))
}

/// Resolve and connect with actionable error messages: connection
/// refused and timeout — the two ways a scrape against a dead or wrong
/// address fails — say what to check instead of just the OS error.
fn connect(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let sock_addr = addr
        .to_socket_addrs()
        .map_err(|e| Error::Serve(format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| Error::Serve(format!("resolve {addr}: no addresses")))?;
    TcpStream::connect_timeout(&sock_addr, timeout).map_err(|e| {
        let hint = match e.kind() {
            std::io::ErrorKind::ConnectionRefused => {
                " (connection refused — is the server running on that address?)"
            }
            std::io::ErrorKind::TimedOut => {
                " (connection timed out — check the host/port and that the server is reachable)"
            }
            _ => "",
        };
        Error::Serve(format!("connect {addr}: {e}{hint}"))
    })
}

/// Streaming HTTP GET for chunked JSONL routes (`/spans`): decodes the
/// chunked body incrementally and invokes `on_line` per complete line.
///
/// Returns the number of lines delivered. Stops after `max_lines` when
/// given, on the server's terminating 0-chunk, on connection close, or —
/// because a live tail has no natural end — on a read timeout, which is
/// reported as a normal return rather than an error.
pub fn http_stream_lines(
    addr: &str,
    path: &str,
    timeout: Duration,
    max_lines: Option<usize>,
    on_line: &mut dyn FnMut(&str),
) -> Result<usize> {
    let mut stream = connect(addr, timeout)?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("read timeout: {e}")))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("write timeout: {e}")))?;
    let request = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream
        .write_all(request.as_bytes())
        .map_err(|e| Error::Serve(format!("send GET {path}: {e}")))?;

    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // headers first
    let header_end = loop {
        if let Some(i) = find_subslice(&buf, b"\r\n\r\n") {
            break i + 4;
        }
        if buf.len() > 64 * 1024 {
            return Err(Error::Serve(format!("oversized response head from {addr}")));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Error::Serve(format!("read GET {path} response head: {e}")))?;
        if n == 0 {
            return Err(Error::Serve(format!("{addr} closed before sending headers for {path}")));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    let status = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| Error::Serve(format!("malformed HTTP response from {addr}")))?;
    if status != 200 {
        let body_preview = String::from_utf8_lossy(&buf[header_end..]).trim().to_string();
        return Err(Error::Serve(format!("GET {path} on {addr}: HTTP {status} {body_preview}")));
    }
    if !head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        return Err(Error::Serve(format!("GET {path} on {addr}: not a chunked stream")));
    }
    buf.drain(..header_end);

    let mut body: Vec<u8> = Vec::new(); // decoded bytes awaiting a newline
    let mut count = 0usize;
    'outer: loop {
        // decode every complete chunk currently buffered
        loop {
            let Some(size_end) = find_subslice(&buf, b"\r\n") else { break };
            let size_str = String::from_utf8_lossy(&buf[..size_end]).trim().to_string();
            let size = usize::from_str_radix(&size_str, 16).map_err(|_| {
                Error::Serve(format!("bad chunk size '{size_str}' in {path} stream from {addr}"))
            })?;
            if size == 0 {
                break 'outer; // server's clean terminator
            }
            let frame = size_end + 2 + size + 2; // size line + payload + CRLF
            if buf.len() < frame {
                break; // partial chunk: read more first
            }
            body.extend_from_slice(&buf[size_end + 2..size_end + 2 + size]);
            buf.drain(..frame);
            while let Some(nl) = body.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = body.drain(..nl + 1).collect();
                on_line(String::from_utf8_lossy(&line[..nl]).as_ref());
                count += 1;
                if max_lines.is_some_and(|max| count >= max) {
                    break 'outer;
                }
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // connection closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            // a quiet tail is a normal way for a live stream to end
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                break;
            }
            Err(e) => return Err(Error::Serve(format!("read GET {path} stream: {e}"))),
        }
    }
    Ok(count)
}

/// What [`http_post_stream`] got back.
#[derive(Clone, Debug)]
pub struct PostStreamOutcome {
    pub status: u16,
    /// Decoded stream lines (chunked 200 responses; one NDJSON line per
    /// entry, also delivered incrementally through `on_line`).
    pub lines: Vec<String>,
    /// Non-streamed body (non-200 or non-chunked responses).
    pub body: String,
    /// `Retry-After` response header in seconds, when present (429s).
    pub retry_after: Option<u64>,
}

/// Blocking HTTP POST with incremental consumption of a chunked streaming
/// response — the client side of `POST /v1/generate`. `on_line` fires per
/// complete line *as it is decoded*, so callers can time first-token
/// arrival; the full set is also returned. Non-200 responses are not an
/// `Err` — the status and body come back in the outcome (a 429 with
/// `Retry-After` is an expected answer under overload, not a failure).
pub fn http_post_stream(
    addr: &str,
    path: &str,
    request_body: &str,
    timeout: Duration,
    on_line: &mut dyn FnMut(&str),
) -> Result<PostStreamOutcome> {
    let mut stream = connect(addr, timeout)?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("read timeout: {e}")))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("write timeout: {e}")))?;
    let request = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{request_body}",
        request_body.len()
    );
    stream
        .write_all(request.as_bytes())
        .map_err(|e| Error::Serve(format!("send POST {path}: {e}")))?;

    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let header_end = loop {
        if let Some(i) = find_subslice(&buf, b"\r\n\r\n") {
            break i + 4;
        }
        if buf.len() > 64 * 1024 {
            return Err(Error::Serve(format!("oversized response head from {addr}")));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| Error::Serve(format!("read POST {path} response head: {e}")))?;
        if n == 0 {
            return Err(Error::Serve(format!("{addr} closed before sending headers for {path}")));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    let status = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| Error::Serve(format!("malformed HTTP response from {addr}")))?;
    let lower = head.to_ascii_lowercase();
    let retry_after = lower
        .lines()
        .find_map(|l| l.strip_prefix("retry-after:"))
        .and_then(|v| v.trim().parse::<u64>().ok());
    buf.drain(..header_end);

    if status != 200 || !lower.contains("transfer-encoding: chunked") {
        // plain response: drain to close and hand the body back whole
        loop {
            match stream.read(&mut chunk) {
                Ok(0) => break,
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    break;
                }
                Err(e) => return Err(Error::Serve(format!("read POST {path} response: {e}"))),
            }
        }
        let body = String::from_utf8_lossy(&buf).to_string();
        return Ok(PostStreamOutcome { status, lines: Vec::new(), body, retry_after });
    }

    // chunked stream: decode incrementally, one callback per line
    let mut body: Vec<u8> = Vec::new();
    let mut lines: Vec<String> = Vec::new();
    'outer: loop {
        loop {
            let Some(size_end) = find_subslice(&buf, b"\r\n") else { break };
            let size_str = String::from_utf8_lossy(&buf[..size_end]).trim().to_string();
            let size = usize::from_str_radix(&size_str, 16).map_err(|_| {
                Error::Serve(format!("bad chunk size '{size_str}' in {path} stream from {addr}"))
            })?;
            if size == 0 {
                break 'outer;
            }
            let frame = size_end + 2 + size + 2;
            if buf.len() < frame {
                break;
            }
            body.extend_from_slice(&buf[size_end + 2..size_end + 2 + size]);
            buf.drain(..frame);
            while let Some(nl) = body.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = body.drain(..nl + 1).collect();
                let line = String::from_utf8_lossy(&line[..nl]).to_string();
                on_line(&line);
                lines.push(line);
            }
        }
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                break;
            }
            Err(e) => return Err(Error::Serve(format!("read POST {path} stream: {e}"))),
        }
    }
    Ok(PostStreamOutcome { status, lines, body: String::new(), retry_after })
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> (MetricsServer, Arc<MetricsRegistry>) {
        let reg = Arc::new(MetricsRegistry::new());
        reg.counter("http_test_total", "test counter").add(3);
        let srv = MetricsServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        (srv, reg)
    }

    #[test]
    fn serves_metrics_and_healthz() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let (status, body) = http_get(&addr, "/healthz", Duration::from_secs(2)).unwrap();
        assert_eq!((status, body.as_str()), (200, "ok\n"));
        let (status, body) = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("http_test_total 3\n"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn unknown_path_is_404_and_quitz_sets_flag() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let (status, _) = http_get(&addr, "/nope", Duration::from_secs(2)).unwrap();
        assert_eq!(status, 404);
        assert!(!srv.quit_requested());
        let (status, body) = http_get(&addr, "/quitz", Duration::from_secs(2)).unwrap();
        assert_eq!((status, body.as_str()), (200, "bye\n"));
        assert!(srv.wait_for_quit(Duration::from_secs(2)));
        srv.shutdown();
    }

    #[test]
    fn scrape_reflects_live_updates() {
        let (srv, reg) = server();
        let addr = srv.local_addr().to_string();
        reg.counter("http_test_total", "test counter").add(4);
        let (_, body) = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
        assert!(body.contains("http_test_total 7\n"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn connect_refused_error_says_what_to_check() {
        // bind-then-drop guarantees the port is closed (nothing listening)
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let err = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("connection refused"), "missing hint: {msg}");
        assert!(msg.contains("is the server running"), "missing hint: {msg}");
        // the streaming client shares the same connect path and hint
        let err = http_stream_lines(&addr, "/spans", Duration::from_secs(2), None, &mut |_| {})
            .unwrap_err();
        assert!(err.to_string().contains("connection refused"), "{err}");
    }

    #[test]
    fn spans_route_is_404_without_a_ring() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let (status, body) = http_get(&addr, "/spans", Duration::from_secs(2)).unwrap();
        assert_eq!(status, 404);
        assert!(body.contains("span export not enabled"), "{body}");
        srv.shutdown();
    }

    /// Write raw bytes at the server, half-close, and read the full
    /// response back — the harness for driving malformed requests that
    /// `http_get` could never produce.
    fn raw_roundtrip(addr: &str, payload: &[u8]) -> (u16, String) {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        s.set_write_timeout(Some(Duration::from_secs(2))).unwrap();
        s.write_all(payload).unwrap();
        let _ = s.shutdown(std::net::Shutdown::Write);
        let mut raw = String::new();
        let _ = s.read_to_string(&mut raw);
        let status =
            raw.split_whitespace().nth(1).and_then(|x| x.parse::<u16>().ok()).unwrap_or(0);
        let body = raw.find("\r\n\r\n").map(|i| raw[i + 4..].to_string()).unwrap_or_default();
        (status, body)
    }

    #[test]
    fn oversized_request_line_is_rejected_with_400() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_REQUEST_LINE_BYTES + 100));
        let (status, body) = raw_roundtrip(&addr, long.as_bytes());
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("request line"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn oversized_header_section_is_rejected_with_400() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let req = format!(
            "GET /healthz HTTP/1.1\r\nX-Big: {}\r\n\r\n",
            "b".repeat(MAX_HEADER_BYTES + 100)
        );
        let (status, body) = raw_roundtrip(&addr, req.as_bytes());
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("head exceeds"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn malformed_content_length_is_rejected_with_400() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let (status, body) =
            raw_roundtrip(&addr, b"POST /x HTTP/1.1\r\nContent-Length: banana\r\n\r\n");
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("Content-Length"), "{body}");
        // two disagreeing Content-Length headers are just as malformed
        let (status, body) = raw_roundtrip(
            &addr,
            b"POST /x HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 5\r\n\r\nabcd",
        );
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("conflicting"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn oversized_body_is_rejected_with_413_without_reading_it() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let req = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        // note: none of the declared body is ever sent — the server must
        // answer from the header alone
        let (status, body) = raw_roundtrip(&addr, req.as_bytes());
        assert_eq!(status, 413, "{body}");
        assert!(body.contains("cap"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn truncated_requests_are_rejected_with_400() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        // head cut off mid-line
        let (status, body) = raw_roundtrip(&addr, b"GET /metr");
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("closed before"), "{body}");
        // complete head, body shorter than declared
        let (status, body) =
            raw_roundtrip(&addr, b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc");
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("mid-body"), "{body}");
        // garbage request line
        let (status, body) = raw_roundtrip(&addr, b"NONSENSE\r\n\r\n");
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("malformed request line"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn post_client_reads_plain_responses_and_retry_after() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        // the metrics server answers POST with a 405; the streaming POST
        // client must surface that as an outcome, not an Err
        let out = http_post_stream(&addr, "/metrics", "{}", Duration::from_secs(2), &mut |_| {})
            .unwrap();
        assert_eq!(out.status, 405);
        assert!(out.lines.is_empty());
        assert!(out.body.contains("method not allowed"), "{}", out.body);
        assert_eq!(out.retry_after, None);
        srv.shutdown();
    }

    #[test]
    fn spans_route_streams_ring_lines_as_chunks() {
        let reg = Arc::new(MetricsRegistry::new());
        let ring = Arc::new(SpanRing::new(16));
        ring.push("{\"id\":1}".to_string());
        ring.push("{\"id\":2}".to_string());
        let srv = MetricsServer::bind_with_spans("127.0.0.1:0", reg, Some(ring.clone())).unwrap();
        let addr = srv.local_addr().to_string();
        // the third span arrives while the client is already tailing, so
        // reaching max_lines=3 proves live delivery, not just backlog
        let pusher = {
            let ring = ring.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(100));
                ring.push("{\"id\":3}".to_string());
            })
        };
        let mut lines = Vec::new();
        let n = http_stream_lines(&addr, "/spans", Duration::from_secs(5), Some(3), &mut |l| {
            lines.push(l.to_string());
        })
        .unwrap();
        pusher.join().unwrap();
        assert_eq!(n, 3);
        assert_eq!(lines, vec!["{\"id\":1}", "{\"id\":2}", "{\"id\":3}"]);
        srv.shutdown();
    }
}
