//! Minimal HTTP/1.1 face for the metrics registry (S19d; no hyper/axum in
//! the offline crate set).
//!
//! [`MetricsServer::bind`] spawns one background thread running a
//! nonblocking accept loop; connections are handled serially on that
//! thread (a scrape endpoint has one client — the collector — so
//! per-connection threads would buy nothing). Routes:
//!
//! * `GET /metrics`  — Prometheus text exposition of the bound registry;
//! * `GET /healthz`  — liveness probe, `ok`;
//! * `GET /quitz`    — sets a quit flag the owning process can poll
//!   ([`MetricsServer::wait_for_quit`]) — the hook `ci.sh` uses to release
//!   a lingering smoke run without killing it;
//! * anything else   — `404` (unknown path) or `405` (non-GET).
//!
//! Binding port `0` picks a free port; [`MetricsServer::local_addr`]
//! reports it. [`http_get`] is the matching `std::net` client (used by
//! `texpand scrape` and the integration tests) so CI needs no curl.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::obs::prometheus;
use crate::obs::registry::MetricsRegistry;

/// How long one connection may take to deliver its request / accept our
/// response before being dropped. Scrapes are local and tiny.
const IO_TIMEOUT: Duration = Duration::from_millis(500);
/// Accept-loop poll interval (the listener is nonblocking).
const POLL: Duration = Duration::from_millis(10);

/// Background `/metrics` + `/healthz` HTTP listener over a registry.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    quit: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving `registry` on a background thread.
    pub fn bind(addr: &str, registry: Arc<MetricsRegistry>) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Serve(format!("metrics listener bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Serve(format!("metrics listener local_addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Serve(format!("metrics listener nonblocking: {e}")))?;
        let stop = Arc::new(AtomicBool::new(false));
        let quit = Arc::new(AtomicBool::new(false));
        let handle = {
            let stop = stop.clone();
            let quit = quit.clone();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // best-effort: a broken scrape connection must
                            // never take the serving process down
                            let _ = handle_conn(stream, &registry, &quit);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(POLL);
                        }
                        Err(_) => std::thread::sleep(POLL),
                    }
                }
            })
        };
        Ok(MetricsServer { addr: local, stop, quit, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client has requested `GET /quitz`.
    pub fn quit_requested(&self) -> bool {
        self.quit.load(Ordering::Relaxed)
    }

    /// Block until `/quitz` is hit or `timeout` elapses; returns whether
    /// quit was requested.
    pub fn wait_for_quit(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        while start.elapsed() < timeout {
            if self.quit_requested() {
                return true;
            }
            std::thread::sleep(POLL);
        }
        self.quit_requested()
    }

    /// Stop the accept loop and join the listener thread.
    pub fn shutdown(self) {
        // Drop does the work; the method exists so call sites read as
        // intent rather than as an unused-variable drop.
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// Read one request, route it, write one response, close.
fn handle_conn(
    mut stream: TcpStream,
    registry: &MetricsRegistry,
    quit: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let request_line = read_request_line(&mut stream)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, content_type, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain; charset=utf-8", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" => {
                ("200 OK", "text/plain; version=0.0.4; charset=utf-8", prometheus::render(registry))
            }
            "/healthz" => ("200 OK", "text/plain; charset=utf-8", "ok\n".to_string()),
            "/quitz" => {
                quit.store(true, Ordering::Relaxed);
                ("200 OK", "text/plain; charset=utf-8", "bye\n".to_string())
            }
            _ => ("404 Not Found", "text/plain; charset=utf-8", "not found\n".to_string()),
        }
    };
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(response.as_bytes())?;
    stream.flush()
}

/// Read up to the end of the request head and return its first line. The
/// buffer is capped: a scrape request head has no business exceeding 8 KiB.
fn read_request_line(stream: &mut TcpStream) -> std::io::Result<String> {
    let mut buf = Vec::with_capacity(256);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
            break;
        }
    }
    let text = String::from_utf8_lossy(&buf);
    Ok(text.lines().next().unwrap_or("").to_string())
}

/// Tiny blocking HTTP GET returning `(status_code, body)`. `addr` is
/// `host:port`; the server side must close the connection after the
/// response (ours does), which is what bounds the read.
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> Result<(u16, String)> {
    let sock_addr = addr
        .to_socket_addrs()
        .map_err(|e| Error::Serve(format!("resolve {addr}: {e}")))?
        .next()
        .ok_or_else(|| Error::Serve(format!("resolve {addr}: no addresses")))?;
    let mut stream = TcpStream::connect_timeout(&sock_addr, timeout)
        .map_err(|e| Error::Serve(format!("connect {addr}: {e}")))?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("read timeout: {e}")))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| Error::Serve(format!("write timeout: {e}")))?;
    let request = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    stream
        .write_all(request.as_bytes())
        .map_err(|e| Error::Serve(format!("send GET {path}: {e}")))?;
    let mut raw = String::new();
    stream
        .read_to_string(&mut raw)
        .map_err(|e| Error::Serve(format!("read GET {path} response: {e}")))?;
    let status = raw
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| Error::Serve(format!("malformed HTTP response from {addr}")))?;
    let body = match raw.find("\r\n\r\n") {
        Some(i) => raw[i + 4..].to_string(),
        None => String::new(),
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> (MetricsServer, Arc<MetricsRegistry>) {
        let reg = Arc::new(MetricsRegistry::new());
        reg.counter("http_test_total", "test counter").add(3);
        let srv = MetricsServer::bind("127.0.0.1:0", reg.clone()).unwrap();
        (srv, reg)
    }

    #[test]
    fn serves_metrics_and_healthz() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let (status, body) = http_get(&addr, "/healthz", Duration::from_secs(2)).unwrap();
        assert_eq!((status, body.as_str()), (200, "ok\n"));
        let (status, body) = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("http_test_total 3\n"), "{body}");
        srv.shutdown();
    }

    #[test]
    fn unknown_path_is_404_and_quitz_sets_flag() {
        let (srv, _reg) = server();
        let addr = srv.local_addr().to_string();
        let (status, _) = http_get(&addr, "/nope", Duration::from_secs(2)).unwrap();
        assert_eq!(status, 404);
        assert!(!srv.quit_requested());
        let (status, body) = http_get(&addr, "/quitz", Duration::from_secs(2)).unwrap();
        assert_eq!((status, body.as_str()), (200, "bye\n"));
        assert!(srv.wait_for_quit(Duration::from_secs(2)));
        srv.shutdown();
    }

    #[test]
    fn scrape_reflects_live_updates() {
        let (srv, reg) = server();
        let addr = srv.local_addr().to_string();
        reg.counter("http_test_total", "test counter").add(4);
        let (_, body) = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
        assert!(body.contains("http_test_total 7\n"), "{body}");
        srv.shutdown();
    }
}
