//! Metric registry (S19b): named counter/gauge/histogram families.
//!
//! The registry is a `Mutex`-guarded table of **families** (name + help +
//! kind) each holding label-keyed **series**. The mutex is taken only at
//! registration and snapshot time: registering returns a cloneable handle
//! wrapping the series' `Arc`'d atomic storage, so the hot path
//! (`Counter::inc`, `Gauge::set`, `Histogram::observe`) is a relaxed
//! atomic op with no lock and no allocation. Call sites acquire handles
//! once (engine construction, segment start) and bump them per
//! tick/step — the same handle-then-bump shape as the Prometheus client
//! libraries.
//!
//! Re-registering an existing (name, labels) pair returns a handle to the
//! *same* storage, so independent subsystems sharing the process-global
//! registry ([`crate::obs::global`]) compose without coordination.
//! Registering a name under a different kind (or a histogram under
//! different buckets) panics: that is a programmer error the process
//! should not limp past, exactly like a malformed bucket layout.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::obs::histogram::{HistogramCore, HistogramSnapshot};

/// Metric family kind (drives the `# TYPE` exposition line).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    /// The exposition-format type keyword.
    pub fn name(&self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// Monotone counter handle (cloneable; clones share storage).
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge handle storing an `f64` as its bit pattern.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram handle (see [`crate::obs::histogram`]).
#[derive(Clone)]
pub struct Histogram(Arc<HistogramCore>);

impl Histogram {
    /// Record one observation (NaN is dropped).
    pub fn observe(&self, v: f64) {
        self.0.observe(v);
    }

    /// Record one observation and pin `request_id` as the landing
    /// bucket's recent exemplar (see [`crate::obs::histogram::Exemplar`]).
    pub fn observe_with_exemplar(&self, v: f64, request_id: u64) {
        self.0.observe_with_exemplar(v, request_id);
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        self.0.snapshot()
    }
}

type LabelSet = Vec<(String, String)>;

/// One family's series storage (all series of a family share a kind).
enum Series {
    Counter(HashMap<LabelSet, Arc<AtomicU64>>),
    Gauge(HashMap<LabelSet, Arc<AtomicU64>>),
    Histogram(Vec<f64>, HashMap<LabelSet, Arc<HistogramCore>>),
}

impl Series {
    fn kind(&self) -> MetricKind {
        match self {
            Series::Counter(_) => MetricKind::Counter,
            Series::Gauge(_) => MetricKind::Gauge,
            Series::Histogram(..) => MetricKind::Histogram,
        }
    }
}

struct Family {
    name: String,
    help: String,
    series: Series,
}

/// Process-wide metric table (see module docs). Cheap to share behind an
/// `Arc`; most code uses the [`crate::obs::global`] instance, tests build
/// their own for isolation (the test binary runs tests concurrently).
pub struct MetricsRegistry {
    families: Mutex<Vec<Family>>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { families: Mutex::new(Vec::new()) }
    }

    /// Register (or re-acquire) an unlabelled counter.
    pub fn counter(&self, name: &str, help: &str) -> Counter {
        self.counter_with(name, help, &[])
    }

    /// Register (or re-acquire) a counter series under `labels`.
    pub fn counter_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        let key = label_key(labels);
        let mut families = self.lock();
        let fam = find_or_insert(&mut families, name, help, MetricKind::Counter);
        let Series::Counter(map) = &mut fam.series else { unreachable!() };
        Counter(map.entry(key).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone())
    }

    /// Register (or re-acquire) an unlabelled gauge.
    pub fn gauge(&self, name: &str, help: &str) -> Gauge {
        self.gauge_with(name, help, &[])
    }

    /// Register (or re-acquire) a gauge series under `labels`.
    pub fn gauge_with(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        let key = label_key(labels);
        let mut families = self.lock();
        let fam = find_or_insert(&mut families, name, help, MetricKind::Gauge);
        let Series::Gauge(map) = &mut fam.series else { unreachable!() };
        Gauge(map.entry(key).or_insert_with(|| Arc::new(AtomicU64::new(0f64.to_bits()))).clone())
    }

    /// Register (or re-acquire) an unlabelled histogram with `bounds`
    /// bucket upper edges (finite, strictly ascending). A family's bounds
    /// are fixed by its first registration; re-registering with different
    /// bounds panics.
    pub fn histogram(&self, name: &str, help: &str, bounds: &[f64]) -> Histogram {
        self.histogram_with(name, help, bounds, &[])
    }

    /// Labelled [`MetricsRegistry::histogram`]. `le` is reserved for the
    /// bucket label and rejected.
    pub fn histogram_with(
        &self,
        name: &str,
        help: &str,
        bounds: &[f64],
        labels: &[(&str, &str)],
    ) -> Histogram {
        assert!(labels.iter().all(|(k, _)| *k != "le"), "label 'le' is reserved for buckets");
        let key = label_key(labels);
        let mut families = self.lock();
        let fam = find_or_insert(&mut families, name, help, MetricKind::Histogram);
        let Series::Histogram(fam_bounds, map) = &mut fam.series else { unreachable!() };
        if fam_bounds.is_empty() {
            *fam_bounds = bounds.to_vec();
        } else {
            assert_eq!(
                &fam_bounds[..],
                bounds,
                "histogram '{name}' re-registered with new buckets"
            );
        }
        Histogram(map.entry(key).or_insert_with(|| Arc::new(HistogramCore::new(bounds))).clone())
    }

    /// Point-in-time copy of every family for exposition, in registration
    /// order with series sorted by label set (deterministic output).
    pub fn snapshot(&self) -> Vec<FamilySnapshot> {
        let families = self.lock();
        families
            .iter()
            .map(|fam| {
                let mut series: Vec<SeriesSnapshot> = match &fam.series {
                    Series::Counter(map) => map
                        .iter()
                        .map(|(k, v)| SeriesSnapshot {
                            labels: k.clone(),
                            value: SeriesValue::Counter(v.load(Ordering::Relaxed)),
                        })
                        .collect(),
                    Series::Gauge(map) => map
                        .iter()
                        .map(|(k, v)| SeriesSnapshot {
                            labels: k.clone(),
                            value: SeriesValue::Gauge(f64::from_bits(v.load(Ordering::Relaxed))),
                        })
                        .collect(),
                    Series::Histogram(_, map) => map
                        .iter()
                        .map(|(k, v)| SeriesSnapshot {
                            labels: k.clone(),
                            value: SeriesValue::Histogram(v.snapshot()),
                        })
                        .collect(),
                };
                series.sort_by(|a, b| a.labels.cmp(&b.labels));
                FamilySnapshot {
                    name: fam.name.clone(),
                    help: fam.help.clone(),
                    kind: fam.series.kind(),
                    series,
                }
            })
            .collect()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Vec<Family>> {
        // a panic while holding the registration lock leaves plain data
        // in a valid state; don't cascade the poison into every exporter
        self.families.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// One family in a [`MetricsRegistry::snapshot`].
pub struct FamilySnapshot {
    pub name: String,
    pub help: String,
    pub kind: MetricKind,
    pub series: Vec<SeriesSnapshot>,
}

/// One labelled series within a family snapshot.
pub struct SeriesSnapshot {
    pub labels: Vec<(String, String)>,
    pub value: SeriesValue,
}

/// A series' sampled value.
pub enum SeriesValue {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

fn label_key(labels: &[(&str, &str)]) -> LabelSet {
    for (k, _) in labels {
        assert!(valid_label_name(k), "invalid metric label name '{k}'");
    }
    let mut key: LabelSet = labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    key.sort();
    key
}

fn find_or_insert<'a>(
    families: &'a mut Vec<Family>,
    name: &str,
    help: &str,
    kind: MetricKind,
) -> &'a mut Family {
    assert!(valid_metric_name(name), "invalid metric name '{name}'");
    if let Some(i) = families.iter().position(|f| f.name == name) {
        let fam = &mut families[i];
        assert_eq!(
            fam.series.kind(),
            kind,
            "metric '{name}' already registered as a {}",
            fam.series.kind().name()
        );
        return fam;
    }
    let series = match kind {
        MetricKind::Counter => Series::Counter(HashMap::new()),
        MetricKind::Gauge => Series::Gauge(HashMap::new()),
        MetricKind::Histogram => Series::Histogram(Vec::new(), HashMap::new()),
    };
    families.push(Family { name: name.to_string(), help: help.to_string(), series });
    families.last_mut().expect("just pushed")
}

/// Exposition-format metric name: `[a-zA-Z_:][a-zA-Z0-9_:]*`.
pub fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Exposition-format label name: `[a-zA-Z_][a-zA-Z0-9_]*`.
pub fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

static GLOBAL: OnceLock<Arc<MetricsRegistry>> = OnceLock::new();

/// The process-global registry: what `texpand serve --metrics-addr`
/// exposes and what the train/serve/coordinator instrumentation points
/// publish into by default.
pub fn global() -> &'static Arc<MetricsRegistry> {
    GLOBAL.get_or_init(|| Arc::new(MetricsRegistry::new()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_share_storage_across_reregistration() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("requests_total", "requests");
        let b = reg.counter("requests_total", "requests");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = reg.gauge("depth", "queue depth");
        g.set(4.5);
        assert_eq!(reg.gauge("depth", "queue depth").get(), 4.5);
    }

    #[test]
    fn labelled_series_are_independent() {
        let reg = MetricsRegistry::new();
        let ok = reg.counter_with("decisions_total", "verdicts", &[("decision", "continue")]);
        let grow = reg.counter_with("decisions_total", "verdicts", &[("decision", "expand")]);
        ok.inc();
        ok.inc();
        grow.inc();
        assert_eq!(ok.get(), 2);
        assert_eq!(grow.get(), 1);
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].series.len(), 2);
    }

    #[test]
    fn label_order_does_not_split_series() {
        let reg = MetricsRegistry::new();
        let a = reg.counter_with("x_total", "x", &[("a", "1"), ("b", "2")]);
        let b = reg.counter_with("x_total", "x", &[("b", "2"), ("a", "1")]);
        a.inc();
        assert_eq!(b.get(), 1);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry::new();
        let _ = reg.counter("thing", "a counter");
        let _ = reg.gauge("thing", "now a gauge");
    }

    #[test]
    #[should_panic(expected = "invalid metric name")]
    fn bad_names_panic() {
        let _ = MetricsRegistry::new().counter("9starts-with-digit", "bad");
    }

    #[test]
    fn snapshot_is_deterministic_and_typed() {
        let reg = MetricsRegistry::new();
        reg.histogram("lat_ms", "latency", &[1.0, 2.0]).observe(1.5);
        reg.counter("c_total", "c").inc();
        let snap = reg.snapshot();
        assert_eq!(snap[0].name, "lat_ms");
        assert_eq!(snap[0].kind, MetricKind::Histogram);
        assert_eq!(snap[1].kind, MetricKind::Counter);
        match &snap[0].series[0].value {
            SeriesValue::Histogram(h) => assert_eq!(h.count, 1),
            _ => panic!("expected histogram value"),
        }
    }

    #[test]
    fn global_registry_is_a_singleton() {
        let a = global().clone();
        let c = a.counter("texpand_obs_registry_selftest_total", "test-only");
        c.inc();
        let before = c.get();
        global().counter("texpand_obs_registry_selftest_total", "test-only").inc();
        assert_eq!(c.get(), before + 1);
    }
}
