//! Run store (S20a): the durable, queryable home of run evidence.
//!
//! A run emits `runs/<name>/events.jsonl` (and benches append to
//! `runs/bench.jsonl`); both are write-side artifacts — buffered, owned
//! by the emitting process, gone from view the moment you want to ask
//! "what did expansion 2 of last week's run cost?". The [`RunStore`]
//! ingests them into `runs/.store/`:
//!
//! ```text
//! runs/.store/
//!   index.json            # per-run byte offsets + record counts (atomic rewrite)
//!   bench.jsonl           # ingested bench rows (append-only)
//!   <run>/records.jsonl   # ingested event lines, append-only
//!   <run>/summary.json    # aggregate RunStats, rewritten per ingest
//! ```
//!
//! **Append-only argument.** Source logs are append-only by contract
//! (`RunLogger` opens with `O_APPEND`), so ingestion is an offset cursor:
//! copy every *complete* (newline-terminated) line past the cursor,
//! advance the cursor by exactly those bytes. A torn tail line is left
//! for the next ingest; re-running ingest is idempotent. The store files
//! are themselves append-only, so a crash mid-ingest costs at most a
//! re-copy of the lines whose index update didn't land — duplicates are
//! impossible because the index is rewritten atomically (tmp + rename)
//! *after* the append and offsets only ever advance. The one exception:
//! a source file *shorter* than its cursor means the run name was reused
//! by a fresh run, and the store re-ingests that run from scratch.
//!
//! **Retention.** Event logs are small (one line per step/boundary/span),
//! but long-lived serve hosts accumulate runs without bound, so
//! [`RunStore::compact`] retires all but the newest `keep` runs' record
//! payloads: `records.jsonl` is deleted, `summary.json` (the aggregate
//! [`RunStats`]) survives, and the run is marked `compacted` in the
//! index. A compacted run whose source log hasn't changed ingests as a
//! no-op; if its source grows (or shrinks — name reuse), the run
//! re-ingests from scratch so the aggregate can never go silently stale.
//!
//! **Stats.** [`RunStore::stats`] folds the ingested records into a
//! [`RunStats`]: segments, the loss trajectory, every expansion with its
//! [`ExpansionPlan`] evidence (rebuilt and cross-checked through
//! [`ExpansionPlan::from_json`] — a tampered plan row fails loudly),
//! preservation-drift measurements per boundary, serve phase
//! percentiles, and span/decision counts. `texpand runs` and `texpand
//! report` are the CLI faces over this.

use std::collections::BTreeMap;
use std::io::Write;

use crate::error::{Error, Result};
use crate::expand::ExpansionPlan;
use crate::json::Value;
use crate::metrics::PhasePercentiles;

/// Handle on `<runs_root>/.store/`.
pub struct RunStore {
    runs_root: String,
    store_dir: String,
}

/// What one [`RunStore::ingest`] call did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct IngestReport {
    /// Records copied by this call.
    pub new_records: u64,
    /// Records in the store for this run after the call.
    pub total_records: u64,
    /// Source bytes consumed so far (the cursor).
    pub source_bytes: u64,
    /// Lines ingested so far that are not valid JSON (cumulative, like
    /// `total_records`). A crashed writer's torn tail never lands here —
    /// the cursor stops before it — so nonzero means the source log was
    /// corrupted in place (bit rot, truncated flush, manual edits).
    /// Ingestion keeps going; the damage is counted, not fatal.
    pub parse_errors: u64,
}

/// Per-run cursor state in `index.json`.
#[derive(Clone, Copy, Debug, Default)]
struct IndexEntry {
    events_bytes: u64,
    records: u64,
    parse_errors: u64,
    /// Records payload retired by [`RunStore::compact`]; the cursor and
    /// counts above still describe what *was* ingested.
    compacted: bool,
}

/// What one [`RunStore::compact`] call did.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CompactReport {
    /// Runs in the index when compaction ran.
    pub examined: usize,
    /// Runs whose record payload this call deleted.
    pub compacted: usize,
    /// Bytes of `records.jsonl` payload freed.
    pub bytes_freed: u64,
    /// Newest runs left intact (≤ `keep`).
    pub kept: usize,
}

type Index = BTreeMap<String, IndexEntry>;

impl RunStore {
    /// Open (creating if needed) the store under `runs_root`.
    pub fn open(runs_root: &str) -> Result<RunStore> {
        let store_dir = format!("{runs_root}/.store");
        std::fs::create_dir_all(&store_dir).map_err(|e| Error::io(&store_dir, e))?;
        Ok(RunStore { runs_root: runs_root.to_string(), store_dir })
    }

    /// The store directory (`<runs_root>/.store`).
    pub fn dir(&self) -> &str {
        &self.store_dir
    }

    /// Runs with ingested records, sorted by name.
    pub fn runs(&self) -> Result<Vec<String>> {
        Ok(self.load_index()?.0.keys().cloned().collect())
    }

    /// Ingest new complete lines of `<runs_root>/<run>/events.jsonl` and
    /// refresh the run's `summary.json`. Idempotent; safe to call on a
    /// live run (the torn tail line waits for the next call).
    pub fn ingest(&self, run: &str) -> Result<IngestReport> {
        let (mut index, bench_bytes) = self.load_index()?;
        let src = format!("{}/{run}/events.jsonl", self.runs_root);
        let data = std::fs::read(&src).map_err(|e| Error::io(&src, e))?;
        let entry = index.entry(run.to_string()).or_default();
        let run_dir = format!("{}/{run}", self.store_dir);
        std::fs::create_dir_all(&run_dir).map_err(|e| Error::io(&run_dir, e))?;
        let records_path = format!("{run_dir}/records.jsonl");
        if entry.compacted && (data.len() as u64) == entry.events_bytes {
            // compacted and the source hasn't moved: the retained
            // summary.json still describes the run — nothing to do
            return Ok(IngestReport {
                new_records: 0,
                total_records: entry.records,
                source_bytes: entry.events_bytes,
                parse_errors: entry.parse_errors,
            });
        }
        if entry.compacted || (data.len() as u64) < entry.events_bytes {
            // compacted source changed (the aggregate would go stale), or
            // the source shrank (run name reused): restart from scratch
            std::fs::write(&records_path, b"").map_err(|e| Error::io(&records_path, e))?;
            *entry = IndexEntry::default();
        }
        let new_records = append_complete_lines(&data, &records_path, entry)?;
        let report = IngestReport {
            new_records,
            total_records: entry.records,
            source_bytes: entry.events_bytes,
            parse_errors: entry.parse_errors,
        };
        self.save_index(&index, bench_bytes)?;
        if new_records > 0 {
            let stats = self.stats(run)?;
            let summary_path = format!("{run_dir}/summary.json");
            write_atomic(&summary_path, &format!("{}\n", stats.to_json().to_pretty()))?;
        }
        Ok(report)
    }

    /// Ingest every run directory under `runs_root` that has an
    /// `events.jsonl`, plus `bench.jsonl`. Returns per-run reports.
    pub fn ingest_all(&self) -> Result<Vec<(String, IngestReport)>> {
        let mut names = Vec::new();
        let entries =
            std::fs::read_dir(&self.runs_root).map_err(|e| Error::io(&self.runs_root, e))?;
        for entry in entries {
            let entry = entry.map_err(|e| Error::io(&self.runs_root, e))?;
            let name = entry.file_name().to_string_lossy().to_string();
            if name == ".store" {
                continue;
            }
            let events = format!("{}/{name}/events.jsonl", self.runs_root);
            if std::path::Path::new(&events).is_file() {
                names.push(name);
            }
        }
        names.sort();
        let mut reports = Vec::with_capacity(names.len());
        for name in names {
            let report = self.ingest(&name)?;
            reports.push((name, report));
        }
        self.ingest_bench()?;
        Ok(reports)
    }

    /// Ingest new complete lines of `<runs_root>/bench.jsonl` into
    /// `.store/bench.jsonl` (no-op when the source doesn't exist).
    pub fn ingest_bench(&self) -> Result<u64> {
        let src = format!("{}/bench.jsonl", self.runs_root);
        let data = match std::fs::read(&src) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(Error::io(&src, e)),
        };
        let (index, bench_bytes) = self.load_index()?;
        let dst = format!("{}/bench.jsonl", self.store_dir);
        let mut entry = IndexEntry { events_bytes: bench_bytes, ..Default::default() };
        if (data.len() as u64) < entry.events_bytes {
            std::fs::write(&dst, b"").map_err(|e| Error::io(&dst, e))?;
            entry.events_bytes = 0;
        }
        let new = append_complete_lines(&data, &dst, &mut entry)?;
        self.save_index(&index, entry.events_bytes)?;
        Ok(new)
    }

    /// Retire all but the newest `keep` runs' record payloads (module
    /// docs: summaries and cursors survive; a compacted run re-ingests
    /// from scratch only when its source log changes). Recency is the
    /// store-side `records.jsonl` mtime (ties broken by name), so "newest"
    /// means most recently ingested. Idempotent.
    pub fn compact(&self, keep: usize) -> Result<CompactReport> {
        let (mut index, bench_bytes) = self.load_index()?;
        let mut order: Vec<(String, std::time::SystemTime)> = index
            .keys()
            .map(|name| {
                let p = format!("{}/{name}/records.jsonl", self.store_dir);
                let mtime = std::fs::metadata(&p)
                    .and_then(|m| m.modified())
                    .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
                (name.clone(), mtime)
            })
            .collect();
        order.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        let mut report = CompactReport {
            examined: order.len(),
            kept: order.len().min(keep),
            ..Default::default()
        };
        for (name, _) in order.into_iter().skip(keep) {
            let entry = index.get_mut(&name).expect("name came from the index");
            if entry.compacted {
                continue;
            }
            let records = format!("{}/{name}/records.jsonl", self.store_dir);
            let bytes = std::fs::metadata(&records).map(|m| m.len()).unwrap_or(0);
            match std::fs::remove_file(&records) {
                Ok(()) => {}
                Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
                Err(e) => return Err(Error::io(&records, e)),
            }
            entry.compacted = true;
            report.compacted += 1;
            report.bytes_freed += bytes;
        }
        self.save_index(&index, bench_bytes)?;
        Ok(report)
    }

    /// Aggregate the ingested records of `run` (see [`RunStats`]).
    pub fn stats(&self, run: &str) -> Result<RunStats> {
        let path = format!("{}/{run}/records.jsonl", self.store_dir);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) => {
                let (index, _) = self.load_index()?;
                if index.get(run).is_some_and(|en| en.compacted) {
                    return Err(Error::Serve(format!(
                        "run '{run}' was compacted — {}/{run}/summary.json keeps the \
                         aggregate; it re-ingests automatically if its source log changes",
                        self.store_dir
                    )));
                }
                return Err(Error::io(
                    format!("{path} (run not ingested? try `texpand runs list`)"),
                    e,
                ));
            }
        };
        let mut stats = RunStats::new(run);
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match Value::parse(line) {
                Ok(v) => stats.absorb(&v),
                Err(_) => stats.malformed += 1,
            }
            stats.records += 1;
        }
        Ok(stats)
    }

    fn index_path(&self) -> String {
        format!("{}/index.json", self.store_dir)
    }

    fn load_index(&self) -> Result<(Index, u64)> {
        let path = self.index_path();
        if !std::path::Path::new(&path).is_file() {
            return Ok((Index::new(), 0));
        }
        let v = Value::load(&path)?;
        let mut index = Index::new();
        for (name, entry) in v.req("runs")?.as_obj()? {
            index.insert(
                name.clone(),
                IndexEntry {
                    events_bytes: entry.req("events_bytes")?.as_i64()? as u64,
                    records: entry.req("records")?.as_i64()? as u64,
                    // absent in pre-resilience indexes: default clean
                    parse_errors: entry
                        .get("parse_errors")
                        .and_then(|p| p.as_i64().ok())
                        .unwrap_or(0) as u64,
                    // absent in pre-retention indexes: default live
                    compacted: entry
                        .get("compacted")
                        .and_then(|c| c.as_bool().ok())
                        .unwrap_or(false),
                },
            );
        }
        let bench_bytes = v.get("bench_bytes").and_then(|b| b.as_i64().ok()).unwrap_or(0) as u64;
        Ok((index, bench_bytes))
    }

    fn save_index(&self, index: &Index, bench_bytes: u64) -> Result<()> {
        let runs = index
            .iter()
            .map(|(name, e)| {
                (
                    name.clone(),
                    Value::obj(vec![
                        ("events_bytes", Value::num(e.events_bytes as f64)),
                        ("records", Value::num(e.records as f64)),
                        ("parse_errors", Value::num(e.parse_errors as f64)),
                        ("compacted", Value::Bool(e.compacted)),
                    ]),
                )
            })
            .collect();
        let doc = Value::obj(vec![
            ("version", Value::num(1.0)),
            ("bench_bytes", Value::num(bench_bytes as f64)),
            ("runs", Value::Obj(runs)),
        ]);
        write_atomic(&self.index_path(), &format!("{}\n", doc.to_pretty()))
    }
}

/// Append every complete line of `data` past the entry's cursor to
/// `dst`, advancing the cursor. The cursor only moves past
/// newline-terminated bytes, so a torn tail is re-examined next call.
/// Each newly copied line is also trial-parsed: lines that are not valid
/// JSON (in-place corruption of the source log) are *counted* in the
/// entry's `parse_errors` but still copied and cursor-advanced, so one
/// flipped bit can never wedge ingestion or shift the offset math.
fn append_complete_lines(data: &[u8], dst: &str, entry: &mut IndexEntry) -> Result<u64> {
    let offset = entry.events_bytes as usize;
    let slice = &data[offset.min(data.len())..];
    let Some(last_nl) = slice.iter().rposition(|&b| b == b'\n') else {
        return Ok(0);
    };
    let complete = &slice[..last_nl + 1];
    let mut out = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dst)
        .map_err(|e| Error::io(dst, e))?;
    out.write_all(complete).map_err(|e| Error::io(dst, e))?;
    out.flush().map_err(|e| Error::io(dst, e))?;
    let new_records = complete.iter().filter(|&&b| b == b'\n').count() as u64;
    let bad = String::from_utf8_lossy(complete)
        .lines()
        .filter(|l| !l.trim().is_empty() && Value::parse(l).is_err())
        .count() as u64;
    entry.events_bytes += complete.len() as u64;
    entry.records += new_records;
    entry.parse_errors += bad;
    Ok(new_records)
}

/// Write `content` to `path` atomically (tmp file + rename), so readers
/// never observe a half-written index or summary.
fn write_atomic(path: &str, content: &str) -> Result<()> {
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, content).map_err(|e| Error::io(&tmp, e))?;
    std::fs::rename(&tmp, path).map_err(|e| Error::io(path, e))
}

/// One trained segment (from a `stage_done` event).
#[derive(Clone, Debug)]
pub struct SegmentStats {
    pub stage: String,
    pub steps: u64,
    pub first_loss: f64,
    pub final_loss: f64,
    pub tokens_per_sec: f64,
    pub params: u64,
}

/// One loss-curve sample (from a `step` event).
#[derive(Clone, Debug)]
pub struct LossPoint {
    pub global_step: u64,
    pub stage: String,
    pub loss: f64,
}

/// One applied expansion (from a `boundary` event), predictions next to
/// measurements.
#[derive(Clone, Debug)]
pub struct ExpansionRecord {
    pub into_stage: String,
    pub ops: u64,
    pub rust_delta: f64,
    pub pjrt_delta: f64,
    pub loss_before: f64,
    pub loss_after: f64,
    pub surgery_ms: f64,
    pub params_after: u64,
    pub params_predicted: u64,
    /// Measured pre-surgery param count (absent in pre-store logs).
    pub params_before: Option<u64>,
    pub param_delta: Option<u64>,
    pub flops_delta_est: f64,
    /// The plan evidence, rebuilt and cross-checked via
    /// [`ExpansionPlan::from_json`]; `None` when the event carried no
    /// plan (pre-store logs).
    pub plan: Option<ExpansionPlan>,
    /// Why plan evidence failed validation, when it did.
    pub plan_error: Option<String>,
}

/// One preservation measurement (from a `preservation` event).
#[derive(Clone, Debug)]
pub struct PreservationRecord {
    pub boundary: String,
    pub probe_delta: f64,
    pub backend_delta: f64,
    pub eval_before: f64,
    pub eval_after: f64,
    pub eval_drift: f64,
    pub tol: f64,
    pub within_tol: bool,
}

/// One durable recovery point (from a `checkpoint` event).
#[derive(Clone, Debug)]
pub struct CheckpointRecord {
    /// Generation number in the run's `ckpt/` chain.
    pub gen: u64,
    /// `"interval"` (every N steps) or `"boundary"` (forced at an
    /// expansion).
    pub trigger: String,
    pub global_step: u64,
    pub segment: u64,
    pub bytes: u64,
    pub write_ms: f64,
}

/// One resume-from-checkpoint (from a `resume` event) — evidence that a
/// recovery point was actually exercised.
#[derive(Clone, Debug)]
pub struct ResumeRecord {
    pub gen: u64,
    pub global_step: u64,
    pub segment: u64,
}

/// Serve-phase outcome (from the last `serve_done` event).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub completed: u64,
    pub tokens_generated: u64,
    pub tokens_per_sec: f64,
    pub rejected: u64,
    pub timeouts: u64,
    pub swaps: u64,
    pub queue_latency: PhasePercentiles,
    pub prefill_latency: PhasePercentiles,
    pub decode_latency: PhasePercentiles,
    pub total_latency: PhasePercentiles,
}

/// Aggregate view of one ingested run — what `texpand runs stats` prints
/// and `summary.json` stores.
#[derive(Clone, Debug, Default)]
pub struct RunStats {
    pub run: String,
    pub records: u64,
    pub malformed: u64,
    pub policy: Option<String>,
    pub schedule: Option<String>,
    pub segments: Vec<SegmentStats>,
    pub loss_points: Vec<LossPoint>,
    pub expansions: Vec<ExpansionRecord>,
    pub preservation: Vec<PreservationRecord>,
    pub decisions: u64,
    pub expand_decisions: u64,
    pub checkpoints: Vec<CheckpointRecord>,
    pub resumes: Vec<ResumeRecord>,
    pub spans: u64,
    pub serve: Option<ServeStats>,
    pub final_eval_loss: Option<f64>,
    pub total_steps: Option<u64>,
    pub tokens_seen: Option<f64>,
}

fn num(v: &Value, key: &str) -> f64 {
    v.get(key).and_then(|x| x.as_f64().ok()).unwrap_or(f64::NAN)
}

fn int(v: &Value, key: &str) -> u64 {
    v.get(key).and_then(|x| x.as_i64().ok()).map(|n| n.max(0) as u64).unwrap_or(0)
}

fn text(v: &Value, key: &str) -> String {
    v.get(key).and_then(|x| x.as_str().ok()).unwrap_or("?").to_string()
}

impl RunStats {
    fn new(run: &str) -> RunStats {
        RunStats { run: run.to_string(), ..Default::default() }
    }

    /// Total measured parameter growth across every expansion (falls back
    /// to the plan's exact delta for rows predating the measured field).
    pub fn params_delta_total(&self) -> u64 {
        self.expansions
            .iter()
            .map(|e| {
                e.param_delta
                    .or(e.plan.as_ref().map(|p| p.param_delta() as u64))
                    .unwrap_or(e.params_after.saturating_sub(e.params_before.unwrap_or(0)))
            })
            .sum()
    }

    /// Fold one event record into the aggregates. Unknown events are
    /// counted in `records` by the caller and otherwise ignored, so the
    /// store never chokes on a newer writer's vocabulary.
    fn absorb(&mut self, v: &Value) {
        let kind = v.get("event").and_then(|e| e.as_str().ok()).unwrap_or("");
        match kind {
            "run_start" => {
                self.policy = Some(text(v, "policy"));
                self.schedule = Some(text(v, "schedule"));
            }
            "step" => {
                self.loss_points.push(LossPoint {
                    global_step: int(v, "global_step"),
                    stage: text(v, "stage"),
                    loss: num(v, "loss"),
                });
            }
            "stage_done" => {
                self.segments.push(SegmentStats {
                    stage: text(v, "stage"),
                    steps: int(v, "steps"),
                    first_loss: num(v, "first_loss"),
                    final_loss: num(v, "final_loss"),
                    tokens_per_sec: num(v, "tokens_per_sec"),
                    params: int(v, "params"),
                });
            }
            "boundary" => {
                let (plan, plan_error) = match v.get("plan") {
                    Some(p) if p != &Value::Null => match ExpansionPlan::from_json(p) {
                        Ok(plan) => (Some(plan), None),
                        Err(e) => (None, Some(e.to_string())),
                    },
                    _ => (None, None),
                };
                self.expansions.push(ExpansionRecord {
                    into_stage: text(v, "into_stage"),
                    ops: int(v, "ops"),
                    rust_delta: num(v, "rust_delta"),
                    pjrt_delta: num(v, "pjrt_delta"),
                    loss_before: num(v, "loss_before"),
                    loss_after: num(v, "loss_after"),
                    surgery_ms: num(v, "surgery_ms"),
                    params_after: int(v, "params_after"),
                    params_predicted: int(v, "params_predicted"),
                    params_before: v.get("params_before").and_then(|x| x.as_i64().ok()).map(|n| n as u64),
                    param_delta: v.get("param_delta").and_then(|x| x.as_i64().ok()).map(|n| n as u64),
                    flops_delta_est: num(v, "flops_delta_est"),
                    plan,
                    plan_error,
                });
            }
            "preservation" => {
                self.preservation.push(PreservationRecord {
                    boundary: text(v, "boundary"),
                    probe_delta: num(v, "probe_delta"),
                    backend_delta: num(v, "backend_delta"),
                    eval_before: num(v, "eval_before"),
                    eval_after: num(v, "eval_after"),
                    eval_drift: num(v, "eval_drift"),
                    tol: num(v, "tol"),
                    within_tol: v
                        .get("within_tol")
                        .and_then(|x| x.as_bool().ok())
                        .unwrap_or(false),
                });
            }
            "decision" => {
                self.decisions += 1;
                if v.get("decision").and_then(|d| d.as_str().ok()) == Some("expand") {
                    self.expand_decisions += 1;
                }
            }
            "checkpoint" => {
                self.checkpoints.push(CheckpointRecord {
                    gen: int(v, "gen"),
                    trigger: text(v, "trigger"),
                    global_step: int(v, "global_step"),
                    segment: int(v, "segment"),
                    bytes: int(v, "bytes"),
                    write_ms: num(v, "write_ms"),
                });
            }
            "resume" => {
                self.resumes.push(ResumeRecord {
                    gen: int(v, "gen"),
                    global_step: int(v, "global_step"),
                    segment: int(v, "segment"),
                });
            }
            "span" => self.spans += 1,
            "serve_done" => {
                let Some(c) = v.get("counters") else { return };
                self.serve = Some(ServeStats {
                    completed: int(c, "completed"),
                    tokens_generated: int(c, "tokens_generated"),
                    tokens_per_sec: num(c, "tokens_per_sec"),
                    rejected: int(c, "rejected"),
                    timeouts: int(c, "timeouts"),
                    swaps: int(c, "swaps"),
                    queue_latency: phase(c, "queue_latency"),
                    prefill_latency: phase(c, "prefill_latency"),
                    decode_latency: phase(c, "decode_latency"),
                    total_latency: phase(c, "total_latency"),
                });
            }
            "run_done" => {
                self.final_eval_loss = v.get("final_eval_loss").and_then(|x| x.as_f64().ok());
                self.total_steps = v.get("total_steps").and_then(|x| x.as_i64().ok()).map(|n| n as u64);
                self.tokens_seen = v.get("tokens_seen").and_then(|x| x.as_f64().ok());
            }
            _ => {}
        }
    }

    /// The `summary.json` document.
    pub fn to_json(&self) -> Value {
        let segments = self
            .segments
            .iter()
            .map(|s| {
                Value::obj(vec![
                    ("stage", Value::str(s.stage.clone())),
                    ("steps", Value::num(s.steps as f64)),
                    ("first_loss", Value::num(s.first_loss)),
                    ("final_loss", Value::num(s.final_loss)),
                    ("tokens_per_sec", Value::num(s.tokens_per_sec)),
                    ("params", Value::num(s.params as f64)),
                ])
            })
            .collect();
        let expansions = self
            .expansions
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("into_stage", Value::str(e.into_stage.clone())),
                    ("ops", Value::num(e.ops as f64)),
                    ("rust_delta", Value::num(e.rust_delta)),
                    ("pjrt_delta", Value::num(e.pjrt_delta)),
                    ("loss_before", Value::num(e.loss_before)),
                    ("loss_after", Value::num(e.loss_after)),
                    ("surgery_ms", Value::num(e.surgery_ms)),
                    ("params_after", Value::num(e.params_after as f64)),
                    ("params_predicted", Value::num(e.params_predicted as f64)),
                    (
                        "param_delta",
                        match e.param_delta {
                            Some(d) => Value::num(d as f64),
                            None => Value::Null,
                        },
                    ),
                    ("flops_delta_est", Value::num(e.flops_delta_est)),
                    ("plan_valid", Value::Bool(e.plan.is_some())),
                    (
                        "plan_error",
                        match &e.plan_error {
                            Some(err) => Value::str(err.clone()),
                            None => Value::Null,
                        },
                    ),
                ])
            })
            .collect();
        let preservation = self
            .preservation
            .iter()
            .map(|p| {
                Value::obj(vec![
                    ("boundary", Value::str(p.boundary.clone())),
                    ("probe_delta", Value::num(p.probe_delta)),
                    ("backend_delta", Value::num(p.backend_delta)),
                    ("eval_before", Value::num(p.eval_before)),
                    ("eval_after", Value::num(p.eval_after)),
                    ("eval_drift", Value::num(p.eval_drift)),
                    ("tol", Value::num(p.tol)),
                    ("within_tol", Value::Bool(p.within_tol)),
                ])
            })
            .collect();
        let serve = match &self.serve {
            Some(s) => Value::obj(vec![
                ("completed", Value::num(s.completed as f64)),
                ("tokens_generated", Value::num(s.tokens_generated as f64)),
                ("tokens_per_sec", Value::num(s.tokens_per_sec)),
                ("rejected", Value::num(s.rejected as f64)),
                ("timeouts", Value::num(s.timeouts as f64)),
                ("swaps", Value::num(s.swaps as f64)),
                ("queue_latency", s.queue_latency.to_json()),
                ("prefill_latency", s.prefill_latency.to_json()),
                ("decode_latency", s.decode_latency.to_json()),
                ("total_latency", s.total_latency.to_json()),
            ]),
            None => Value::Null,
        };
        let opt_num = |x: Option<f64>| match x {
            Some(n) => Value::num(n),
            None => Value::Null,
        };
        Value::obj(vec![
            ("run", Value::str(self.run.clone())),
            ("records", Value::num(self.records as f64)),
            ("malformed", Value::num(self.malformed as f64)),
            (
                "policy",
                match &self.policy {
                    Some(p) => Value::str(p.clone()),
                    None => Value::Null,
                },
            ),
            (
                "schedule",
                match &self.schedule {
                    Some(s) => Value::str(s.clone()),
                    None => Value::Null,
                },
            ),
            ("segments", Value::Arr(segments)),
            ("loss_points", Value::num(self.loss_points.len() as f64)),
            ("expansions", Value::Arr(expansions)),
            ("params_delta_total", Value::num(self.params_delta_total() as f64)),
            ("preservation", Value::Arr(preservation)),
            ("decisions", Value::num(self.decisions as f64)),
            ("expand_decisions", Value::num(self.expand_decisions as f64)),
            (
                "checkpoints",
                Value::Arr(
                    self.checkpoints
                        .iter()
                        .map(|c| {
                            Value::obj(vec![
                                ("gen", Value::num(c.gen as f64)),
                                ("trigger", Value::str(c.trigger.clone())),
                                ("global_step", Value::num(c.global_step as f64)),
                                ("segment", Value::num(c.segment as f64)),
                                ("bytes", Value::num(c.bytes as f64)),
                                ("write_ms", Value::num(c.write_ms)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "resumes",
                Value::Arr(
                    self.resumes
                        .iter()
                        .map(|r| {
                            Value::obj(vec![
                                ("gen", Value::num(r.gen as f64)),
                                ("global_step", Value::num(r.global_step as f64)),
                                ("segment", Value::num(r.segment as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("spans", Value::num(self.spans as f64)),
            ("serve", serve),
            ("final_eval_loss", opt_num(self.final_eval_loss)),
            (
                "total_steps",
                match self.total_steps {
                    Some(n) => Value::num(n as f64),
                    None => Value::Null,
                },
            ),
            ("tokens_seen", opt_num(self.tokens_seen)),
        ])
    }
}

/// Parse a nested phase-percentile object off a counters record.
fn phase(c: &Value, key: &str) -> PhasePercentiles {
    c.get(key).map(PhasePercentiles::from_json).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, ModelConfig};

    fn tmp_root(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("texpand-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d.to_str().unwrap().to_string()
    }

    fn write_events(root: &str, run: &str, lines: &[&str]) {
        let dir = format!("{root}/{run}");
        std::fs::create_dir_all(&dir).unwrap();
        let mut text = lines.join("\n");
        text.push('\n');
        std::fs::write(format!("{dir}/events.jsonl"), text).unwrap();
    }

    #[test]
    fn ingest_is_incremental_and_idempotent() {
        let root = tmp_root("incr");
        write_events(
            &root,
            "r1",
            &[r#"{"event":"run_start","policy":"fixed","schedule":"s"}"#],
        );
        let store = RunStore::open(&root).unwrap();
        let rep = store.ingest("r1").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (1, 1));
        // idempotent: nothing new
        let rep = store.ingest("r1").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (0, 1));
        // append one complete line plus a torn tail (no newline)
        let path = format!("{root}/r1/events.jsonl");
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"event\":\"span\",\"id\":1}\n{\"event\":\"spa").unwrap();
        drop(f);
        let rep = store.ingest("r1").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (1, 2), "torn tail not ingested");
        // finishing the torn line makes it land
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"n\",\"id\":2}\n").unwrap();
        drop(f);
        let rep = store.ingest("r1").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (1, 3));
        let stats = store.stats("r1").unwrap();
        assert_eq!(stats.records, 3);
        assert_eq!(stats.spans, 2);
        assert_eq!(stats.malformed, 0, "torn line was never half-ingested");
        assert_eq!(store.runs().unwrap(), vec!["r1".to_string()]);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn reused_run_name_restarts_ingestion() {
        let root = tmp_root("reuse");
        write_events(&root, "r", &[r#"{"event":"span","id":1}"#, r#"{"event":"span","id":2}"#]);
        let store = RunStore::open(&root).unwrap();
        store.ingest("r").unwrap();
        // a fresh (shorter) source under the same name: restart, no dupes
        write_events(&root, "r", &[r#"{"event":"span","id":9}"#]);
        let rep = store.ingest("r").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (1, 1));
        assert_eq!(store.stats("r").unwrap().spans, 1);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn stats_aggregate_run_events_and_validate_plans() {
        let root = tmp_root("stats");
        let cfg = ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 };
        let plan = ExpansionPlan::new(&cfg, vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        let boundary = Value::obj(vec![
            ("event", Value::str("boundary")),
            ("into_stage", Value::str("stage1")),
            ("ops", Value::num(1.0)),
            ("rust_delta", Value::num(1e-7)),
            ("pjrt_delta", Value::num(1e-7)),
            ("loss_before", Value::num(2.5)),
            ("loss_after", Value::num(2.5)),
            ("surgery_ms", Value::num(3.0)),
            ("params_before", Value::num(plan.params_before() as f64)),
            ("params_after", Value::num(plan.params_after() as f64)),
            ("param_delta", Value::num(plan.param_delta() as f64)),
            ("params_predicted", Value::num(plan.params_after() as f64)),
            ("flops_delta_est", Value::num(plan.flops_delta())),
            ("plan", plan.to_json()),
        ]);
        let lines = [
            r#"{"event":"run_start","policy":"fixed","schedule":"tiny"}"#.to_string(),
            r#"{"event":"step","stage":"stage0","global_step":0,"loss":3.0}"#.to_string(),
            r#"{"event":"stage_done","stage":"stage0","steps":5,"first_loss":3.0,"final_loss":2.5,"tokens_per_sec":100.0,"params":123}"#.to_string(),
            boundary.to_string(),
            r#"{"event":"preservation","boundary":"stage1","probe_delta":1e-7,"backend_delta":1e-7,"eval_before":2.5,"eval_after":2.5,"eval_drift":0.0,"tol":1e-4,"within_tol":true}"#.to_string(),
            r#"{"event":"decision","decision":"expand"}"#.to_string(),
            r#"{"event":"run_done","final_eval_loss":2.2,"total_steps":10,"tokens_seen":640}"#.to_string(),
            "not json at all".to_string(),
        ];
        let refs: Vec<&str> = lines.iter().map(|s| s.as_str()).collect();
        write_events(&root, "r", &refs);
        let store = RunStore::open(&root).unwrap();
        store.ingest("r").unwrap();
        let s = store.stats("r").unwrap();
        assert_eq!(s.policy.as_deref(), Some("fixed"));
        assert_eq!(s.segments.len(), 1);
        assert_eq!(s.loss_points.len(), 1);
        assert_eq!(s.expansions.len(), 1);
        assert_eq!(s.preservation.len(), 1);
        assert!(s.preservation[0].within_tol);
        assert_eq!((s.decisions, s.expand_decisions), (1, 1));
        assert_eq!(s.malformed, 1);
        assert_eq!(s.params_delta_total(), plan.param_delta() as u64);
        let e = &s.expansions[0];
        assert!(e.plan.is_some(), "plan evidence rebuilt: {:?}", e.plan_error);
        assert_eq!(e.plan.as_ref().unwrap().param_delta(), plan.param_delta());
        assert_eq!(s.final_eval_loss, Some(2.2));
        // summary.json landed and parses
        let summary = Value::load(&format!("{}/r/summary.json", store.dir())).unwrap();
        assert_eq!(summary.req("expansions").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(summary.req("params_delta_total").unwrap().as_i64().unwrap() as usize, plan.param_delta());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn tampered_plan_evidence_is_flagged_not_trusted() {
        let root = tmp_root("tamper");
        let cfg = ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 };
        let plan = ExpansionPlan::new(&cfg, vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        let mut fields: Vec<(&str, Value)> = Vec::new();
        let j = plan.to_json();
        for key in ["from", "ops", "to"] {
            fields.push((key, j.req(key).unwrap().clone()));
        }
        fields.push(("params_after", Value::num(1.0))); // tampered
        let boundary = Value::obj(vec![
            ("event", Value::str("boundary")),
            ("into_stage", Value::str("stage1")),
            ("plan", Value::obj(fields)),
        ]);
        write_events(&root, "r", &[boundary.to_string().as_str()]);
        let store = RunStore::open(&root).unwrap();
        store.ingest("r").unwrap();
        let s = store.stats("r").unwrap();
        assert_eq!(s.expansions.len(), 1);
        assert!(s.expansions[0].plan.is_none());
        assert!(s.expansions[0].plan_error.as_deref().unwrap().contains("params_after"));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn bit_flipped_lines_are_counted_not_fatal() {
        let root = tmp_root("bitflip");
        // a healthy log...
        let mut good = vec![
            r#"{"event":"run_start","policy":"fixed","schedule":"s"}"#.to_string(),
            r#"{"event":"span","id":1}"#.to_string(),
            r#"{"event":"run_done","final_eval_loss":2.0,"total_steps":3}"#.to_string(),
        ];
        // ...with one line corrupted in place (bit 5 of its first byte:
        // '{' 0x7B -> 0x5B '[', which still parses — so flip a byte in
        // the middle to break the string structure instead)
        let mut bytes = good[1].clone().into_bytes();
        bytes[8] ^= 0x20;
        good[1] = String::from_utf8_lossy(&bytes).into_owned();
        assert!(Value::parse(&good[1]).is_err(), "corrupted line must not parse: {}", good[1]);
        let refs: Vec<&str> = good.iter().map(|s| s.as_str()).collect();
        write_events(&root, "r", &refs);

        let store = RunStore::open(&root).unwrap();
        let rep = store.ingest("r").unwrap();
        assert_eq!(rep.new_records, 3, "corrupted line still ingested");
        assert_eq!(rep.parse_errors, 1, "and counted as damage");
        // the count is cumulative and survives the index round-trip
        let rep = store.ingest("r").unwrap();
        assert_eq!((rep.new_records, rep.parse_errors), (0, 1));
        // aggregation agrees and the surviving records are intact
        let s = store.stats("r").unwrap();
        assert_eq!(s.malformed, 1);
        assert_eq!(s.policy.as_deref(), Some("fixed"));
        assert_eq!(s.final_eval_loss, Some(2.0));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn checkpoint_and_resume_events_become_recovery_points() {
        let root = tmp_root("ckptev");
        let lines = [
            r#"{"event":"run_start","policy":"fixed","schedule":"s"}"#,
            r#"{"event":"checkpoint","gen":1,"trigger":"interval","global_step":4,"segment":0,"bytes":2048,"write_ms":1.5}"#,
            r#"{"event":"checkpoint","gen":2,"trigger":"boundary","global_step":6,"segment":1,"bytes":4096,"write_ms":2.0}"#,
            r#"{"event":"resume","gen":2,"global_step":6,"segment":1,"local_step":0}"#,
        ];
        write_events(&root, "r", &lines);
        let store = RunStore::open(&root).unwrap();
        store.ingest("r").unwrap();
        let s = store.stats("r").unwrap();
        assert_eq!(s.checkpoints.len(), 2);
        assert_eq!(s.checkpoints[0].trigger, "interval");
        assert_eq!(s.checkpoints[1].gen, 2);
        assert_eq!(s.checkpoints[1].trigger, "boundary");
        assert_eq!(s.checkpoints[1].global_step, 6);
        assert_eq!(s.resumes.len(), 1);
        assert_eq!(s.resumes[0].gen, 2);
        // summary.json carries the recovery points for `texpand report`
        let summary = Value::load(&format!("{}/r/summary.json", store.dir())).unwrap();
        assert_eq!(summary.req("checkpoints").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(summary.req("resumes").unwrap().as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn bench_rows_ingest_by_offset() {
        let root = tmp_root("bench");
        std::fs::write(format!("{root}/bench.jsonl"), "{\"kind\":\"step\"}\n").unwrap();
        let store = RunStore::open(&root).unwrap();
        assert_eq!(store.ingest_bench().unwrap(), 1);
        assert_eq!(store.ingest_bench().unwrap(), 0);
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(format!("{root}/bench.jsonl"))
            .unwrap();
        f.write_all(b"{\"kind\":\"step2\"}\n").unwrap();
        drop(f);
        assert_eq!(store.ingest_bench().unwrap(), 1);
        let stored = std::fs::read_to_string(format!("{}/bench.jsonl", store.dir())).unwrap();
        assert_eq!(stored.lines().count(), 2);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn compact_keeps_newest_and_frees_bytes() {
        let root = tmp_root("compact");
        let store = RunStore::open(&root).unwrap();
        for run in ["r1", "r2", "r3"] {
            write_events(&root, run, &[r#"{"event":"span","id":1}"#]);
            store.ingest(run).unwrap();
            // recency is the store-side records.jsonl mtime: space the
            // ingests out so the ordering is unambiguous
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        let rep = store.compact(2).unwrap();
        assert_eq!((rep.examined, rep.kept, rep.compacted), (3, 2, 1));
        assert!(rep.bytes_freed > 0, "oldest run's records were on disk");
        let records = |run: &str| format!("{}/{run}/records.jsonl", store.dir());
        let summary = |run: &str| format!("{}/{run}/summary.json", store.dir());
        assert!(!std::path::Path::new(&records("r1")).exists(), "oldest retired");
        assert!(std::path::Path::new(&summary("r1")).exists(), "aggregate survives");
        assert!(std::path::Path::new(&records("r2")).exists());
        assert!(std::path::Path::new(&records("r3")).exists());
        // idempotent: a second pass has nothing left to retire
        let rep = store.compact(2).unwrap();
        assert_eq!((rep.compacted, rep.bytes_freed), (0, 0));
        // unchanged source: ingest is a no-op that keeps the counts and
        // does NOT resurrect the records payload
        let rep = store.ingest("r1").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (0, 1));
        assert!(!std::path::Path::new(&records("r1")).exists());
        // the run still lists; only stats() needs the payload
        assert!(store.runs().unwrap().contains(&"r1".to_string()));
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn compacted_run_reingests_when_source_changes() {
        let root = tmp_root("compact-regrow");
        let store = RunStore::open(&root).unwrap();
        write_events(&root, "r", &[r#"{"event":"span","id":1}"#]);
        store.ingest("r").unwrap();
        store.compact(0).unwrap();
        // source grew: the retained aggregate is stale, so ingestion
        // restarts from byte 0 and the payload comes back
        let path = format!("{root}/r/events.jsonl");
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"event\":\"span\",\"id\":2}\n").unwrap();
        drop(f);
        let rep = store.ingest("r").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (2, 2));
        assert_eq!(store.stats("r").unwrap().spans, 2);
        // compact again, then shrink the source (run name reused):
        // same restart path, no dupes
        store.compact(0).unwrap();
        write_events(&root, "r", &[r#"{"event":"span","id":9}"#]);
        let rep = store.ingest("r").unwrap();
        assert_eq!((rep.new_records, rep.total_records), (1, 1));
        assert_eq!(store.stats("r").unwrap().spans, 1);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn stats_on_compacted_run_explains_itself() {
        let root = tmp_root("compact-stats");
        let store = RunStore::open(&root).unwrap();
        write_events(&root, "r", &[r#"{"event":"span","id":1}"#]);
        store.ingest("r").unwrap();
        store.compact(0).unwrap();
        let err = store.stats("r").unwrap_err().to_string();
        assert!(err.contains("compacted"), "got: {err}");
        assert!(err.contains("summary.json"), "got: {err}");
        std::fs::remove_dir_all(&root).unwrap();
    }
}
