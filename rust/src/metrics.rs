//! Run logging and timing (S12).
//!
//! A [`RunLogger`] owns one run directory (`runs/<name>/`) and writes:
//! * `events.jsonl` — every structured event (step losses, boundary
//!   surgeries, preservation probes, throughput, serve spans);
//! * `loss.csv` — `global_step,stage,loss,tokens_seen,wall_ms` rows, the
//!   series behind the E3 loss-curve figures.
//!
//! Writes are buffered and never abort the run: a failed line is counted
//! ([`RunLogger::dropped_lines`]) and the *first* underlying IO error is
//! kept for the owner to surface ([`RunLogger::take_write_error`]) — a
//! full disk should cost log lines, not the training run. Callers flush
//! at segment boundaries ([`RunLogger::flush`]); dropping the logger
//! flushes too, so a completed run is always fully on disk.

use std::io::Write;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::json::Value;

/// Structured logger for one training/benchmark run.
pub struct RunLogger {
    dir: String,
    events: Box<dyn Write + Send>,
    events_path: String,
    loss_csv: Box<dyn Write + Send>,
    loss_path: String,
    start: Instant,
    quiet: bool,
    /// Event/CSV lines lost to write failures.
    dropped_lines: u64,
    /// First write/flush failure, kept until taken.
    write_error: Option<Error>,
}

impl RunLogger {
    /// Create `runs/<name>/` (fails if files cannot be created).
    pub fn create(root: &str, name: &str) -> Result<RunLogger> {
        let dir = format!("{root}/{name}");
        std::fs::create_dir_all(&dir).map_err(|e| Error::io(&dir, e))?;
        let events_path = format!("{dir}/events.jsonl");
        let events = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&events_path)
            .map_err(|e| Error::io(&events_path, e))?;
        let loss_path = format!("{dir}/loss.csv");
        let fresh = !std::path::Path::new(&loss_path).exists()
            || std::fs::metadata(&loss_path).map(|m| m.len() == 0).unwrap_or(true);
        let mut loss_csv = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&loss_path)
            .map_err(|e| Error::io(&loss_path, e))?;
        if fresh {
            writeln!(loss_csv, "global_step,stage,loss,tokens_seen,wall_ms").map_err(|e| Error::io(&loss_path, e))?;
        }
        Ok(RunLogger {
            dir,
            events: Box::new(std::io::BufWriter::new(events)),
            events_path,
            loss_csv: Box::new(std::io::BufWriter::new(loss_csv)),
            loss_path,
            start: Instant::now(),
            quiet: false,
            dropped_lines: 0,
            write_error: None,
        })
    }

    /// Build a logger over arbitrary writers — nothing touches the
    /// filesystem. This is the injection seam the durability tests use to
    /// drive the error path with failing writers (`rust/tests/common`).
    pub fn with_writers(
        events: Box<dyn Write + Send>,
        loss_csv: Box<dyn Write + Send>,
    ) -> RunLogger {
        RunLogger {
            dir: String::new(),
            events,
            events_path: "<mem>/events.jsonl".into(),
            loss_csv,
            loss_path: "<mem>/loss.csv".into(),
            start: Instant::now(),
            quiet: true,
            dropped_lines: 0,
            write_error: None,
        }
    }

    /// Suppress stdout mirroring (benches).
    pub fn quiet(mut self) -> RunLogger {
        self.quiet = true;
        self
    }

    pub fn dir(&self) -> &str {
        &self.dir
    }

    /// Path of the structured event log (what the run store ingests).
    pub fn events_path(&self) -> &str {
        &self.events_path
    }

    /// Milliseconds since logger creation.
    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    /// Lines lost to write failures so far.
    pub fn dropped_lines(&self) -> u64 {
        self.dropped_lines
    }

    /// Take the first recorded write/flush failure, if any (take-once;
    /// the owner decides whether to warn or abort).
    pub fn take_write_error(&mut self) -> Option<Error> {
        self.write_error.take()
    }

    /// Flush both buffered writers — called at segment boundaries so a
    /// crash between segments loses at most one segment's tail. Flush
    /// failures are recorded like write failures.
    pub fn flush(&mut self) {
        if let Err(e) = self.events.flush() {
            let path = self.events_path.clone();
            self.write_error.get_or_insert_with(|| Error::io(path, e));
        }
        if let Err(e) = self.loss_csv.flush() {
            let path = self.loss_path.clone();
            self.write_error.get_or_insert_with(|| Error::io(path, e));
        }
    }

    /// Write a structured event (adds `t_ms` automatically).
    pub fn event(&mut self, kind: &str, fields: Vec<(&str, Value)>) {
        let mut all = vec![("event", Value::str(kind)), ("t_ms", Value::num(self.elapsed_ms()))];
        all.extend(fields);
        let line = Value::obj(all).to_string();
        if let Err(e) = writeln!(self.events, "{line}") {
            self.dropped_lines += 1;
            let path = self.events_path.clone();
            self.write_error.get_or_insert_with(|| Error::io(path, e));
        }
        if !self.quiet {
            println!("[{kind}] {line}");
        }
    }

    /// Log one growth-policy decision together with the evidence it was
    /// made on (S17). One row per eval-bearing observation plus every
    /// non-`Continue` verdict — the audit trail for "why did the model
    /// grow here": `ci.sh` smoke-greps these rows, and the policy-compare
    /// bench reads them back. An `Expand` decision carries its full
    /// [`crate::expand::ExpansionPlan`] metadata (round-trippable ops,
    /// exact param delta, estimated FLOPs delta, predicted config) as the
    /// `plan` field, so the log alone reconstructs what was committed.
    /// Each row also bumps the `texpand_policy_decisions_total` counter
    /// (labelled by verdict) in the global metrics registry.
    pub fn decision(
        &mut self,
        policy: &str,
        obs: &crate::growth::TrainObs,
        decision: &crate::growth::Decision,
    ) {
        let (ops, plan) = match decision {
            crate::growth::Decision::Expand(plan) => (
                Value::Arr(plan.ops().iter().map(|o| Value::str(o.kind())).collect()),
                plan.to_json(),
            ),
            _ => (Value::Null, Value::Null),
        };
        let eval = match obs.eval_loss {
            Some(e) => Value::num(f64::from(e)),
            None => Value::Null,
        };
        crate::obs::global()
            .counter_with(
                "texpand_policy_decisions_total",
                "Growth policy decisions by verdict",
                &[("decision", decision.tag())],
            )
            .inc();
        self.event(
            "decision",
            vec![
                ("policy", Value::str(policy)),
                ("decision", Value::str(decision.tag())),
                ("ops", ops),
                ("plan", plan),
                ("global_step", Value::num(obs.global_step as f64)),
                ("arch_step", Value::num(obs.arch_step as f64)),
                ("train_loss", Value::num(f64::from(obs.train_loss))),
                ("eval_loss", eval),
                ("tokens_seen", Value::num(obs.tokens_seen as f64)),
                ("est_flops", Value::num(obs.est_flops)),
                ("params", Value::num(obs.params as f64)),
            ],
        );
        // decisions are recovery evidence (why did the model grow here):
        // push them to disk immediately so a crash right after a verdict
        // never loses the verdict (DESIGN.md §16.5)
        self.flush();
    }

    /// Append one loss-curve row.
    pub fn loss_row(&mut self, global_step: usize, stage: &str, loss: f32, tokens_seen: usize) {
        if let Err(e) = writeln!(
            self.loss_csv,
            "{global_step},{stage},{loss},{tokens_seen},{:.1}",
            self.elapsed_ms()
        ) {
            self.dropped_lines += 1;
            let path = self.loss_path.clone();
            self.write_error.get_or_insert_with(|| Error::io(path, e));
        }
    }
}

/// p50/p95/p99 of one request phase in milliseconds, estimated from the
/// serve engine's fixed-bucket latency histograms (exact to within one
/// bucket width — see [`crate::obs::histogram`]). All zero until the
/// first request finishes or when engine metrics are disabled.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhasePercentiles {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl PhasePercentiles {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("p50_ms", Value::num(self.p50_ms)),
            ("p95_ms", Value::num(self.p95_ms)),
            ("p99_ms", Value::num(self.p99_ms)),
        ])
    }

    /// Parse back the [`PhasePercentiles::to_json`] layout (run-store
    /// ingestion of `serve_done` events). Missing fields read as zero,
    /// matching the all-zero default before any request finishes.
    pub fn from_json(v: &Value) -> PhasePercentiles {
        let f = |k: &str| v.get(k).and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
        PhasePercentiles { p50_ms: f("p50_ms"), p95_ms: f("p95_ms"), p99_ms: f("p99_ms") }
    }
}

/// Serving-engine throughput/latency counters (S15; `texpand serve`).
///
/// Maintained by [`crate::serve::Engine`]: one counter bump per tick /
/// admission / swap, wall time split by phase so decode throughput is not
/// polluted by prompt priming or swap surgery. The `*_latency` percentile
/// fields mirror the engine's phase histograms, refreshed as requests
/// finish.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeCounters {
    pub submitted: u64,
    pub completed: u64,
    /// Continuation tokens decoded (one per in-flight sequence per tick).
    pub tokens_generated: u64,
    /// Prompt tokens processed while priming KV caches.
    pub prompt_tokens: u64,
    /// Ticks that decoded at least one token.
    pub ticks: u64,
    /// Committed hot-swaps.
    pub swaps: u64,
    /// Submissions refused by queue backpressure
    /// (`EngineOptions::max_pending`).
    pub rejected: u64,
    /// In-flight sequences expired by the per-request deadline
    /// (`EngineOptions::request_timeout_ticks`).
    pub timeouts: u64,
    pub decode_ns: u128,
    pub prime_ns: u128,
    pub swap_ns: u128,
    /// Queue-wait percentiles across finished requests.
    pub queue_latency: PhasePercentiles,
    /// Prompt-prime percentiles across finished requests.
    pub prefill_latency: PhasePercentiles,
    /// Decode-phase percentiles across finished requests.
    pub decode_latency: PhasePercentiles,
    /// Submit-to-finish percentiles across finished requests.
    pub total_latency: PhasePercentiles,
}

impl ServeCounters {
    /// Decode throughput: continuation tokens per second of decode time.
    pub fn tokens_per_sec(&self) -> f64 {
        if self.decode_ns == 0 {
            return 0.0;
        }
        self.tokens_generated as f64 / (self.decode_ns as f64 / 1e9)
    }

    /// Mean wall time of a decoding tick, in milliseconds.
    pub fn mean_tick_ms(&self) -> f64 {
        if self.ticks == 0 {
            return 0.0;
        }
        self.decode_ns as f64 / 1e6 / self.ticks as f64
    }

    /// Structured snapshot for `events.jsonl` / CLI output. The first 13
    /// fields are the pre-percentile layout, kept in place and in order
    /// so existing consumers parse unchanged; the `*_latency` objects are
    /// appended after them.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("submitted", Value::num(self.submitted as f64)),
            ("completed", Value::num(self.completed as f64)),
            ("tokens_generated", Value::num(self.tokens_generated as f64)),
            ("prompt_tokens", Value::num(self.prompt_tokens as f64)),
            ("ticks", Value::num(self.ticks as f64)),
            ("swaps", Value::num(self.swaps as f64)),
            ("rejected", Value::num(self.rejected as f64)),
            ("timeouts", Value::num(self.timeouts as f64)),
            ("decode_ms", Value::num(self.decode_ns as f64 / 1e6)),
            ("prime_ms", Value::num(self.prime_ns as f64 / 1e6)),
            ("swap_ms", Value::num(self.swap_ns as f64 / 1e6)),
            ("tokens_per_sec", Value::num(self.tokens_per_sec())),
            ("mean_tick_ms", Value::num(self.mean_tick_ms())),
            ("queue_latency", self.queue_latency.to_json()),
            ("prefill_latency", self.prefill_latency.to_json()),
            ("decode_latency", self.decode_latency.to_json()),
            ("total_latency", self.total_latency.to_json()),
        ])
    }
}

/// Scoped wall-clock timer.
pub struct Timer(Instant);

impl Timer {
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> String {
        let d = std::env::temp_dir().join(format!("texpand-metrics-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.to_str().unwrap().to_string()
    }

    #[test]
    fn logger_writes_events_and_csv() {
        let root = tmpdir("basic");
        let mut log = RunLogger::create(&root, "run1").unwrap().quiet();
        log.event("stage_start", vec![("stage", Value::str("stage0"))]);
        log.loss_row(1, "stage0", 3.25, 512);
        log.loss_row(2, "stage0", 3.10, 1024);
        drop(log);

        let events = std::fs::read_to_string(format!("{root}/run1/events.jsonl")).unwrap();
        let parsed = Value::parse(events.lines().next().unwrap()).unwrap();
        assert_eq!(parsed.req("event").unwrap().as_str().unwrap(), "stage_start");
        assert!(parsed.get("t_ms").is_some());

        let csv = std::fs::read_to_string(format!("{root}/run1/loss.csv")).unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "global_step,stage,loss,tokens_seen,wall_ms");
        assert!(lines[1].starts_with("1,stage0,3.25,512,"));
        assert_eq!(lines.len(), 3);
        std::fs::remove_dir_all(format!("{root}/run1")).unwrap();
    }

    #[test]
    fn csv_header_not_duplicated_on_reopen() {
        let root = tmpdir("reopen");
        {
            let mut log = RunLogger::create(&root, "run2").unwrap().quiet();
            log.loss_row(1, "s", 1.0, 1);
        }
        {
            let mut log = RunLogger::create(&root, "run2").unwrap().quiet();
            log.loss_row(2, "s", 0.5, 2);
        }
        let csv = std::fs::read_to_string(format!("{root}/run2/loss.csv")).unwrap();
        assert_eq!(csv.lines().filter(|l| l.starts_with("global_step")).count(), 1);
        assert_eq!(csv.lines().count(), 3);
        std::fs::remove_dir_all(format!("{root}/run2")).unwrap();
    }

    #[test]
    fn flush_makes_buffered_lines_visible_before_drop() {
        let root = tmpdir("flush");
        let mut log = RunLogger::create(&root, "run4").unwrap().quiet();
        log.event("x", vec![]);
        log.flush();
        assert!(log.take_write_error().is_none());
        assert_eq!(log.dropped_lines(), 0);
        let events = std::fs::read_to_string(format!("{root}/run4/events.jsonl")).unwrap();
        assert_eq!(events.lines().count(), 1, "flushed line visible while logger is open");
        drop(log);
        std::fs::remove_dir_all(format!("{root}/run4")).unwrap();
    }

    /// Writer that fails every write/flush, for the error path.
    struct FailingWriter;

    impl Write for FailingWriter {
        fn write(&mut self, _: &[u8]) -> std::io::Result<usize> {
            Err(std::io::Error::new(std::io::ErrorKind::WriteZero, "disk full"))
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::WriteZero, "disk full"))
        }
    }

    #[test]
    fn failed_writes_are_counted_and_first_error_surfaced() {
        let mut log = RunLogger::with_writers(Box::new(FailingWriter), Box::new(FailingWriter));
        log.event("a", vec![]);
        log.loss_row(1, "s", 1.0, 1);
        log.event("b", vec![]);
        assert_eq!(log.dropped_lines(), 3, "every failed line is counted");
        let err = log.take_write_error().expect("first error kept");
        assert!(err.to_string().contains("events.jsonl"), "{err}");
        assert!(log.take_write_error().is_none(), "take-once");
        log.flush();
        let err = log.take_write_error().expect("flush failures surface too");
        assert!(err.to_string().contains("disk full"), "{err}");
        assert_eq!(log.dropped_lines(), 3, "flush does not bump dropped lines");
    }

    #[test]
    fn decision_rows_flush_immediately() {
        use crate::growth::{Decision, TrainObs};
        // a decision on a healthy logger is durable without an explicit
        // caller-side flush — read the file back while the logger is open
        let root = tmpdir("decision-flush");
        let mut log = RunLogger::create(&root, "run5").unwrap().quiet();
        let obs = TrainObs {
            global_step: 1,
            arch_step: 1,
            train_loss: 2.0,
            eval_loss: Some(2.0),
            tokens_seen: 16,
            est_flops: 1.0,
            params: 10,
        };
        log.decision("plateau", &obs, &Decision::Continue);
        let events = std::fs::read_to_string(format!("{root}/run5/events.jsonl")).unwrap();
        assert_eq!(events.lines().count(), 1, "decision visible before drop");
        drop(log);
        std::fs::remove_dir_all(format!("{root}/run5")).unwrap();

        // and on a failing writer, the flush inside decision() surfaces
        // the error right away instead of deferring it to run teardown
        let mut bad = RunLogger::with_writers(Box::new(FailingWriter), Box::new(FailingWriter));
        bad.decision("plateau", &obs, &Decision::Continue);
        assert!(bad.take_write_error().is_some(), "decision flush reports the failure");
    }

    #[test]
    fn decision_rows_carry_evidence_and_plan_metadata() {
        use crate::config::{GrowthOp, ModelConfig};
        use crate::expand::ExpansionPlan;
        use crate::growth::{Decision, TrainObs};

        let root = tmpdir("decision");
        let mut log = RunLogger::create(&root, "run3").unwrap().quiet();
        let obs = TrainObs {
            global_step: 7,
            arch_step: 3,
            train_loss: 2.5,
            eval_loss: Some(2.4),
            tokens_seen: 448,
            est_flops: 1e9,
            params: 1234,
        };
        let cfg = ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 };
        let plan = ExpansionPlan::new(&cfg, vec![GrowthOp::Mlp { p: 64 }]).unwrap();
        log.decision("plateau", &obs, &Decision::Expand(plan.clone()));
        let no_eval = TrainObs { eval_loss: None, ..obs };
        log.decision("plateau", &no_eval, &Decision::Continue);
        drop(log);

        let events = std::fs::read_to_string(format!("{root}/run3/events.jsonl")).unwrap();
        let mut lines = events.lines();
        let first = Value::parse(lines.next().unwrap()).unwrap();
        assert_eq!(first.req("event").unwrap().as_str().unwrap(), "decision");
        assert_eq!(first.req("policy").unwrap().as_str().unwrap(), "plateau");
        assert_eq!(first.req("decision").unwrap().as_str().unwrap(), "expand");
        let ops = first.req("ops").unwrap().as_arr().unwrap();
        assert_eq!(ops.len(), 1);
        assert_eq!(ops[0].as_str().unwrap(), "mlp");
        // the plan metadata is the decision's evidence: exact param delta,
        // round-trippable op objects, predicted target config
        let plan_json = first.req("plan").unwrap();
        assert_eq!(
            plan_json.req("param_delta").unwrap().as_i64().unwrap() as usize,
            plan.param_delta()
        );
        let op0 = &plan_json.req("ops").unwrap().as_arr().unwrap()[0];
        assert_eq!(GrowthOp::from_json(op0).unwrap(), GrowthOp::Mlp { p: 64 });
        assert_eq!(
            ModelConfig::from_json(plan_json.req("to").unwrap()).unwrap().mlp,
            64
        );
        assert_eq!(first.req("global_step").unwrap().as_i64().unwrap(), 7);
        assert!((first.req("eval_loss").unwrap().as_f64().unwrap() - 2.4).abs() < 1e-6);
        let second = Value::parse(lines.next().unwrap()).unwrap();
        assert_eq!(second.req("decision").unwrap().as_str().unwrap(), "continue");
        assert_eq!(second.req("eval_loss").unwrap(), &Value::Null);
        assert_eq!(second.req("ops").unwrap(), &Value::Null);
        assert_eq!(second.req("plan").unwrap(), &Value::Null);
        std::fs::remove_dir_all(format!("{root}/run3")).unwrap();
    }

    #[test]
    fn serve_counters_math_and_json() {
        let mut c = ServeCounters::default();
        assert_eq!(c.tokens_per_sec(), 0.0);
        assert_eq!(c.mean_tick_ms(), 0.0);
        c.tokens_generated = 500;
        c.decode_ns = 1_000_000_000; // 1 s
        c.ticks = 10;
        c.decode_latency = PhasePercentiles { p50_ms: 1.0, p95_ms: 2.0, p99_ms: 3.0 };
        assert!((c.tokens_per_sec() - 500.0).abs() < 1e-9);
        assert!((c.mean_tick_ms() - 100.0).abs() < 1e-9);
        let j = c.to_json();
        assert_eq!(j.req("tokens_generated").unwrap().as_i64().unwrap(), 500);
        assert!((j.req("tokens_per_sec").unwrap().as_f64().unwrap() - 500.0).abs() < 1e-9);
        let d = j.req("decode_latency").unwrap();
        assert!((d.req("p95_ms").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
        let q = j.req("queue_latency").unwrap();
        assert_eq!(q.req("p50_ms").unwrap().as_f64().unwrap(), 0.0, "untouched phases are zero");
    }

    #[test]
    fn phase_percentiles_round_trip_json() {
        let p = PhasePercentiles { p50_ms: 1.5, p95_ms: 9.0, p99_ms: 20.25 };
        assert_eq!(PhasePercentiles::from_json(&p.to_json()), p);
        assert_eq!(PhasePercentiles::from_json(&Value::obj(vec![])), PhasePercentiles::default());
    }

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.ms() >= 4.0);
        assert!(t.secs() < 1.0);
    }
}
