//! Growth coordinator (S10b) — the framework's top-level orchestration.
//!
//! A run is a **policy-driven loop** over architecture segments:
//!
//! ```text
//! init params (stage0 config)
//!   └─ train segment ──▶ policy: Continue | Expand(plan) | Stop
//!        │                          │            │
//!        │◀─── keep stepping ───────┘            │
//!        └─ boundary: surgery(params, moments) + probes ─▶ next segment
//! ```
//!
//! The stage list is no longer fixed up front: a [`GrowthPolicy`] decides
//! at every step whether to keep training, expand (carrying a validated
//! [`ExpansionPlan`] with its predicted outcome), or stop. [`Coordinator::run`] drives the default [`FixedSchedule`] policy,
//! which replays the schedule's stage table bit-identically to the old
//! stage-wise loop; [`Coordinator::run_with_policy`] takes any policy
//! (plateau-triggered staged growth, greedy branch-probe search, ...).
//!
//! At every boundary the coordinator *proves* (empirically) the paper's
//! claim before continuing:
//! 1. **Rust-oracle probe** — pure-Rust forward before vs after surgery on
//!    a held-out probe batch; `max|Δ logits|` must be ≤ `preserve_tol`.
//! 2. **Backend probe** — previous segment's `fwd` executable on old
//!    params vs next segment's `fwd` on expanded params, through whichever
//!    [`ExecBackend`] is driving the run; same tolerance. On the PJRT path
//!    this is the check that would catch AOT/manifest drift, not just
//!    surgery bugs. A reference-model backend (native) would reproduce
//!    probe 1 bit for bit, so its result is reused instead of recomputed.
//!
//! Artifact resolution follows the backend: a backend that
//! [`ExecBackend::needs_artifacts`] loads stage executables from the AOT
//! manifest (so its stage table must match the schedule, and only the
//! fixed policy can drive it); the native backend synthesizes stage
//! metadata for whatever architecture the policy grew, so adaptive
//! policies run fully offline.
//!
//! The coordinator is also the entry point for the §5 future-work use
//! cases: [`Coordinator::branch`] (model families) reuses the boundary
//! machinery without the schedule.

use std::path::Path;

use crate::autodiff::ExecBackend;
use crate::ckpt::{Chain, CkptHook};
use crate::config::{GrowthSchedule, ModelConfig, OptimKind, TrainConfig};
use crate::data::{Batch, Batcher, CorpusKind};
use crate::error::{Error, Result};
use crate::expand::{ExpandOptions, ExpansionPlan};
use crate::growth::{FixedSchedule, GrowthPolicy};
use crate::json::Value;
use crate::metrics::RunLogger;
use crate::model as refmodel;
use crate::optim::Optimizer;
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::runtime::{Manifest, ManifestStage, StageExec};
use crate::train::{eval_loss, train_segment, SegmentEnd, StageReport, TrainState};

/// Coordinator behaviour knobs (CLI-mapped).
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Multiply every stage's scheduled step count (quick smoke runs).
    pub steps_scale: f64,
    /// Run the two preservation probes at each boundary (default on).
    pub verify_boundaries: bool,
    /// Save a checkpoint at the end of every segment.
    pub save_checkpoints: bool,
    /// Synthetic corpus selection.
    pub corpus: CorpusKind,
    pub corpus_len: usize,
    /// Initializer std for unconstrained expansion parameters.
    pub expand_init_std: f32,
    /// Write a durable [`crate::ckpt`] run checkpoint every N global steps
    /// (0 = boundary checkpoints only, and only when resume is requested).
    pub checkpoint_every: usize,
    /// Generations retained in the checkpoint chain.
    pub checkpoint_keep: usize,
    /// Resume from the newest valid checkpoint generation under the run
    /// dir instead of starting fresh.
    pub resume: bool,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            steps_scale: 1.0,
            verify_boundaries: true,
            save_checkpoints: true,
            corpus: CorpusKind::MarkovText,
            corpus_len: 200_000,
            expand_init_std: 0.02,
            checkpoint_every: 0,
            checkpoint_keep: 3,
            resume: false,
        }
    }
}

/// Per-boundary preservation measurement.
#[derive(Clone, Debug)]
pub struct BoundaryReport {
    pub into_stage: String,
    pub ops: usize,
    pub rust_delta: f32,
    /// Probe delta measured through the *executing backend* (PJRT
    /// artifacts, or the native interpreter when running offline). On a
    /// reference-model backend this equals [`BoundaryReport::rust_delta`]
    /// by construction and the duplicate probe is skipped. The name
    /// predates the backend abstraction and is kept for log/report
    /// compatibility.
    pub pjrt_delta: f32,
    /// Eval loss immediately before/after surgery (PJRT path) — the loss
    /// continuity evidence for E3.
    pub loss_before: f32,
    pub loss_after: f32,
    pub surgery_ms: f64,
}

/// Full-run outcome.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub run_dir: String,
    /// Which policy drove the run.
    pub policy: String,
    pub stages: Vec<StageReport>,
    pub boundaries: Vec<BoundaryReport>,
    pub final_eval_loss: f32,
    pub total_steps: usize,
}

/// The growth coordinator (see module docs). Generic over the execution
/// engine: pass `Box::new(Runtime::cpu()?)` for the PJRT artifact path or
/// `Box::new(NativeBackend::new())` (with `Manifest::from_schedule`) for
/// the offline pure-Rust path.
pub struct Coordinator {
    pub schedule: GrowthSchedule,
    pub manifest: Manifest,
    pub backend: Box<dyn ExecBackend>,
    pub tcfg: TrainConfig,
    pub opts: CoordinatorOptions,
}

impl Coordinator {
    /// Build a coordinator. When the backend resolves stage executables
    /// from AOT artifacts, the manifest is cross-validated against the
    /// schedule (they are written by the two halves of the build); a
    /// reference-model backend synthesizes its stage metadata, so for it
    /// the manifest is advisory and mismatches are not errors.
    pub fn new(
        schedule: GrowthSchedule,
        manifest: Manifest,
        backend: Box<dyn ExecBackend>,
        tcfg: TrainConfig,
        opts: CoordinatorOptions,
    ) -> Result<Coordinator> {
        if backend.needs_artifacts() {
            Self::validate_manifest(&schedule, &manifest)?;
        }
        Ok(Coordinator { schedule, manifest, backend, tcfg, opts })
    }

    /// The manifest/schedule drift check (only meaningful when stage
    /// executables actually come from the manifest's artifact files).
    fn validate_manifest(schedule: &GrowthSchedule, manifest: &Manifest) -> Result<()> {
        if manifest.stages.len() != schedule.stages.len() {
            return Err(Error::Manifest(format!(
                "manifest has {} stages, schedule '{}' has {} — rerun `make artifacts`",
                manifest.stages.len(),
                schedule.name,
                schedule.stages.len()
            )));
        }
        for (ms, ss) in manifest.stages.iter().zip(&schedule.stages) {
            if ms.name != ss.name || ms.config != ss.config {
                return Err(Error::Manifest(format!(
                    "stage '{}' config mismatch between manifest ({:?}) and schedule ({:?})",
                    ss.name, ms.config, ss.config
                )));
            }
        }
        if manifest.batch != schedule.batch {
            return Err(Error::Manifest(format!(
                "manifest batch {} != schedule batch {}",
                manifest.batch, schedule.batch
            )));
        }
        Ok(())
    }

    /// The run-identity fingerprint written into every checkpoint: the
    /// inputs that determine the deterministic training trajectory.
    /// Resuming under any different value would silently diverge from the
    /// interrupted run, so [`Coordinator::run_with_policy`] compares this
    /// against the stored fingerprint and rejects mismatches up front.
    /// `seed` and `steps_scale` are serialized as hex bit patterns so the
    /// comparison is exact, not Display-rounded.
    fn fingerprint(&self, policy_name: &str) -> Value {
        Value::obj(vec![
            ("schedule", Value::str(self.schedule.name.clone())),
            ("policy", Value::str(policy_name)),
            ("seed", Value::str(format!("{:016x}", self.tcfg.seed))),
            (
                "optimizer",
                Value::str(match self.tcfg.optimizer {
                    OptimKind::Adam => "adam",
                    OptimKind::Sgd => "sgd",
                }),
            ),
            ("corpus", Value::str(self.opts.corpus.name())),
            ("corpus_len", Value::num(self.opts.corpus_len as f64)),
            ("batch", Value::num(self.schedule.batch as f64)),
            (
                "steps_scale_bits",
                Value::str(format!("{:016x}", self.opts.steps_scale.to_bits())),
            ),
        ])
    }

    /// Resolve the executable for a (possibly policy-grown) architecture.
    /// Artifact backends look the segment up in the manifest — and fail
    /// loudly if the policy's architecture drifted from what was compiled;
    /// the native backend gets synthesized stage metadata for exactly the
    /// architecture the run has grown into.
    fn load_exec(&mut self, name: &str, cfg: &ModelConfig) -> Result<StageExec> {
        if self.backend.needs_artifacts() {
            let exec = self.backend.load_stage(&self.manifest, name)?;
            if &exec.meta.config != cfg {
                return Err(Error::Manifest(format!(
                    "segment '{name}' grew to {:?} but the artifact manifest compiled {:?} — \
                     adaptive policies need --backend native",
                    cfg, exec.meta.config
                )));
            }
            return Ok(exec);
        }
        let manifest = Manifest {
            schedule: self.schedule.name.clone(),
            batch: self.schedule.batch,
            kernels: "native".to_string(),
            stages: vec![ManifestStage {
                name: name.to_string(),
                steps: 0,
                config: *cfg,
                num_params: cfg.num_params(),
                fwd_file: String::new(),
                step_file: String::new(),
            }],
            dir: String::new(),
        };
        self.backend.load_stage(&manifest, name)
    }

    /// Execute the growth schedule under the default [`FixedSchedule`]
    /// policy — exactly the pre-policy coordinator behaviour.
    pub fn run(&mut self, run_root: &str, run_name: &str) -> Result<RunSummary> {
        let mut policy = FixedSchedule::new(&self.schedule, self.opts.steps_scale);
        self.run_with_policy(run_root, run_name, &mut policy)
    }

    /// Execute a policy-driven growth run; returns the run summary.
    pub fn run_with_policy(
        &mut self,
        run_root: &str,
        run_name: &str,
        policy: &mut dyn GrowthPolicy,
    ) -> Result<RunSummary> {
        // durable-run setup happens BEFORE the logger opens its append
        // handles: a resume must rewind loss.csv first, or the logger
        // would keep appending to the renamed-away inode
        let run_dir = format!("{run_root}/{run_name}");
        let ckpt_active = self.opts.checkpoint_every > 0 || self.opts.resume;
        let mut ckpt_hook: Option<CkptHook> = None;
        let mut resumed: Option<(u64, crate::ckpt::RunCheckpoint)> = None;
        if ckpt_active {
            let chain =
                Chain::open(&Path::new(&run_dir).join("ckpt"), self.opts.checkpoint_keep)?;
            let fingerprint = self.fingerprint(policy.name());
            if self.opts.resume {
                match chain.load_latest_valid()? {
                    Some((gen, ck)) => {
                        if ck.fingerprint.to_string() != fingerprint.to_string() {
                            return Err(Error::Checkpoint(format!(
                                "resume rejected: checkpoint gen {gen} was written by a run \
                                 with identity {} but this invocation is {} — a resume under \
                                 different inputs would silently diverge",
                                ck.fingerprint.to_string(),
                                fingerprint.to_string()
                            )));
                        }
                        rewind_loss_csv(&run_dir, ck.global_step)?;
                        resumed = Some((gen, ck));
                    }
                    None => eprintln!(
                        "warning: --resume requested but no checkpoint exists under \
                         {run_dir}/ckpt; starting fresh"
                    ),
                }
            } else {
                // a fresh run must not leave stale generations behind for
                // a later --resume to pick up
                chain.reset()?;
            }
            ckpt_hook = Some(CkptHook::new(chain, self.opts.checkpoint_every, fingerprint));
        }
        let mut logger = RunLogger::create(run_root, run_name)?;
        let first_cfg = self.schedule.stages[0].config;
        // evidence for the events log; also keeps resume-state reporting
        // alive after `resumed` is consumed by the init below
        let resume_meta =
            resumed.as_ref().map(|(gen, ck)| (*gen, ck.global_step, ck.segment, ck.local_step));

        // run state: either the deterministic fresh-start path (unchanged
        // from before checkpointing existed, so non-resumed runs are
        // bit-identical to older builds) or a full restore from the
        // newest valid checkpoint generation
        let (mut rng, mut params, mut opt, mut batcher, mut state, mut segment) = match resumed {
            Some((_, ck)) => {
                policy.restore(&ck.policy_state)?;
                let rng = Pcg32::from_parts(
                    ck.surgery_rng.0,
                    ck.surgery_rng.1,
                    ck.surgery_rng.2,
                );
                let opt = ck.to_optimizer(&self.tcfg)?;
                // seq/vocab are invariant under every growth op, so the
                // stage-0 geometry rebuilds the same token stream the
                // interrupted run was drawing from; only the draw cursor
                // needs restoring
                let mut batcher = Batcher::from_corpus(
                    self.opts.corpus,
                    self.opts.corpus_len,
                    first_cfg.vocab,
                    first_cfg.seq,
                    self.schedule.batch,
                    self.tcfg.seed ^ 0xC0DE,
                )?;
                batcher.restore_rng(ck.batcher_rng.0, ck.batcher_rng.1, ck.batcher_rng.2);
                let mut state = TrainState::new();
                state.global_step = ck.global_step;
                state.tokens_seen = ck.tokens_seen;
                state.est_flops = ck.est_flops;
                if let Some(h) = ckpt_hook.as_mut() {
                    h.last_plan = ck.last_plan.clone();
                    h.set_resume_local_step(ck.local_step);
                }
                (rng, ck.params, opt, batcher, state, ck.segment)
            }
            None => {
                let mut rng = Pcg32::seeded(self.tcfg.seed);
                let params = ParamStore::init(&first_cfg, &mut rng, 0.02);
                let opt = Optimizer::new(&self.tcfg, &params);
                let batcher = Batcher::from_corpus(
                    self.opts.corpus,
                    self.opts.corpus_len,
                    first_cfg.vocab,
                    first_cfg.seq,
                    self.schedule.batch,
                    self.tcfg.seed ^ 0xC0DE,
                )?;
                (rng, params, opt, batcher, TrainState::new(), 0)
            }
        };
        logger.event(
            "run_start",
            vec![
                ("schedule", Value::str(self.schedule.name.clone())),
                ("policy", Value::str(policy.name())),
                ("corpus", Value::str(self.opts.corpus.name())),
                ("optimizer", Value::str(opt.name())),
                ("platform", Value::str(self.backend.platform())),
                ("stages", Value::num(self.schedule.stages.len() as f64)),
            ],
        );
        if let Some((gen, global_step, seg, local_step)) = resume_meta {
            println!(
                "resuming from checkpoint gen {gen}: global step {global_step}, \
                 segment {seg} (+{local_step} local steps)"
            );
            logger.event(
                "resume",
                vec![
                    ("gen", Value::num(gen as f64)),
                    ("global_step", Value::num(global_step as f64)),
                    ("segment", Value::num(seg as f64)),
                    ("local_step", Value::num(local_step as f64)),
                ],
            );
            logger.flush();
        }
        // one fixed held-out probe batch serves boundary preservation
        // checks, policy eval observations, and the final eval (stable
        // across calls by construction, so this matches the old per-use
        // regeneration bit for bit; an independent stream, so a resumed
        // run regenerates it identically)
        let probe = batcher.probe(self.tcfg.seed ^ 0xE7A1);

        let mut stage_reports = Vec::new();
        let mut boundary_reports = Vec::new();

        let final_exec = loop {
            let seg_name = format!("stage{segment}");
            let exec = self.load_exec(&seg_name, params.config())?;
            if let Some(h) = ckpt_hook.as_mut() {
                // the hook captures segment context at write time; the
                // surgery RNG only advances at boundaries, so its parts
                // here are exactly what a restored segment needs
                h.segment = segment;
                h.surgery_rng = rng.to_parts();
            }
            let (report, end) = train_segment(
                self.backend.as_ref(),
                &exec,
                &mut params,
                &mut opt,
                &mut batcher,
                &self.tcfg,
                &mut logger,
                &mut state,
                policy,
                Some(&probe),
                ckpt_hook.as_mut(),
            )?;
            stage_reports.push(report);
            if self.opts.save_checkpoints {
                let path = format!("{}/{seg_name}.txpd", logger.dir());
                params.save(
                    &path,
                    &Value::obj(vec![
                        ("stage", Value::str(seg_name.clone())),
                        ("global_step", Value::num(state.global_step as f64)),
                        ("tokens_seen", Value::num(state.tokens_seen as f64)),
                    ]),
                )?;
            }
            match end {
                SegmentEnd::Stop => break exec,
                SegmentEnd::Expand(plan) => {
                    if !plan.is_identity() {
                        let report = self.boundary(
                            &mut params,
                            &mut opt,
                            &probe,
                            &exec,
                            &plan,
                            &format!("stage{}", segment + 1),
                            &mut rng,
                            &mut logger,
                        )?;
                        boundary_reports.push(report);
                    }
                    segment += 1;
                    // forced checkpoint at every expansion boundary
                    // (identity plans too — they also end a segment):
                    // the post-surgery params, expanded Adam moments and
                    // advanced surgery RNG are exactly the state a crash
                    // during the next segment must not lose
                    if let Some(h) = ckpt_hook.as_mut() {
                        h.segment = segment;
                        h.surgery_rng = rng.to_parts();
                        h.last_plan = Some(plan.to_json());
                        h.write(
                            "boundary",
                            0,
                            &params,
                            &opt,
                            &batcher,
                            &*policy,
                            &state,
                            &mut logger,
                        )?;
                    }
                }
            }
        };

        let final_eval_loss = eval_loss(self.backend.as_ref(), &final_exec, &params, &probe)?;
        logger.event(
            "run_done",
            vec![
                ("policy", Value::str(policy.name())),
                ("final_eval_loss", Value::num(f64::from(final_eval_loss))),
                ("total_steps", Value::num(state.global_step as f64)),
                ("tokens_seen", Value::num(state.tokens_seen as f64)),
                ("est_flops", Value::num(state.est_flops)),
                ("expansions", Value::num(boundary_reports.len() as f64)),
            ],
        );
        logger.flush();
        if let Some(e) = logger.take_write_error() {
            eprintln!(
                "warning: run log writes failed ({} lines dropped): {e}",
                logger.dropped_lines()
            );
        }
        Ok(RunSummary {
            run_dir: logger.dir().to_string(),
            policy: policy.name().to_string(),
            stages: stage_reports,
            boundaries: boundary_reports,
            final_eval_loss,
            total_steps: state.global_step,
        })
    }

    /// Apply one boundary's plan with both preservation probes. The plan
    /// is the transaction: params and optimizer moments expand through
    /// [`ExpansionPlan::apply_train`], which validates everything before
    /// mutating and post-checks the predicted config and param count.
    #[allow(clippy::too_many_arguments)]
    fn boundary(
        &mut self,
        params: &mut ParamStore,
        opt: &mut Optimizer,
        probe: &Batch,
        prev_exec: &StageExec,
        plan: &ExpansionPlan,
        into_name: &str,
        rng: &mut Pcg32,
        logger: &mut RunLogger,
    ) -> Result<BoundaryReport> {
        let timer = crate::metrics::Timer::start();

        // before-surgery references. A reference-model backend (native)
        // would recompute the rust-oracle logits bit for bit, so its probe
        // and loss reuse them instead of running three more forwards.
        let reference_backend = self.backend.is_reference_model();
        let rust_before = refmodel::forward(params.config(), params, &probe.tokens)?;
        let backend_before = if reference_backend {
            None
        } else {
            Some(self.backend.forward(prev_exec, params, &probe.tokens)?)
        };
        let loss_before = if reference_backend {
            refmodel::cross_entropy(&rust_before, &probe.targets)?
        } else {
            eval_loss(self.backend.as_ref(), prev_exec, params, probe)?
        };

        // the transaction: params + moments through the one plan seam
        let params_before = params.num_scalars();
        let expand_opts =
            ExpandOptions { init: crate::expand::Init::Normal(self.opts.expand_init_std), ..Default::default() };
        plan.apply_train(params, opt, &expand_opts, rng)?;
        let surgery_ms = timer.ms();

        // after-surgery probes
        let grown_cfg = *params.config();
        let next_exec = self.load_exec(into_name, &grown_cfg)?;
        let rust_after = refmodel::forward(params.config(), params, &probe.tokens)?;
        let backend_after = if reference_backend {
            None
        } else {
            Some(self.backend.forward(&next_exec, params, &probe.tokens)?)
        };
        let loss_after = if reference_backend {
            refmodel::cross_entropy(&rust_after, &probe.targets)?
        } else {
            eval_loss(self.backend.as_ref(), &next_exec, params, probe)?
        };

        let rust_delta = refmodel::max_logit_delta(&rust_before, &rust_after)?;
        let pjrt_delta = match (&backend_before, &backend_after) {
            (Some(before), Some(after)) => refmodel::max_logit_delta(before, after)?,
            // reference backend: the backend probe IS the rust oracle
            _ => rust_delta,
        };
        logger.event(
            "boundary",
            vec![
                ("into_stage", Value::str(into_name)),
                ("ops", Value::num(plan.ops().len() as f64)),
                ("rust_delta", Value::num(f64::from(rust_delta))),
                ("pjrt_delta", Value::num(f64::from(pjrt_delta))),
                ("loss_before", Value::num(f64::from(loss_before))),
                ("loss_after", Value::num(f64::from(loss_after))),
                ("surgery_ms", Value::num(surgery_ms)),
                ("params_before", Value::num(params_before as f64)),
                ("params_after", Value::num(params.num_scalars() as f64)),
                ("param_delta", Value::num((params.num_scalars() - params_before) as f64)),
                // plan predictions next to the measured outcome — the
                // param prediction is exact (asserted by apply_train), the
                // FLOPs prediction is the cost-model estimate
                ("params_predicted", Value::num(plan.params_after() as f64)),
                ("flops_delta_est", Value::num(plan.flops_delta())),
                // full plan evidence: the run store rebuilds and
                // cross-checks this via ExpansionPlan::from_json
                ("plan", plan.to_json()),
            ],
        );
        // an expansion boundary is the event this whole repo exists for:
        // make it visible to a live scrape, and durable in the log
        crate::obs::global()
            .counter("texpand_train_expansions_total", "Committed expansion boundaries")
            .inc();
        // preservation-drift monitor: one event + gauge per boundary, so a
        // whole multi-stage run leaves a queryable preservation trail and a
        // live scrape sees the most recent boundary's drift
        let drift = rust_delta.max(pjrt_delta);
        let tol = self.tcfg.preserve_tol;
        let within_tol = drift <= tol;
        crate::obs::global()
            .gauge(
                "texpand_preservation_drift",
                "max|delta logits| across both probes at the latest expansion boundary",
            )
            .set(f64::from(drift));
        logger.event(
            "preservation",
            vec![
                ("boundary", Value::str(into_name)),
                ("probe_delta", Value::num(f64::from(rust_delta))),
                ("backend_delta", Value::num(f64::from(pjrt_delta))),
                ("eval_before", Value::num(f64::from(loss_before))),
                ("eval_after", Value::num(f64::from(loss_after))),
                ("eval_drift", Value::num(f64::from(loss_after - loss_before))),
                ("tol", Value::num(f64::from(tol))),
                ("within_tol", Value::Bool(within_tol)),
            ],
        );
        if !within_tol {
            eprintln!(
                "warning: preservation drift {drift:.3e} exceeds probe tolerance {tol:.0e} \
                 at boundary into '{into_name}'"
            );
        }
        logger.flush();
        if self.opts.verify_boundaries {
            if rust_delta > self.tcfg.preserve_tol {
                return Err(Error::Train(format!(
                    "boundary into '{into_name}' violated preservation (rust oracle): max|Δ| = {rust_delta}"
                )));
            }
            if pjrt_delta > self.tcfg.preserve_tol {
                return Err(Error::Train(format!(
                    "boundary into '{into_name}' violated preservation (backend path): max|Δ| = {pjrt_delta}"
                )));
            }
        }
        Ok(BoundaryReport {
            into_stage: into_name.to_string(),
            ops: plan.ops().len(),
            rust_delta,
            pjrt_delta,
            loss_before,
            loss_after,
            surgery_ms,
        })
    }

    /// §5 use case (b): branch a trained checkpoint into a larger family
    /// member and finetune it. `stage_name` selects which manifest stage the
    /// branch architecture corresponds to (its artifacts must exist).
    #[allow(clippy::too_many_arguments)]
    pub fn branch(
        &mut self,
        base: &ParamStore,
        ops: &[crate::config::GrowthOp],
        stage_name: &str,
        finetune_steps: usize,
        run_root: &str,
        run_name: &str,
        probe: &Batch,
    ) -> Result<(ParamStore, StageReport, f32)> {
        let mut logger = RunLogger::create(run_root, run_name)?;
        let mut rng = Pcg32::seeded(self.tcfg.seed ^ 0xB4A2C4);
        let expand_opts =
            ExpandOptions { init: crate::expand::Init::Normal(self.opts.expand_init_std), ..Default::default() };
        let plan = ExpansionPlan::new(base.config(), ops.to_vec())?;
        let mut params = plan.materialize(base, &expand_opts, &mut rng)?;
        let exec = self.backend.load_stage(&self.manifest, stage_name)?;
        if params.config() != &exec.meta.config {
            return Err(Error::Config(format!(
                "branch ops produce {:?} but stage '{stage_name}' expects {:?}",
                params.config(),
                exec.meta.config
            )));
        }
        let mut opt = Optimizer::new(&self.tcfg, &params);
        let mut batcher = Batcher::from_corpus(
            self.opts.corpus,
            self.opts.corpus_len,
            params.config().vocab,
            params.config().seq,
            self.schedule.batch,
            self.tcfg.seed ^ 0xC0DE, // same corpus as the main run
        )?;
        let mut state = TrainState::new();
        let report = crate::train::train_stage(
            self.backend.as_ref(),
            &exec,
            &mut params,
            &mut opt,
            &mut batcher,
            &self.tcfg,
            &mut logger,
            &mut state,
            finetune_steps,
        )?;
        let eval = eval_loss(self.backend.as_ref(), &exec, &params, probe)?;
        Ok((params, report, eval))
    }
}

/// Trim `loss.csv` back to the checkpointed step so a resumed run appends
/// a continuation instead of duplicating (or interleaving with) rows the
/// crashed run wrote past its last checkpoint. Keeps the header plus every
/// *complete* 5-column row whose step is ≤ `global_step`; a partially
/// flushed final line — the torn-write crash case — fails the column
/// count and is dropped. The rewrite itself is tmp+rename atomic, so a
/// crash during the rewind cannot lose the file either.
fn rewind_loss_csv(run_dir: &str, global_step: usize) -> Result<()> {
    let path = format!("{run_dir}/loss.csv");
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        // no loss.csv yet (crash before the first flush): nothing to trim
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(Error::io(&path, e)),
    };
    let mut kept = String::with_capacity(text.len());
    for (i, line) in text.lines().enumerate() {
        let keep = if i == 0 {
            // RunLogger writes the header before any row on a fresh file
            line == "global_step,stage,loss,tokens_seen,wall_ms"
        } else {
            let cols: Vec<&str> = line.split(',').collect();
            cols.len() == 5 && cols[0].parse::<usize>().is_ok_and(|s| s <= global_step)
        };
        if keep {
            kept.push_str(line);
            kept.push('\n');
        }
    }
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, kept.as_bytes()).map_err(|e| Error::io(&tmp, e))?;
    std::fs::rename(&tmp, &path).map_err(|e| Error::io(&path, e))?;
    Ok(())
}
