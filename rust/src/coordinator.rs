//! Growth coordinator (S10b) — the framework's top-level orchestration.
//!
//! Walks a [`GrowthSchedule`] end to end:
//!
//! ```text
//! init params (stage0 config)
//!   └─ train stage0 ──▶ boundary: surgery(params, moments) + probes
//!        └─ train stage1 ──▶ ... ──▶ train stageN, checkpoints per stage
//! ```
//!
//! At every boundary the coordinator *proves* (empirically) the paper's
//! claim before continuing:
//! 1. **Rust-oracle probe** — pure-Rust forward before vs after surgery on
//!    a held-out probe batch; `max|Δ logits|` must be ≤ `preserve_tol`.
//! 2. **Backend probe** — previous stage's `fwd` executable on old params
//!    vs next stage's `fwd` on expanded params, through whichever
//!    [`ExecBackend`] is driving the run; same tolerance. On the PJRT path
//!    this is the check that would catch AOT/manifest drift, not just
//!    surgery bugs. A reference-model backend (native) would reproduce
//!    probe 1 bit for bit, so its result is reused instead of recomputed.
//!
//! The coordinator is also the entry point for the §5 future-work use
//! cases: [`Coordinator::branch`] (model families) reuses the boundary
//! machinery without the schedule.

use crate::autodiff::ExecBackend;
use crate::config::{GrowthSchedule, TrainConfig};
use crate::data::{Batch, Batcher, CorpusKind};
use crate::error::{Error, Result};
use crate::expand::ExpandOptions;
use crate::json::Value;
use crate::metrics::RunLogger;
use crate::model as refmodel;
use crate::optim::Optimizer;
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::runtime::{Manifest, StageExec};
use crate::train::{eval_loss, train_stage, StageReport, TrainState};

/// Coordinator behaviour knobs (CLI-mapped).
#[derive(Clone, Debug)]
pub struct CoordinatorOptions {
    /// Multiply every stage's scheduled step count (quick smoke runs).
    pub steps_scale: f64,
    /// Run the two preservation probes at each boundary (default on).
    pub verify_boundaries: bool,
    /// Save a checkpoint at the end of every stage.
    pub save_checkpoints: bool,
    /// Synthetic corpus selection.
    pub corpus: CorpusKind,
    pub corpus_len: usize,
    /// Initializer std for unconstrained expansion parameters.
    pub expand_init_std: f32,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            steps_scale: 1.0,
            verify_boundaries: true,
            save_checkpoints: true,
            corpus: CorpusKind::MarkovText,
            corpus_len: 200_000,
            expand_init_std: 0.02,
        }
    }
}

/// Per-boundary preservation measurement.
#[derive(Clone, Debug)]
pub struct BoundaryReport {
    pub into_stage: String,
    pub ops: usize,
    pub rust_delta: f32,
    /// Probe delta measured through the *executing backend* (PJRT
    /// artifacts, or the native interpreter when running offline). On a
    /// reference-model backend this equals [`BoundaryReport::rust_delta`]
    /// by construction and the duplicate probe is skipped. The name
    /// predates the backend abstraction and is kept for log/report
    /// compatibility.
    pub pjrt_delta: f32,
    /// Eval loss immediately before/after surgery (PJRT path) — the loss
    /// continuity evidence for E3.
    pub loss_before: f32,
    pub loss_after: f32,
    pub surgery_ms: f64,
}

/// Full-run outcome.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub run_dir: String,
    pub stages: Vec<StageReport>,
    pub boundaries: Vec<BoundaryReport>,
    pub final_eval_loss: f32,
    pub total_steps: usize,
}

/// The growth coordinator (see module docs). Generic over the execution
/// engine: pass `Box::new(Runtime::cpu()?)` for the PJRT artifact path or
/// `Box::new(NativeBackend::new())` (with `Manifest::from_schedule`) for
/// the offline pure-Rust path.
pub struct Coordinator {
    pub schedule: GrowthSchedule,
    pub manifest: Manifest,
    pub backend: Box<dyn ExecBackend>,
    pub tcfg: TrainConfig,
    pub opts: CoordinatorOptions,
}

impl Coordinator {
    /// Build a coordinator, cross-validating the manifest against the
    /// schedule (they are written by the two halves of the build).
    pub fn new(
        schedule: GrowthSchedule,
        manifest: Manifest,
        backend: Box<dyn ExecBackend>,
        tcfg: TrainConfig,
        opts: CoordinatorOptions,
    ) -> Result<Coordinator> {
        if manifest.stages.len() != schedule.stages.len() {
            return Err(Error::Manifest(format!(
                "manifest has {} stages, schedule '{}' has {} — rerun `make artifacts`",
                manifest.stages.len(),
                schedule.name,
                schedule.stages.len()
            )));
        }
        for (ms, ss) in manifest.stages.iter().zip(&schedule.stages) {
            if ms.name != ss.name || ms.config != ss.config {
                return Err(Error::Manifest(format!(
                    "stage '{}' config mismatch between manifest ({:?}) and schedule ({:?})",
                    ss.name, ms.config, ss.config
                )));
            }
        }
        if manifest.batch != schedule.batch {
            return Err(Error::Manifest(format!(
                "manifest batch {} != schedule batch {}",
                manifest.batch, schedule.batch
            )));
        }
        Ok(Coordinator { schedule, manifest, backend, tcfg, opts })
    }

    fn scaled_steps(&self, steps: usize) -> usize {
        ((steps as f64 * self.opts.steps_scale).round() as usize).max(1)
    }

    /// Execute the full growth schedule; returns the run summary.
    pub fn run(&mut self, run_root: &str, run_name: &str) -> Result<RunSummary> {
        let mut logger = RunLogger::create(run_root, run_name)?;
        let first_cfg = self.schedule.stages[0].config;
        let mut rng = Pcg32::seeded(self.tcfg.seed);
        let mut params = ParamStore::init(&first_cfg, &mut rng, 0.02);
        let mut opt = Optimizer::new(&self.tcfg, &params);
        let mut batcher = Batcher::from_corpus(
            self.opts.corpus,
            self.opts.corpus_len,
            first_cfg.vocab,
            first_cfg.seq,
            self.schedule.batch,
            self.tcfg.seed ^ 0xC0DE,
        )?;
        logger.event(
            "run_start",
            vec![
                ("schedule", Value::str(self.schedule.name.clone())),
                ("corpus", Value::str(self.opts.corpus.name())),
                ("optimizer", Value::str(opt.name())),
                ("platform", Value::str(self.backend.platform())),
                ("stages", Value::num(self.schedule.stages.len() as f64)),
            ],
        );

        let mut state = TrainState::new();
        let mut stage_reports = Vec::new();
        let mut boundary_reports = Vec::new();
        let mut prev_exec: Option<StageExec> = None;

        for (i, stage_spec) in self.schedule.stages.clone().iter().enumerate() {
            if i > 0 && !stage_spec.apply.is_empty() {
                let report = self.boundary(
                    &mut params,
                    &mut opt,
                    &batcher,
                    prev_exec.as_ref().expect("stage > 0 has prev"),
                    stage_spec,
                    &mut rng,
                    &mut logger,
                )?;
                boundary_reports.push(report);
            }
            let exec = self.backend.load_stage(&self.manifest, &stage_spec.name)?;
            let steps = self.scaled_steps(stage_spec.steps);
            let report = train_stage(
                self.backend.as_ref(),
                &exec,
                &mut params,
                &mut opt,
                &mut batcher,
                &self.tcfg,
                &mut logger,
                &mut state,
                steps,
            )?;
            stage_reports.push(report);
            if self.opts.save_checkpoints {
                let path = format!("{}/{}.txpd", logger.dir(), stage_spec.name);
                params.save(
                    &path,
                    &Value::obj(vec![
                        ("stage", Value::str(stage_spec.name.clone())),
                        ("global_step", Value::num(state.global_step as f64)),
                        ("tokens_seen", Value::num(state.tokens_seen as f64)),
                    ]),
                )?;
            }
            prev_exec = Some(exec);
        }

        let final_exec = prev_exec.expect("at least one stage");
        let probe = batcher.probe(self.tcfg.seed ^ 0xE7A1);
        let final_eval_loss = eval_loss(self.backend.as_ref(), &final_exec, &params, &probe)?;
        logger.event(
            "run_done",
            vec![
                ("final_eval_loss", Value::num(f64::from(final_eval_loss))),
                ("total_steps", Value::num(state.global_step as f64)),
                ("tokens_seen", Value::num(state.tokens_seen as f64)),
            ],
        );
        Ok(RunSummary {
            run_dir: logger.dir().to_string(),
            stages: stage_reports,
            boundaries: boundary_reports,
            final_eval_loss,
            total_steps: state.global_step,
        })
    }

    /// Apply one boundary's surgery with both preservation probes.
    #[allow(clippy::too_many_arguments)]
    fn boundary(
        &mut self,
        params: &mut ParamStore,
        opt: &mut Optimizer,
        batcher: &Batcher,
        prev_exec: &StageExec,
        stage_spec: &crate::config::Stage,
        rng: &mut Pcg32,
        logger: &mut RunLogger,
    ) -> Result<BoundaryReport> {
        let probe = batcher.probe(self.tcfg.seed ^ 0xE7A1);
        let timer = crate::metrics::Timer::start();

        // before-surgery references. A reference-model backend (native)
        // would recompute the rust-oracle logits bit for bit, so its probe
        // and loss reuse them instead of running three more forwards.
        let reference_backend = self.backend.is_reference_model();
        let rust_before = refmodel::forward(params.config(), params, &probe.tokens)?;
        let backend_before = if reference_backend {
            None
        } else {
            Some(self.backend.forward(prev_exec, params, &probe.tokens)?)
        };
        let loss_before = if reference_backend {
            refmodel::cross_entropy(&rust_before, &probe.targets)?
        } else {
            eval_loss(self.backend.as_ref(), prev_exec, params, &probe)?
        };

        // the surgery itself (owned path: the pre-surgery store is dead)
        let expand_opts =
            ExpandOptions { init: crate::expand::Init::Normal(self.opts.expand_init_std), ..Default::default() };
        let dummy = crate::config::ModelConfig {
            layers: 1, hidden: 1, heads: 1, k: 1, v: 1, mlp: 1, seq: 1, vocab: 1,
        };
        let old = std::mem::replace(params, ParamStore::zeros(&dummy));
        *params = crate::expand::apply_ops_owned(old, &stage_spec.apply, rng, &expand_opts)?;
        opt.expand(&stage_spec.apply)?;
        opt.validate_against(params)?;
        let surgery_ms = timer.ms();

        // after-surgery probes
        let next_exec = self.backend.load_stage(&self.manifest, &stage_spec.name)?;
        let rust_after = refmodel::forward(params.config(), params, &probe.tokens)?;
        let backend_after = if reference_backend {
            None
        } else {
            Some(self.backend.forward(&next_exec, params, &probe.tokens)?)
        };
        let loss_after = if reference_backend {
            refmodel::cross_entropy(&rust_after, &probe.targets)?
        } else {
            eval_loss(self.backend.as_ref(), &next_exec, params, &probe)?
        };

        let rust_delta = refmodel::max_logit_delta(&rust_before, &rust_after)?;
        let pjrt_delta = match (&backend_before, &backend_after) {
            (Some(before), Some(after)) => refmodel::max_logit_delta(before, after)?,
            // reference backend: the backend probe IS the rust oracle
            _ => rust_delta,
        };
        logger.event(
            "boundary",
            vec![
                ("into_stage", Value::str(stage_spec.name.clone())),
                ("ops", Value::num(stage_spec.apply.len() as f64)),
                ("rust_delta", Value::num(f64::from(rust_delta))),
                ("pjrt_delta", Value::num(f64::from(pjrt_delta))),
                ("loss_before", Value::num(f64::from(loss_before))),
                ("loss_after", Value::num(f64::from(loss_after))),
                ("surgery_ms", Value::num(surgery_ms)),
                ("params_after", Value::num(params.num_scalars() as f64)),
            ],
        );
        if self.opts.verify_boundaries {
            if rust_delta > self.tcfg.preserve_tol {
                return Err(Error::Train(format!(
                    "boundary into '{}' violated preservation (rust oracle): max|Δ| = {rust_delta}",
                    stage_spec.name
                )));
            }
            if pjrt_delta > self.tcfg.preserve_tol {
                return Err(Error::Train(format!(
                    "boundary into '{}' violated preservation (backend path): max|Δ| = {pjrt_delta}",
                    stage_spec.name
                )));
            }
        }
        Ok(BoundaryReport {
            into_stage: stage_spec.name.clone(),
            ops: stage_spec.apply.len(),
            rust_delta,
            pjrt_delta,
            loss_before,
            loss_after,
            surgery_ms,
        })
    }

    /// §5 use case (b): branch a trained checkpoint into a larger family
    /// member and finetune it. `stage_name` selects which manifest stage the
    /// branch architecture corresponds to (its artifacts must exist).
    #[allow(clippy::too_many_arguments)]
    pub fn branch(
        &mut self,
        base: &ParamStore,
        ops: &[crate::config::GrowthOp],
        stage_name: &str,
        finetune_steps: usize,
        run_root: &str,
        run_name: &str,
        probe: &Batch,
    ) -> Result<(ParamStore, StageReport, f32)> {
        let mut logger = RunLogger::create(run_root, run_name)?;
        let mut rng = Pcg32::seeded(self.tcfg.seed ^ 0xB4A2C4);
        let expand_opts =
            ExpandOptions { init: crate::expand::Init::Normal(self.opts.expand_init_std), ..Default::default() };
        let mut params =
            if ops.is_empty() { base.clone() } else { crate::expand::apply_ops(base, ops, &mut rng, &expand_opts)? };
        let exec = self.backend.load_stage(&self.manifest, stage_name)?;
        if params.config() != &exec.meta.config {
            return Err(Error::Config(format!(
                "branch ops produce {:?} but stage '{stage_name}' expects {:?}",
                params.config(),
                exec.meta.config
            )));
        }
        let mut opt = Optimizer::new(&self.tcfg, &params);
        let mut batcher = Batcher::from_corpus(
            self.opts.corpus,
            self.opts.corpus_len,
            params.config().vocab,
            params.config().seq,
            self.schedule.batch,
            self.tcfg.seed ^ 0xC0DE, // same corpus as the main run
        )?;
        let mut state = TrainState::new();
        let report = train_stage(
            self.backend.as_ref(),
            &exec,
            &mut params,
            &mut opt,
            &mut batcher,
            &self.tcfg,
            &mut logger,
            &mut state,
            finetune_steps,
        )?;
        let eval = eval_loss(self.backend.as_ref(), &exec, &params, probe)?;
        Ok((params, report, eval))
    }
}
