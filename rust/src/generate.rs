//! Autoregressive generation from a trained checkpoint (S10c).
//!
//! Decodes through the stage's `fwd` executable on any [`ExecBackend`]
//! (PJRT artifact, or the native interpreter for artifact-free offline
//! runs): the window of the
//! last `seq` tokens is fed left-aligned (zero-padded on the right — the
//! causal mask guarantees logits at position `len-1` ignore the padding),
//! and the next token is sampled from the logits at the last real
//! position. Once the history exceeds `seq`, the window slides.
//!
//! This is deliberately the *simple* KV-less decode: each new token re-runs
//! the full forward. At the framework's stage sizes that costs a few ms per
//! token on CPU. The KV-cached serving path lives in [`crate::serve`]
//! (pure-Rust reference model; a cached PJRT path would need per-position
//! artifacts and stays future work) — [`generate_ref`] here is its KV-less
//! oracle twin. The value of this module is the end-to-end loop: train →
//! grow → checkpoint → generate.

use crate::autodiff::ExecBackend;
use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::runtime::StageExec;

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct Sampler {
    /// 0.0 = greedy argmax; otherwise softmax temperature.
    pub temperature: f32,
    /// Restrict sampling to the k most likely tokens.
    pub top_k: Option<usize>,
    pub seed: u64,
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler { temperature: 0.8, top_k: Some(40), seed: 0 }
    }
}

/// Pick the next token from a logits row (pub for unit testing).
///
/// Degenerate inputs never panic: an empty row returns token 0, NaN logits
/// are excluded from consideration (a NaN must not hijack the ranking by
/// poisoning comparisons), and an all-NaN row falls back to token 0.
pub fn sample_from_logits(logits: &[f32], sampler: &Sampler, rng: &mut Pcg32) -> u32 {
    if logits.is_empty() || sampler.temperature <= 0.0 {
        return argmax(logits);
    }
    // rank non-NaN tokens, apply top-k cutoff
    let mut idx: Vec<usize> = (0..logits.len()).filter(|&i| !logits[i].is_nan()).collect();
    if idx.is_empty() {
        return 0;
    }
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal));
    let k = sampler.top_k.unwrap_or(idx.len()).max(1).min(idx.len());
    let kept = &idx[..k];
    let max = logits[kept[0]];
    let weights: Vec<f64> = kept
        .iter()
        .map(|&i| (f64::from(logits[i] - max) / f64::from(sampler.temperature)).exp())
        .collect();
    kept[rng.weighted(&weights)] as u32
}

/// Greedy argmax over a logits row: first-index-wins on exact ties, NaN
/// entries skipped (NaN-poisoned comparisons previously made the result
/// depend on NaN position), `0` for an empty or all-NaN row.
pub fn argmax(row: &[f32]) -> u32 {
    let mut best: Option<usize> = None;
    for (i, v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some(b) if *v <= row[b] => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or(0) as u32
}

/// Generate `new_tokens` continuation tokens for each prompt, through any
/// [`ExecBackend`] (PJRT artifact or the native interpreter).
///
/// `prompts.len()` must equal the stage's configured batch size (pad with
/// clones of the last prompt if you have fewer — see the CLI).
pub fn generate(
    backend: &dyn ExecBackend,
    stage: &StageExec,
    params: &ParamStore,
    prompts: &[Vec<u32>],
    new_tokens: usize,
    sampler: &Sampler,
) -> Result<Vec<Vec<u32>>> {
    let cfg = *params.config();
    if prompts.len() != stage.batch {
        return Err(Error::Runtime(format!(
            "{} prompts for an artifact compiled with batch {}",
            prompts.len(),
            stage.batch
        )));
    }
    for p in prompts {
        if p.is_empty() {
            return Err(Error::Runtime("empty prompt".into()));
        }
        if let Some(&t) = p.iter().find(|&&t| t as usize >= cfg.vocab) {
            return Err(Error::Runtime(format!("prompt token {t} out of vocab {}", cfg.vocab)));
        }
    }

    let mut rng = Pcg32::new(sampler.seed, 0x6E6E);
    let mut histories: Vec<Vec<u32>> = prompts.to_vec();
    for _ in 0..new_tokens {
        // build the [B, seq] window batch
        let mut windows = Vec::with_capacity(histories.len());
        let mut read_pos = Vec::with_capacity(histories.len());
        for h in &histories {
            let (window, pos) = decode_window(h, cfg.seq);
            windows.push(window);
            read_pos.push(pos);
        }
        let logits = backend.forward(stage, params, &windows)?;
        for ((h, l), &pos) in histories.iter_mut().zip(&logits).zip(&read_pos) {
            let next = sample_from_logits(l.row(pos), sampler, &mut rng);
            h.push(next);
        }
    }
    Ok(histories)
}

/// Build the model-input window for one decode step: the full (right-zero-
/// padded) history while it fits `seq`, else the last `seq` tokens. Returns
/// the window and the row index holding the last real token's logits.
pub(crate) fn decode_window(history: &[u32], seq: usize) -> (Vec<u32>, usize) {
    if history.len() <= seq {
        let mut w = history.to_vec();
        w.resize(seq, 0); // right-pad; causal mask shields pos len-1
        (w, history.len() - 1)
    } else {
        (history[history.len() - seq..].to_vec(), seq - 1)
    }
}

/// Pure-Rust KV-less reference decode: the same windowing and sampling as
/// [`generate`], but through [`crate::model::forward_one`] instead of a
/// PJRT artifact — every new token re-runs the full forward.
///
/// This is the serving subsystem's oracle: `serve::Engine`'s KV-cached
/// decode must be token-identical to this loop for greedy sampling
/// (`tests/integration_serve.rs`), and `benches/serving_latency.rs`
/// measures the incremental path's speedup against it.
pub fn generate_ref(
    params: &ParamStore,
    prompts: &[Vec<u32>],
    new_tokens: usize,
    sampler: &Sampler,
) -> Result<Vec<Vec<u32>>> {
    let cfg: ModelConfig = *params.config();
    for p in prompts {
        if p.is_empty() {
            return Err(Error::Runtime("empty prompt".into()));
        }
        if let Some(&t) = p.iter().find(|&&t| t as usize >= cfg.vocab) {
            return Err(Error::Runtime(format!("prompt token {t} out of vocab {}", cfg.vocab)));
        }
    }
    let mut rng = Pcg32::new(sampler.seed, 0x6E6E);
    let mut histories: Vec<Vec<u32>> = prompts.to_vec();
    for _ in 0..new_tokens {
        for h in histories.iter_mut() {
            let (window, pos) = decode_window(h, cfg.seq);
            let logits = crate::model::forward_one(&cfg, params, &window)?;
            h.push(sample_from_logits(logits.row(pos), sampler, &mut rng));
        }
    }
    Ok(histories)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Pcg32::seeded(0);
        let s = Sampler { temperature: 0.0, top_k: None, seed: 0 };
        assert_eq!(sample_from_logits(&[0.1, 5.0, -2.0], &s, &mut rng), 1);
        assert_eq!(sample_from_logits(&[9.0, 5.0, -2.0], &s, &mut rng), 0);
    }

    #[test]
    fn top_k_one_equals_greedy() {
        let mut rng = Pcg32::seeded(1);
        let s = Sampler { temperature: 1.0, top_k: Some(1), seed: 0 };
        for _ in 0..20 {
            assert_eq!(sample_from_logits(&[0.0, 1.0, 3.0, 2.0], &s, &mut rng), 2);
        }
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Pcg32::seeded(2);
        let s = Sampler { temperature: 1.0, top_k: None, seed: 0 };
        let logits = [2.0f32, 0.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_from_logits(&logits, &s, &mut rng) as usize] += 1;
        }
        // p(token 0) = e^2 / (e^2 + 3) ~ 0.71
        assert!(counts[0] > 1200, "{counts:?}");
        assert!(counts[1] > 0 && counts[2] > 0 && counts[3] > 0, "{counts:?}");
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut rng = Pcg32::seeded(3);
        let sharp = Sampler { temperature: 0.1, top_k: None, seed: 0 };
        let logits = [1.0f32, 0.5, 0.0];
        let hits = (0..500)
            .filter(|_| sample_from_logits(&logits, &sharp, &mut rng) == 0)
            .count();
        assert!(hits > 480, "{hits}");
    }

    #[test]
    fn top_k_excludes_tail() {
        let mut rng = Pcg32::seeded(4);
        let s = Sampler { temperature: 5.0, top_k: Some(2), seed: 0 };
        let logits = [3.0f32, 2.9, -10.0, -10.0];
        for _ in 0..200 {
            let t = sample_from_logits(&logits, &s, &mut rng);
            assert!(t < 2, "sampled excluded token {t}");
        }
    }

    #[test]
    fn argmax_ties_pick_first_index() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0]), 0);
    }

    #[test]
    fn argmax_ignores_nan_and_guards_empty() {
        // a NaN used to poison the running comparison and win by default
        assert_eq!(argmax(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax(&[1.0, f32::NAN, 0.5]), 0);
        assert_eq!(argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax(&[]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn sampling_guards_empty_and_nan_rows() {
        let mut rng = Pcg32::seeded(5);
        let hot = Sampler { temperature: 1.0, top_k: Some(4), seed: 0 };
        assert_eq!(sample_from_logits(&[], &hot, &mut rng), 0);
        assert_eq!(sample_from_logits(&[f32::NAN, f32::NAN], &hot, &mut rng), 0);
        // NaN entries are excluded from the candidate set entirely
        for _ in 0..100 {
            let t = sample_from_logits(&[f32::NAN, 1.0, 0.5], &hot, &mut rng);
            assert!(t == 1 || t == 2, "sampled NaN-poisoned token {t}");
        }
    }
}

#[cfg(test)]
mod prop_tests {
    use super::*;
    use crate::prop::Runner;

    fn random_logits(rng: &mut Pcg32) -> Vec<f32> {
        let n = 1 + rng.below(24);
        (0..n).map(|_| rng.normal_f32(3.0)).collect()
    }

    #[test]
    fn prop_greedy_at_zero_temperature_equals_argmax() {
        Runner::new("greedy-equals-argmax", 100).run(
            |rng| random_logits(rng),
            |logits| {
                let s = Sampler { temperature: 0.0, top_k: None, seed: 0 };
                let got = sample_from_logits(logits, &s, &mut Pcg32::seeded(1));
                let want = argmax(logits);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("sampled {got}, argmax {want}"))
                }
            },
        );
    }

    #[test]
    fn prop_top_k_never_samples_outside_k_most_likely() {
        Runner::new("top-k-containment", 100).run(
            |rng| {
                let logits = random_logits(rng);
                let k = 1 + rng.below(logits.len());
                let seed = rng.next_u64();
                (logits, k, seed)
            },
            |(logits, k, seed)| {
                let s = Sampler { temperature: 1.5, top_k: Some(*k), seed: 0 };
                let t = sample_from_logits(logits, &s, &mut Pcg32::seeded(*seed)) as usize;
                // t is inside the k most likely iff fewer than k entries
                // beat it strictly
                let beaten_by = logits.iter().filter(|&&v| v > logits[t]).count();
                if beaten_by < *k {
                    Ok(())
                } else {
                    Err(format!("token {t} ranks {beaten_by} with k={k}: {logits:?}"))
                }
            },
        );
    }

    #[test]
    fn prop_fixed_seed_gives_deterministic_draws() {
        Runner::new("seeded-determinism", 60).run(
            |rng| (random_logits(rng), rng.next_u64()),
            |(logits, seed)| {
                let s = Sampler { temperature: 0.9, top_k: Some(8), seed: 0 };
                let draw = |seed: u64| {
                    let mut rng = Pcg32::seeded(seed);
                    (0..8).map(|_| sample_from_logits(logits, &s, &mut rng)).collect::<Vec<u32>>()
                };
                if draw(*seed) == draw(*seed) {
                    Ok(())
                } else {
                    Err("same seed produced different draw sequences".into())
                }
            },
        );
    }
}
