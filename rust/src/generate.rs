//! Autoregressive generation from a trained checkpoint (S10c).
//!
//! Decodes through the stage's compiled `fwd` artifact: the window of the
//! last `seq` tokens is fed left-aligned (zero-padded on the right — the
//! causal mask guarantees logits at position `len-1` ignore the padding),
//! and the next token is sampled from the logits at the last real
//! position. Once the history exceeds `seq`, the window slides.
//!
//! This is deliberately the *simple* KV-less decode: each new token re-runs
//! the full forward. At the framework's stage sizes that costs a few ms per
//! token on CPU; a KV-cache decode path would need per-position artifacts
//! (future work, noted in DESIGN.md). The value here is the end-to-end
//! loop: train → grow → checkpoint → generate, all through PJRT.

use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::runtime::{Runtime, StageExec};

/// Sampling configuration.
#[derive(Clone, Copy, Debug)]
pub struct Sampler {
    /// 0.0 = greedy argmax; otherwise softmax temperature.
    pub temperature: f32,
    /// Restrict sampling to the k most likely tokens.
    pub top_k: Option<usize>,
    pub seed: u64,
}

impl Default for Sampler {
    fn default() -> Self {
        Sampler { temperature: 0.8, top_k: Some(40), seed: 0 }
    }
}

/// Pick the next token from a logits row (pub for unit testing).
pub fn sample_from_logits(logits: &[f32], sampler: &Sampler, rng: &mut Pcg32) -> u32 {
    if sampler.temperature <= 0.0 {
        return argmax(logits);
    }
    // rank tokens, apply top-k cutoff
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap_or(std::cmp::Ordering::Equal));
    let k = sampler.top_k.unwrap_or(logits.len()).max(1).min(logits.len());
    let kept = &idx[..k];
    let max = logits[kept[0]];
    let weights: Vec<f64> = kept
        .iter()
        .map(|&i| (f64::from(logits[i] - max) / f64::from(sampler.temperature)).exp())
        .collect();
    kept[rng.weighted(&weights)] as u32
}

fn argmax(row: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as u32
}

/// Generate `new_tokens` continuation tokens for each prompt.
///
/// `prompts.len()` must equal the artifact's compiled batch size (pad with
/// clones of the last prompt if you have fewer — see the CLI).
pub fn generate(
    rt: &Runtime,
    stage: &StageExec,
    params: &ParamStore,
    prompts: &[Vec<u32>],
    new_tokens: usize,
    sampler: &Sampler,
) -> Result<Vec<Vec<u32>>> {
    let cfg = *params.config();
    if prompts.len() != stage.batch {
        return Err(Error::Runtime(format!(
            "{} prompts for an artifact compiled with batch {}",
            prompts.len(),
            stage.batch
        )));
    }
    for p in prompts {
        if p.is_empty() {
            return Err(Error::Runtime("empty prompt".into()));
        }
        if let Some(&t) = p.iter().find(|&&t| t as usize >= cfg.vocab) {
            return Err(Error::Runtime(format!("prompt token {t} out of vocab {}", cfg.vocab)));
        }
    }

    let mut rng = Pcg32::new(sampler.seed, 0x6E6E);
    let mut histories: Vec<Vec<u32>> = prompts.to_vec();
    for _ in 0..new_tokens {
        // build the [B, seq] window batch
        let mut windows = Vec::with_capacity(histories.len());
        let mut read_pos = Vec::with_capacity(histories.len());
        for h in &histories {
            let (window, pos) = if h.len() <= cfg.seq {
                let mut w = h.clone();
                w.resize(cfg.seq, 0); // right-pad; causal mask shields pos len-1
                (w, h.len() - 1)
            } else {
                (h[h.len() - cfg.seq..].to_vec(), cfg.seq - 1)
            };
            windows.push(window);
            read_pos.push(pos);
        }
        let logits = rt.forward(stage, params, &windows)?;
        for ((h, l), &pos) in histories.iter_mut().zip(&logits).zip(&read_pos) {
            let next = sample_from_logits(l.row(pos), sampler, &mut rng);
            h.push(next);
        }
    }
    Ok(histories)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut rng = Pcg32::seeded(0);
        let s = Sampler { temperature: 0.0, top_k: None, seed: 0 };
        assert_eq!(sample_from_logits(&[0.1, 5.0, -2.0], &s, &mut rng), 1);
        assert_eq!(sample_from_logits(&[9.0, 5.0, -2.0], &s, &mut rng), 0);
    }

    #[test]
    fn top_k_one_equals_greedy() {
        let mut rng = Pcg32::seeded(1);
        let s = Sampler { temperature: 1.0, top_k: Some(1), seed: 0 };
        for _ in 0..20 {
            assert_eq!(sample_from_logits(&[0.0, 1.0, 3.0, 2.0], &s, &mut rng), 2);
        }
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Pcg32::seeded(2);
        let s = Sampler { temperature: 1.0, top_k: None, seed: 0 };
        let logits = [2.0f32, 0.0, 0.0, 0.0];
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_from_logits(&logits, &s, &mut rng) as usize] += 1;
        }
        // p(token 0) = e^2 / (e^2 + 3) ~ 0.71
        assert!(counts[0] > 1200, "{counts:?}");
        assert!(counts[1] > 0 && counts[2] > 0 && counts[3] > 0, "{counts:?}");
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut rng = Pcg32::seeded(3);
        let sharp = Sampler { temperature: 0.1, top_k: None, seed: 0 };
        let logits = [1.0f32, 0.5, 0.0];
        let hits = (0..500)
            .filter(|_| sample_from_logits(&logits, &sharp, &mut rng) == 0)
            .count();
        assert!(hits > 480, "{hits}");
    }

    #[test]
    fn top_k_excludes_tail() {
        let mut rng = Pcg32::seeded(4);
        let s = Sampler { temperature: 5.0, top_k: Some(2), seed: 0 };
        let logits = [3.0f32, 2.9, -10.0, -10.0];
        for _ in 0..200 {
            let t = sample_from_logits(&logits, &s, &mut rng);
            assert!(t < 2, "sampled excluded token {t}");
        }
    }
}
