//! The greedy branch-probe policy: NAS-lite schedule search, in-line.
//!
//! `examples/schedule_search.rs` seeded this idea as a one-shot offline
//! ranking; this policy runs it *during* training. When progress stalls
//! (same plateau trigger as [`super::LossPlateau`]), the live checkpoint is
//! branched across every [`crate::expand::candidate_ops`] proposal plus a
//! no-expansion control. Function preservation makes the comparison sound:
//! every branch starts from the *identical* function, so after a short
//! probe-training budget the eval-loss differences are attributable to the
//! added capacity, not to init luck. The winner by loss improvement per
//! unit of probe compute is committed; if the control wins, the model
//! isn't capacity-bound yet and training simply continues.
//!
//! Probing is native-only by construction: it drives
//! [`crate::autodiff::loss_and_grads`] directly on cloned state (params,
//! optimizer moments, data stream), so the live run is never perturbed —
//! the probe's batches are the very ones the main loop consumes next,
//! evaluated on a clone of the batcher.

use crate::autodiff::loss_and_grads;
use crate::config::{GrowthSchedule, PolicyConfig, TrainConfig};
use crate::data::Batcher;
use crate::error::{Error, Result};
use crate::expand::{candidate_ops, Expandable, ExpandOptions, ExpansionPlan, Init};
use crate::json::Value;
use crate::model;
use crate::optim::{clip_global_norm, Optimizer};
use crate::params::ParamStore;
use crate::rng::Pcg32;

use super::{scaled_total, Decision, GrowthPolicy, PlateauDetector, PolicyCtx, TrainObs};

/// One probed candidate's outcome (also consumed by
/// `examples/schedule_search.rs` for its ranking table).
#[derive(Clone, Debug)]
pub struct CandidateScore {
    /// The candidate plan; the identity plan is the control (keep training
    /// the current architecture).
    pub plan: ExpansionPlan,
    /// Scalar parameter count of the branch (== `plan.params_after()`).
    pub params: usize,
    /// Probe eval loss immediately after branching — equals the base
    /// model's eval loss up to preservation tolerance, which is what makes
    /// the ranking fair.
    pub eval_at_branch: f32,
    /// Probe eval loss after `probe_budget` training steps on the branch.
    pub eval_after: f32,
    /// Loss improvement over the shared starting point.
    pub dloss: f64,
    /// Probe training compute from the plan's own estimate
    /// (`plan.est_train_flops` over the probe tokens), in TFLOPs.
    pub probe_compute: f64,
    /// The greedy objective: `dloss / probe_compute`.
    pub score: f64,
}

/// Branch the checkpoint across the control + every candidate plan,
/// probe-train each for `probe_budget` steps on an identical cloned data
/// stream, and score by loss improvement per unit of the *plan's own*
/// compute estimate. Pure native path (no backend, no logger) — callers
/// own run-state cloning semantics.
pub fn rank_candidates(
    params: &ParamStore,
    opt: &Optimizer,
    batcher: &Batcher,
    tcfg: &TrainConfig,
    probe_budget: usize,
    seed: u64,
) -> Result<Vec<CandidateScore>> {
    // deliberately NOT the coordinator's final-eval probe (seed ^ 0xE7A1):
    // scoring candidates on the batch that later reports final_eval_loss
    // would select ops on the test set and bias policy comparisons
    let probe = batcher.probe(tcfg.seed ^ 0x9B0B5EED);
    let base_logits = model::forward(params.config(), params, &probe.tokens)?;
    let base_eval = model::cross_entropy(&base_logits, &probe.targets)?;

    let mut candidates = vec![ExpansionPlan::identity(params.config())];
    for op in candidate_ops(params.config()) {
        candidates.push(ExpansionPlan::new(params.config(), vec![op])?);
    }

    let mut out = Vec::with_capacity(candidates.len());
    for (i, plan) in candidates.into_iter().enumerate() {
        let mut rng = Pcg32::new(seed, 0x6EED ^ i as u64);
        let expand_opts = ExpandOptions { init: Init::Normal(0.02), ..Default::default() };
        let mut branch = plan.materialize(params, &expand_opts, &mut rng)?;
        let mut branch_opt = opt.clone();
        branch_opt.apply_plan(&plan, &expand_opts, &mut rng)?;
        let cfg = *branch.config();
        let eval_at_branch = {
            let logits = model::forward(&cfg, &branch, &probe.tokens)?;
            model::cross_entropy(&logits, &probe.targets)?
        };
        // identical data stream per candidate: clone the live batcher
        let mut stream = batcher.clone();
        for _ in 0..probe_budget {
            let batch = stream.next();
            let (_, mut grads) = loss_and_grads(&cfg, &branch, &batch)?;
            if let Some(max) = tcfg.grad_clip {
                clip_global_norm(&mut grads, max);
            }
            branch_opt.step(&mut branch, &grads)?;
        }
        let eval_after = {
            let logits = model::forward(&cfg, &branch, &probe.tokens)?;
            model::cross_entropy(&logits, &probe.targets)?
        };
        let probe_tokens = (probe_budget * batcher.batch() * cfg.seq) as f64;
        let probe_compute = plan.est_train_flops(probe_tokens) / 1e12;
        let dloss = f64::from(base_eval - eval_after);
        out.push(CandidateScore {
            params: plan.params_after(),
            plan,
            eval_at_branch,
            eval_after,
            dloss,
            probe_compute,
            score: dloss / probe_compute,
        });
    }
    Ok(out)
}

/// See module docs.
pub struct GreedyBranch {
    detector: PlateauDetector,
    total_steps: usize,
    cooldown: usize,
    /// Arch-step deadline forcing a probe round without a plateau verdict
    /// (scaled mean stage budget — greedy has no per-stage table to lean on).
    deadline: Option<usize>,
    probe_budget: usize,
    eval_every: usize,
    /// Stop growing once the model reaches the schedule's final size: the
    /// step budget is matched against the fixed schedule, so unbounded
    /// growth would just starve every architecture of training.
    max_params: usize,
    /// The deadline forces at most ONE probe round per architecture —
    /// without this, a control win past the deadline would re-probe every
    /// subsequent step (deadline_hit stays true until the next expansion
    /// resets arch_step). Plateau-triggered rounds are naturally throttled
    /// by the detector's window refill.
    deadline_armed: bool,
    /// Previous observation's arch_step, to detect segment changes (an
    /// expansion resets arch_step) and re-arm the deadline.
    last_arch_step: usize,
    rng: Pcg32,
}

impl GreedyBranch {
    pub fn new(
        schedule: &GrowthSchedule,
        steps_scale: f64,
        pcfg: &PolicyConfig,
        seed: u64,
    ) -> GreedyBranch {
        let total_steps = scaled_total(schedule, steps_scale);
        let mean_stage = (total_steps / schedule.stages.len()).max(1);
        let deadline = if pcfg.deadline_scale > 0.0 {
            Some(((mean_stage as f64 * pcfg.deadline_scale).round() as usize).max(1))
        } else {
            None
        };
        GreedyBranch {
            detector: PlateauDetector::new(pcfg.window, pcfg.min_slope),
            total_steps,
            cooldown: pcfg.cooldown,
            deadline,
            probe_budget: pcfg.probe_budget,
            eval_every: pcfg.eval_every,
            max_params: schedule.final_config().num_params(),
            deadline_armed: true,
            last_arch_step: 0,
            rng: Pcg32::new(seed, 0x62A7C4),
        }
    }
}

impl GrowthPolicy for GreedyBranch {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn eval_every(&self) -> Option<usize> {
        Some(self.eval_every)
    }

    fn decide(&mut self, obs: &TrainObs, ctx: &PolicyCtx<'_>) -> Decision {
        if obs.global_step >= self.total_steps {
            return Decision::Stop;
        }
        if obs.arch_step <= self.last_arch_step {
            self.deadline_armed = true; // arch_step reset: a new segment began
        }
        self.last_arch_step = obs.arch_step;
        let plateaued = match obs.eval_loss {
            Some(e) => self.detector.observe(e),
            None => false,
        };
        if obs.arch_step < self.cooldown {
            return Decision::Continue;
        }
        let deadline_hit =
            self.deadline_armed && self.deadline.is_some_and(|d| obs.arch_step >= d);
        if !(plateaued || deadline_hit) {
            return Decision::Continue;
        }
        // a probe round is due; whatever it concludes, restart the evidence
        // window (the next plateau verdict needs a full fresh window) and
        // spend the architecture's one deadline credit
        self.detector.reset();
        self.deadline_armed = false;
        if obs.params >= self.max_params {
            return Decision::Continue; // grown out: spend remaining budget training
        }
        let ranked = match rank_candidates(
            ctx.params,
            ctx.opt,
            ctx.batcher,
            ctx.tcfg,
            self.probe_budget,
            self.rng.next_u64(),
        ) {
            Ok(r) => r,
            // a failed probe must not kill the run — skip this round
            Err(_) => return Decision::Continue,
        };
        // candidates that would overshoot the cap are ineligible (the cap
        // is the matched-compute bound, not a soft target); the control is
        // always eligible since current params are below the cap here
        let best = ranked
            .into_iter()
            .filter(|c| c.score.is_finite() && c.params <= self.max_params)
            .max_by(|a, b| a.score.total_cmp(&b.score));
        match best {
            Some(c) if !c.plan.is_identity() => Decision::Expand(c.plan),
            // control won (or no eligible candidate)
            _ => Decision::Continue,
        }
    }

    // Mutable state: the detector window, the probe-seed RNG, and the
    // deadline re-arm latch. The RNG matters for bit-identical resume —
    // each probe round draws its branch seed from it.
    fn snapshot(&self) -> Value {
        let (state, inc, spare) = self.rng.to_parts();
        Value::obj(vec![
            (
                "evals",
                Value::Arr(self.detector.evals().iter().map(|&e| Value::num(e as f64)).collect()),
            ),
            ("deadline_armed", Value::Bool(self.deadline_armed)),
            ("last_arch_step", Value::num(self.last_arch_step as f64)),
            (
                "rng",
                Value::obj(vec![
                    ("state", Value::str(format!("{state:016x}"))),
                    ("inc", Value::str(format!("{inc:016x}"))),
                    ("spare_bits", match spare {
                        Some(z) => Value::str(format!("{:016x}", z.to_bits())),
                        None => Value::Null,
                    }),
                ]),
            ),
        ])
    }

    fn restore(&mut self, state: &Value) -> Result<()> {
        self.detector.reset();
        for e in state.req("evals")?.as_arr()? {
            self.detector.push_eval(e.as_f64()? as f32);
        }
        self.deadline_armed = state.req("deadline_armed")?.as_bool()?;
        self.last_arch_step = state.req("last_arch_step")?.as_usize()?;
        let rng = state.req("rng")?;
        let hex = |v: &Value| -> Result<u64> {
            let s = v.as_str()?;
            u64::from_str_radix(s, 16)
                .map_err(|_| Error::Checkpoint(format!("greedy rng: bad hex {s:?}")))
        };
        let spare = match rng.req("spare_bits")? {
            Value::Null => None,
            bits => Some(f64::from_bits(hex(bits)?)),
        };
        self.rng = Pcg32::from_parts(hex(rng.req("state")?)?, hex(rng.req("inc")?)?, spare);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, PolicyKind};
    use crate::data::CorpusKind;
    use crate::growth::testutil::drive;
    use crate::json::Value;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn sched() -> GrowthSchedule {
        GrowthSchedule::from_json(
            &Value::parse(
                r#"{
                    "name": "g", "batch": 2, "seq": 8, "vocab": 16,
                    "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                    "stages": [
                        {"steps": 10},
                        {"steps": 10, "apply": [{"op":"mlp","p":32}]}
                    ]
                }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn rank_candidates_branches_preserve_and_score() {
        let cfg = tiny_cfg();
        let tcfg = TrainConfig::default();
        let mut rng = Pcg32::seeded(3);
        let params = ParamStore::init(&cfg, &mut rng, 0.05);
        let opt = Optimizer::new(&tcfg, &params);
        let batcher =
            Batcher::from_corpus(CorpusKind::MarkovText, 5_000, cfg.vocab, cfg.seq, 2, 9).unwrap();

        let ranked = rank_candidates(&params, &opt, &batcher, &tcfg, 2, 42).unwrap();
        assert_eq!(ranked.len(), 7, "control + six candidates");
        assert!(ranked[0].plan.is_identity(), "first entry is the control");
        let base_eval = ranked[0].eval_at_branch;
        for c in &ranked {
            // the paper's property, load-bearing for the ranking: every
            // branch starts from the same function as the base
            assert!(
                (c.eval_at_branch - base_eval).abs() <= 1e-4,
                "{:?}: branch eval {} != base {}",
                c.plan.ops(),
                c.eval_at_branch,
                base_eval
            );
            assert!(c.eval_after.is_finite(), "{:?}", c.plan.ops());
            assert!(c.probe_compute > 0.0, "{:?}", c.plan.ops());
            assert!(c.score.is_finite(), "{:?}", c.plan.ops());
            assert_eq!(c.params, c.plan.params_after(), "score params must be the plan's");
        }
        // expansions really did grow
        assert!(ranked[1..].iter().all(|c| c.params > ranked[0].params));
        // and costlier plans carry larger compute estimates than the control
        assert!(ranked[1..].iter().all(|c| c.probe_compute > ranked[0].probe_compute));
    }

    #[test]
    fn rank_candidates_is_deterministic() {
        let cfg = tiny_cfg();
        let tcfg = TrainConfig::default();
        let mut rng = Pcg32::seeded(4);
        let params = ParamStore::init(&cfg, &mut rng, 0.05);
        let opt = Optimizer::new(&tcfg, &params);
        let batcher =
            Batcher::from_corpus(CorpusKind::MarkovText, 5_000, cfg.vocab, cfg.seq, 2, 9).unwrap();
        let a = rank_candidates(&params, &opt, &batcher, &tcfg, 2, 7).unwrap();
        let b = rank_candidates(&params, &opt, &batcher, &tcfg, 2, 7).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.eval_after.to_bits(), y.eval_after.to_bits(), "{:?}", x.plan.ops());
        }
    }

    #[test]
    fn greedy_policy_runs_probe_rounds_without_perturbing_ctx() {
        let pcfg = PolicyConfig {
            kind: PolicyKind::Greedy,
            eval_every: 1,
            window: 2,
            min_slope: 0.5,
            cooldown: 0,
            deadline_scale: 0.0,
            probe_budget: 1,
        };
        pcfg.validate().unwrap();
        let mut p = GreedyBranch::new(&sched(), 1.0, &pcfg, 11);
        assert_eq!(p.eval_every(), Some(1));
        // flat eval stream triggers probe rounds; drive()'s zero-params
        // context gives no candidate an edge, so decisions just must be
        // well-formed and the run must reach its budget
        let obs: Vec<(f32, Option<f32>)> = (0..20).map(|_| (2.0, Some(2.0))).collect();
        let got = drive(&mut p, &obs);
        assert_eq!(got.len(), 20);
        assert_eq!(*got.last().unwrap(), Decision::Stop);
        for d in &got {
            if let Decision::Expand(plan) = d {
                assert_eq!(plan.ops().len(), 1, "greedy commits exactly one op per boundary");
            }
        }
    }

    #[test]
    fn snapshot_restore_round_trips_rng_and_window() {
        let pcfg = PolicyConfig {
            kind: PolicyKind::Greedy,
            eval_every: 1,
            window: 3,
            min_slope: 0.5,
            cooldown: 0,
            deadline_scale: 0.0,
            probe_budget: 1,
        };
        let mut p = GreedyBranch::new(&sched(), 1.0, &pcfg, 11);
        // advance the probe-seed rng and part-fill the window
        let _ = p.rng.next_u64();
        p.detector.push_eval(2.5);
        p.detector.push_eval(2.25);
        p.deadline_armed = false;
        p.last_arch_step = 7;
        let snap = p.snapshot();

        let mut q = GreedyBranch::new(&sched(), 1.0, &pcfg, 11);
        q.restore(&snap).unwrap();
        assert_eq!(q.detector.evals(), p.detector.evals());
        assert!(!q.deadline_armed);
        assert_eq!(q.last_arch_step, 7);
        assert_eq!(q.rng.to_parts(), p.rng.to_parts());
        assert_eq!(q.rng.next_u64(), p.rng.next_u64(), "probe seeds must continue identically");
    }

    #[test]
    fn greedy_respects_param_cap() {
        let pcfg = PolicyConfig {
            kind: PolicyKind::Greedy,
            eval_every: 1,
            window: 2,
            min_slope: 0.5,
            cooldown: 0,
            deadline_scale: 0.0,
            probe_budget: 1,
        };
        let mut p = GreedyBranch::new(&sched(), 1.0, &pcfg, 11);
        let cap = sched().final_config().num_params();
        let cfg = tiny_cfg();
        let params = ParamStore::zeros(&cfg);
        let tcfg = TrainConfig::default();
        let opt = Optimizer::new(&tcfg, &params);
        let batcher =
            Batcher::from_corpus(CorpusKind::MarkovText, 2_000, cfg.vocab, cfg.seq, 2, 1).unwrap();
        let ctx = PolicyCtx { params: &params, opt: &opt, batcher: &batcher, tcfg: &tcfg };
        // window full + at-cap params: the policy must decline to probe
        for step in 1..=3 {
            let obs = TrainObs {
                global_step: step,
                arch_step: step,
                train_loss: 2.0,
                eval_loss: Some(2.0),
                tokens_seen: step * 16,
                est_flops: step as f64,
                params: cap, // pretend we're already at the schedule's final size
            };
            assert_eq!(p.decide(&obs, &ctx), Decision::Continue, "step {step}");
        }
    }
}
