//! The loss-plateau policy: staged ops, adaptive timing.
//!
//! The schedule still says *what* grows (its per-stage `apply` lists, in
//! order); the policy decides *when*. A [`PlateauDetector`] watches the
//! eval-loss stream: when the mean per-eval improvement over a sliding
//! window drops below `min_slope`, the current capacity has stopped paying
//! for its steps and the next staged expansion fires. Two guard rails keep
//! it well-behaved:
//!
//! * **cooldown** — no expansion may fire within `cooldown` steps of
//!   entering an architecture (post-surgery, new zero-init capacity needs
//!   a few steps of gradient signal before progress is judged);
//! * **deadline** — if no plateau is detected within `deadline_scale` ×
//!   the stage's scheduled steps, the expansion fires anyway, so a noisy
//!   eval stream degrades to "a bit later than scheduled", never "never".
//!
//! The run stops at the schedule's (scaled) total step budget, making
//! plateau runs compute-comparable with fixed-schedule runs. Because
//! per-segment deadlines compound (boundary *i* being late delays every
//! later boundary), a **budget backstop** force-fires pending expansions
//! once the remaining budget is only just enough to give each one a
//! minimal segment — the stop budget can cut training short, but never
//! silently drop staged growth.

use std::collections::VecDeque;

use crate::config::{GrowthSchedule, PolicyConfig};
use crate::error::{Error, Result};
use crate::expand::ExpansionPlan;
use crate::json::Value;

use super::{scaled_steps, scaled_total, Decision, GrowthPolicy, PolicyCtx, TrainObs};

/// Windowed eval-loss slope detector (pure state machine, unit-testable
/// without a trainer). Feed it one eval loss at a time; it reports whether
/// the stream has plateaued.
#[derive(Clone, Debug)]
pub struct PlateauDetector {
    window: usize,
    min_slope: f32,
    evals: VecDeque<f32>,
}

impl PlateauDetector {
    /// `window` >= 2 evals; `min_slope` is the minimum mean per-eval loss
    /// improvement that still counts as progress.
    pub fn new(window: usize, min_slope: f32) -> PlateauDetector {
        PlateauDetector { window: window.max(2), min_slope, evals: VecDeque::new() }
    }

    /// Observe one eval loss. Returns `true` when the window is full and
    /// the mean per-eval improvement across it fell below `min_slope`.
    /// Non-finite evals (NaN/inf — e.g. a diverging probe) clear the
    /// window: corrupt evidence must never trigger surgery.
    pub fn observe(&mut self, eval_loss: f32) -> bool {
        if !eval_loss.is_finite() {
            self.evals.clear();
            return false;
        }
        self.evals.push_back(eval_loss);
        if self.evals.len() > self.window {
            self.evals.pop_front();
        }
        if self.evals.len() < self.window {
            return false; // window longer than the history so far: no verdict
        }
        let first = *self.evals.front().expect("window full");
        let last = *self.evals.back().expect("window full");
        let slope = (first - last) / (self.window - 1) as f32;
        slope < self.min_slope
    }

    /// Forget all history (called across expansion boundaries — the old
    /// architecture's losses say nothing about the new one's progress).
    pub fn reset(&mut self) {
        self.evals.clear();
    }

    /// Evals currently held (diagnostics/tests).
    pub fn len(&self) -> usize {
        self.evals.len()
    }

    /// The held evals, oldest first (checkpoint snapshot path).
    pub fn evals(&self) -> &VecDeque<f32> {
        &self.evals
    }

    /// Append one eval without producing a verdict (checkpoint restore
    /// path — the stream was already judged before the snapshot).
    pub fn push_eval(&mut self, eval_loss: f32) {
        self.evals.push_back(eval_loss);
        while self.evals.len() > self.window {
            self.evals.pop_front();
        }
    }

    pub fn is_empty(&self) -> bool {
        self.evals.is_empty()
    }
}

/// One pending staged expansion: the validated plan plus the arch-step
/// deadline by which it fires even without a plateau verdict.
struct PendingExpansion {
    plan: ExpansionPlan,
    deadline: Option<usize>,
}

/// See module docs.
pub struct LossPlateau {
    detector: PlateauDetector,
    pending: VecDeque<PendingExpansion>,
    total_steps: usize,
    cooldown: usize,
    eval_every: usize,
}

impl LossPlateau {
    pub fn new(schedule: &GrowthSchedule, steps_scale: f64, pcfg: &PolicyConfig) -> LossPlateau {
        // boundary into stage i is judged while training stage i-1, so its
        // deadline scales stage i-1's budget
        let mut pending = VecDeque::new();
        for i in 1..schedule.stages.len() {
            let ops = schedule.stages[i].apply.clone();
            if ops.is_empty() {
                continue; // nothing to fire — plateau ignores no-op stages
            }
            // stage configs chain through no-op stages (a skipped stage's
            // config equals its predecessor's), so stage i-1's config is
            // always the live config when this plan fires
            let plan = ExpansionPlan::new(&schedule.stages[i - 1].config, ops)
                .expect("schedule ops validated at parse time");
            debug_assert_eq!(plan.target_config(), &schedule.stages[i].config);
            let prev_budget = scaled_steps(schedule.stages[i - 1].steps, steps_scale);
            let deadline = if pcfg.deadline_scale > 0.0 {
                Some(((prev_budget as f64 * pcfg.deadline_scale).round() as usize).max(1))
            } else {
                None
            };
            pending.push_back(PendingExpansion { plan, deadline });
        }
        LossPlateau {
            detector: PlateauDetector::new(pcfg.window, pcfg.min_slope),
            pending,
            total_steps: scaled_total(schedule, steps_scale),
            cooldown: pcfg.cooldown,
            eval_every: pcfg.eval_every,
        }
    }
}

impl GrowthPolicy for LossPlateau {
    fn name(&self) -> &'static str {
        "plateau"
    }

    fn eval_every(&self) -> Option<usize> {
        Some(self.eval_every)
    }

    fn decide(&mut self, obs: &TrainObs, _ctx: &PolicyCtx<'_>) -> Decision {
        if obs.global_step >= self.total_steps {
            return Decision::Stop;
        }
        // keep the detector fed even while ineligible to fire, so the
        // verdict is ready the moment the cooldown lifts
        let plateaued = match obs.eval_loss {
            Some(e) => self.detector.observe(e),
            None => false,
        };
        if self.pending.is_empty() {
            return Decision::Continue; // all staged growth spent: train out the budget
        }
        // budget backstop: per-segment deadlines bound *per-boundary*
        // lateness, but lateness compounds — once the remaining budget is
        // only just enough to give each pending expansion a minimal
        // segment, fire now (overriding cooldown and deadline) so staged
        // growth is never silently dropped at the stop budget
        let reserve = self.cooldown.max(1);
        let budget_pressure =
            obs.global_step + self.pending.len() * reserve >= self.total_steps;
        if !budget_pressure {
            if obs.arch_step < self.cooldown {
                return Decision::Continue; // cooldown suppression
            }
            let deadline_hit = self
                .pending
                .front()
                .expect("checked non-empty")
                .deadline
                .is_some_and(|d| obs.arch_step >= d);
            if !(plateaued || deadline_hit) {
                return Decision::Continue;
            }
        }
        let fired = self.pending.pop_front().expect("checked non-empty");
        self.detector.reset();
        Decision::Expand(fired.plan)
    }

    // Mutable state: the detector's eval window and how many staged
    // expansions remain. Deadlines/cooldown are config-derived and come
    // back identically from the schedule at resume. f32 evals survive the
    // JSON round trip exactly (f64 shortest-round-trip formatting).
    fn snapshot(&self) -> Value {
        Value::obj(vec![
            ("pending", Value::num(self.pending.len() as f64)),
            (
                "evals",
                Value::Arr(self.detector.evals.iter().map(|&e| Value::num(e as f64)).collect()),
            ),
        ])
    }

    fn restore(&mut self, state: &Value) -> Result<()> {
        let pending = state.req("pending")?.as_usize()?;
        if pending > self.pending.len() {
            return Err(Error::Checkpoint(format!(
                "plateau policy: checkpoint has {pending} expansions pending but the \
                 schedule only defines {}",
                self.pending.len()
            )));
        }
        while self.pending.len() > pending {
            self.pending.pop_front();
        }
        self.detector.evals.clear();
        for e in state.req("evals")?.as_arr()? {
            self.detector.evals.push_back(e.as_f64()? as f32);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::drive;
    use crate::json::Value;

    fn sched() -> GrowthSchedule {
        GrowthSchedule::from_json(
            &Value::parse(
                r#"{
                    "name": "pl", "batch": 2, "seq": 8, "vocab": 16,
                    "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                    "stages": [
                        {"steps": 10},
                        {"steps": 10, "apply": [{"op":"mlp","p":32}]},
                        {"steps": 10, "apply": [{"op":"heads_add","count":1}]}
                    ]
                }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    fn pcfg(window: usize, min_slope: f32, cooldown: usize, deadline_scale: f64) -> PolicyConfig {
        PolicyConfig {
            kind: crate::config::PolicyKind::Plateau,
            eval_every: 1,
            window,
            min_slope,
            cooldown,
            deadline_scale,
            ..Default::default()
        }
    }

    // ---- detector ----------------------------------------------------------

    #[test]
    fn detector_slope_is_mean_improvement_over_window() {
        let mut d = PlateauDetector::new(3, 0.05);
        for e in [3.0, 2.9, 2.8] {
            let fired = d.observe(e);
            assert!(!fired, "slope 0.1/eval is progress");
        }
        // [2.9, 2.8, 2.79]: slope (2.9-2.79)/2 = 0.055 — still just progress
        assert!(!d.observe(2.79));
        // [2.8, 2.79, 2.785]: slope (2.8-2.785)/2 = 0.0075 < 0.05 — plateau
        assert!(d.observe(2.785));
    }

    #[test]
    fn detector_nan_and_inf_clear_history() {
        let mut d = PlateauDetector::new(2, 0.05);
        assert!(!d.observe(2.0));
        assert!(!d.observe(f32::NAN), "NaN must never fire");
        assert_eq!(d.len(), 0, "NaN clears the window");
        assert!(!d.observe(2.0), "window refilling after NaN");
        assert!(!d.observe(f32::INFINITY));
        assert!(d.is_empty());
        // a fresh flat pair after the reset can fire again
        assert!(!d.observe(2.0));
        assert!(d.observe(2.0));
    }

    #[test]
    fn detector_window_longer_than_history_never_fires() {
        // window of 10, only 5 perfectly flat evals: no verdict possible
        let mut d = PlateauDetector::new(10, 0.05);
        for _ in 0..5 {
            assert!(!d.observe(2.0));
        }
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn detector_reset_forgets() {
        let mut d = PlateauDetector::new(2, 0.05);
        assert!(!d.observe(2.0));
        d.reset();
        assert!(!d.observe(2.0), "post-reset window is part-full again");
        assert!(d.observe(2.0));
    }

    // ---- policy ------------------------------------------------------------

    #[test]
    fn plateau_fires_staged_ops_in_order_then_stops_at_budget() {
        // flat losses + tiny window + no cooldown: fires as soon as legal
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(2, 0.5, 0, 0.0));
        assert_eq!(p.eval_every(), Some(1));
        let obs: Vec<(f32, Option<f32>)> = (0..30).map(|_| (2.0, Some(2.0))).collect();
        let got = drive(&mut p, &obs);
        let expands: Vec<(usize, usize)> = got
            .iter()
            .enumerate()
            .filter_map(|(i, d)| match d {
                Decision::Expand(plan) => Some((i + 1, plan.ops().len())),
                _ => None,
            })
            .collect();
        // window fills at eval 2 -> first fire at step 2; detector resets,
        // refills over 2 more evals -> second at step 4
        assert_eq!(expands, vec![(2, 1), (4, 1)]);
        assert_eq!(*got.last().unwrap(), Decision::Stop, "stops at 30-step budget");
        assert!(!got[..29].iter().any(|d| *d == Decision::Stop));
    }

    #[test]
    fn cooldown_suppresses_early_fire() {
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(2, 0.5, 5, 0.0));
        let obs: Vec<(f32, Option<f32>)> = (0..12).map(|_| (2.0, Some(2.0))).collect();
        let got = drive(&mut p, &obs);
        let first_expand = got.iter().position(|d| matches!(d, Decision::Expand(_))).unwrap();
        assert_eq!(first_expand + 1, 5, "suppressed until arch_step hits cooldown");
        // second fire also waits out the (restarted) cooldown
        let second_expand =
            got.iter().skip(first_expand + 1).position(|d| matches!(d, Decision::Expand(_))).unwrap();
        assert_eq!(second_expand + 1, 5);
    }

    #[test]
    fn descending_loss_defers_expansion_until_budget_backstop() {
        // steady 0.05/eval improvement (above min_slope 0.01), no deadline:
        // no plateau fire — but the budget backstop must still get both
        // staged expansions in before the 30-step budget. cooldown 0 ⇒
        // reserve 1 step per pending expansion: fire at 28 (2 pending) and
        // 29 (1 pending).
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(3, 0.01, 0, 0.0));
        let obs: Vec<(f32, Option<f32>)> =
            (0..29).map(|i| (2.0, Some(3.0 - 0.05 * i as f32))).collect();
        let got = drive(&mut p, &obs);
        let expand_at: Vec<usize> = got
            .iter()
            .enumerate()
            .filter(|(_, d)| matches!(d, Decision::Expand(_)))
            .map(|(i, _)| i + 1)
            .collect();
        assert!(
            !got[..27].iter().any(|d| matches!(d, Decision::Expand(_))),
            "steady improvement must hold off expansion until budget pressure"
        );
        assert_eq!(expand_at, vec![28, 29], "backstop fires all staged growth before the budget");
    }

    #[test]
    fn budget_backstop_reserves_cooldown_per_pending_expansion() {
        // cooldown 5 ⇒ reserve 5 steps per pending expansion: with a
        // never-plateauing stream and no deadline, pressure hits at
        // 30 - 2*5 = 20 (2 pending) then 30 - 5 = 25 (1 pending)
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(3, 0.01, 5, 0.0));
        let obs: Vec<(f32, Option<f32>)> =
            (0..29).map(|i| (2.0, Some(5.0 - 0.05 * i as f32))).collect();
        let got = drive(&mut p, &obs);
        let expand_at: Vec<usize> = got
            .iter()
            .enumerate()
            .filter(|(_, d)| matches!(d, Decision::Expand(_)))
            .map(|(i, _)| i + 1)
            .collect();
        assert_eq!(expand_at, vec![20, 25]);
    }

    #[test]
    fn deadline_forces_fire_despite_progress() {
        // same descending stream, but deadline_scale 1.5 over a 10-step
        // stage -> forced fire at arch_step 15
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(3, 0.01, 0, 1.5));
        let obs: Vec<(f32, Option<f32>)> =
            (0..29).map(|i| (2.0, Some(3.0 - 0.05 * i as f32))).collect();
        let got = drive(&mut p, &obs);
        let first_expand = got.iter().position(|d| matches!(d, Decision::Expand(_))).unwrap();
        assert_eq!(first_expand + 1, 15);
    }

    #[test]
    fn nan_evals_suppress_fire_at_policy_level() {
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(2, 0.5, 0, 0.0));
        let obs: Vec<(f32, Option<f32>)> = (0..6).map(|_| (2.0, Some(f32::NAN))).collect();
        let got = drive(&mut p, &obs);
        assert!(
            !got.iter().any(|d| matches!(d, Decision::Expand(_))),
            "an all-NaN eval stream must never trigger surgery"
        );
    }

    #[test]
    fn snapshot_restore_preserves_detector_window_and_pending() {
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(3, 0.5, 0, 0.0));
        // two evals in a 3-window (no verdict yet), nothing fired
        let _ = drive(&mut p, &[(2.0, Some(2.5)), (2.0, Some(2.25))]);
        let snap = p.snapshot();

        let mut resumed = LossPlateau::new(&sched(), 1.0, &pcfg(3, 0.5, 0, 0.0));
        resumed.restore(&snap).unwrap();
        assert_eq!(resumed.pending.len(), 2);
        assert_eq!(resumed.detector.len(), 2);
        assert_eq!(resumed.detector.evals, p.detector.evals);
        // bit-exact evals: the third flat observation fires on both
        let a = drive(&mut p, &[(2.0, Some(2.25))]);
        let b = drive(&mut resumed, &[(2.0, Some(2.25))]);
        assert!(matches!(a[0], Decision::Expand(_)));
        assert!(matches!(b[0], Decision::Expand(_)));
        assert_eq!(resumed.pending.len(), p.pending.len());
    }

    #[test]
    fn exhausted_staged_ops_continue_to_budget() {
        let mut p = LossPlateau::new(&sched(), 1.0, &pcfg(2, 0.5, 0, 0.0));
        let obs: Vec<(f32, Option<f32>)> = (0..29).map(|_| (2.0, Some(2.0))).collect();
        let got = drive(&mut p, &obs);
        let expands = got.iter().filter(|d| matches!(d, Decision::Expand(_))).count();
        assert_eq!(expands, 2, "only two staged expansions exist");
        assert_eq!(got[28], Decision::Continue, "keeps training after growth is spent");
    }
}
