//! The fixed-schedule policy: replay the stage table verbatim.
//!
//! This is the pre-refactor coordinator expressed as a policy, and the
//! refactor's equivalence oracle: a run driven by `FixedSchedule` must be
//! bit-identical — same batch stream, same surgery RNG draws, same
//! optimizer trajectory — to the old stage-wise loop
//! (`integration_policy.rs` asserts this against a hand-rolled replay).
//!
//! Each boundary is compiled into an [`ExpansionPlan`] at construction:
//! the schedule's per-stage configs make the source config of every
//! boundary known up front, so the whole stage table is validated as a
//! plan sequence before a single training step runs.

use std::collections::VecDeque;

use crate::config::GrowthSchedule;
use crate::error::{Error, Result};
use crate::expand::ExpansionPlan;
use crate::json::Value;

use super::{scaled_steps, scaled_total, Decision, GrowthPolicy, PolicyCtx, TrainObs};

/// Replays a [`GrowthSchedule`]'s stage table: expansion `i` fires exactly
/// when the cumulative scaled step count of stages `0..i` completes, and
/// the run stops after the final stage's budget.
pub struct FixedSchedule {
    /// `(fire_at_global_step, plan)` per stage boundary, in order. No-op
    /// stages (empty `apply`) become identity plans: they split segments
    /// exactly like the old per-stage loop did.
    boundaries: VecDeque<(usize, ExpansionPlan)>,
    total_steps: usize,
}

impl FixedSchedule {
    pub fn new(schedule: &GrowthSchedule, steps_scale: f64) -> FixedSchedule {
        let mut boundaries = VecDeque::new();
        let mut cum = 0usize;
        for (i, stage) in schedule.stages.iter().enumerate() {
            if i > 0 {
                // the boundary into stage i starts from stage i-1's config;
                // the schedule parser already composed every op, so plan
                // construction cannot fail on a loaded schedule
                let plan =
                    ExpansionPlan::new(&schedule.stages[i - 1].config, stage.apply.clone())
                        .expect("schedule ops validated at parse time");
                debug_assert_eq!(plan.target_config(), &stage.config);
                boundaries.push_back((cum, plan));
            }
            cum += scaled_steps(stage.steps, steps_scale);
        }
        FixedSchedule { boundaries, total_steps: scaled_total(schedule, steps_scale) }
    }
}

impl GrowthPolicy for FixedSchedule {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn decide(&mut self, obs: &TrainObs, _ctx: &PolicyCtx<'_>) -> Decision {
        if let Some((fire_at, _)) = self.boundaries.front() {
            if obs.global_step >= *fire_at {
                let (_, plan) = self.boundaries.pop_front().expect("front checked");
                return Decision::Expand(plan);
            }
        }
        if obs.global_step >= self.total_steps {
            Decision::Stop
        } else {
            Decision::Continue
        }
    }

    // The only mutable state is which boundaries already fired; the plans
    // themselves are rebuilt deterministically from the schedule at
    // resume, so the snapshot is just the remaining-boundary count.
    fn snapshot(&self) -> Value {
        Value::obj(vec![("remaining", Value::num(self.boundaries.len() as f64))])
    }

    fn restore(&mut self, state: &Value) -> Result<()> {
        let remaining = state.req("remaining")?.as_usize()?;
        if remaining > self.boundaries.len() {
            return Err(Error::Checkpoint(format!(
                "fixed policy: checkpoint has {remaining} boundaries remaining but the \
                 schedule only defines {}",
                self.boundaries.len()
            )));
        }
        while self.boundaries.len() > remaining {
            self.boundaries.pop_front();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::growth::testutil::drive;
    use crate::json::Value;

    fn sched(json: &str) -> GrowthSchedule {
        GrowthSchedule::from_json(&Value::parse(json).unwrap()).unwrap()
    }

    fn three_stage() -> GrowthSchedule {
        sched(
            r#"{
                "name": "f", "batch": 2, "seq": 8, "vocab": 16,
                "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                "stages": [
                    {"steps": 3},
                    {"steps": 2, "apply": [{"op":"mlp","p":32}]},
                    {"steps": 2, "apply": [{"op":"heads_add","count":1}]}
                ]
            }"#,
        )
    }

    #[test]
    fn fires_boundaries_at_cumulative_steps_then_stops() {
        let mut p = FixedSchedule::new(&three_stage(), 1.0);
        assert!(p.eval_every().is_none(), "fixed policy needs no eval probes");
        let obs: Vec<(f32, Option<f32>)> = (0..7).map(|_| (1.0, None)).collect();
        let got = drive(&mut p, &obs);
        assert_eq!(got.len(), 7);
        assert_eq!(got[0], Decision::Continue);
        assert_eq!(got[1], Decision::Continue);
        assert!(
            matches!(&got[2], Decision::Expand(plan) if plan.ops().len() == 1),
            "{:?}",
            got[2]
        );
        assert_eq!(got[3], Decision::Continue);
        assert!(
            matches!(&got[4], Decision::Expand(plan) if plan.ops().len() == 1),
            "{:?}",
            got[4]
        );
        assert_eq!(got[5], Decision::Continue);
        assert_eq!(got[6], Decision::Stop);
    }

    #[test]
    fn boundary_plans_predict_stage_configs() {
        let s = three_stage();
        let p = FixedSchedule::new(&s, 1.0);
        assert_eq!(p.boundaries.len(), 2);
        for ((_, plan), stage) in p.boundaries.iter().zip(&s.stages[1..]) {
            assert_eq!(plan.target_config(), &stage.config);
            assert_eq!(plan.params_after(), stage.config.num_params());
        }
    }

    #[test]
    fn steps_scale_rescales_boundaries() {
        // scale 2.0: stages of 6/4/4 steps -> boundaries after 6 and 10,
        // stop after 14
        let mut p = FixedSchedule::new(&three_stage(), 2.0);
        let obs: Vec<(f32, Option<f32>)> = (0..14).map(|_| (1.0, None)).collect();
        let got = drive(&mut p, &obs);
        let expand_at: Vec<usize> = got
            .iter()
            .enumerate()
            .filter(|(_, d)| matches!(d, Decision::Expand(_)))
            .map(|(i, _)| i + 1)
            .collect();
        assert_eq!(expand_at, vec![6, 10]);
        assert_eq!(*got.last().unwrap(), Decision::Stop);
    }

    #[test]
    fn snapshot_restore_resumes_mid_schedule() {
        let s = three_stage();
        let mut oracle = FixedSchedule::new(&s, 1.0);
        // fire the first boundary (step 3), snapshot, then check a fresh
        // restored policy replays the rest of the decision stream
        let obs: Vec<(f32, Option<f32>)> = (0..3).map(|_| (1.0, None)).collect();
        let pre = drive(&mut oracle, &obs);
        assert!(matches!(pre[2], Decision::Expand(_)));
        let snap = oracle.snapshot();

        let mut resumed = FixedSchedule::new(&s, 1.0);
        resumed.restore(&snap).unwrap();
        assert_eq!(resumed.boundaries.len(), 1);
        // restore rejects a snapshot claiming more boundaries than exist
        let mut tiny = FixedSchedule::new(&s, 1.0);
        tiny.boundaries.pop_front();
        tiny.boundaries.pop_front();
        assert!(tiny.restore(&Value::obj(vec![("remaining", Value::num(9.0))])).is_err());
    }

    #[test]
    fn no_op_stage_splits_segment_with_identity_plan() {
        let s = sched(
            r#"{
                "name": "noop", "batch": 2, "seq": 8, "vocab": 16,
                "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                "stages": [{"steps": 1}, {"steps": 1}]
            }"#,
        );
        let mut p = FixedSchedule::new(&s, 1.0);
        let got = drive(&mut p, &[(1.0, None), (1.0, None)]);
        match &got[0] {
            Decision::Expand(plan) => assert!(plan.is_identity()),
            other => panic!("expected identity expand, got {other:?}"),
        }
        assert_eq!(got[1], Decision::Stop);
    }
}
