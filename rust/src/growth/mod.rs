//! Growth policies (S17) — the when/what-to-expand decision seam.
//!
//! The paper's §5 future work ("neural architecture search techniques could
//! be applied to determine optimal transformation scheduling") needs the
//! *decision* separated from the *mechanism*. The mechanism — function-
//! preserving parameter surgery — lives in [`crate::expand`]; this module
//! owns the decision: a [`GrowthPolicy`] consumes the per-step
//! [`TrainObs`] stream produced by [`crate::train::train_segment`] and
//! answers with a [`Decision`]. The coordinator is a policy-driven loop:
//!
//! ```text
//! train step ─▶ TrainObs ─▶ policy.decide ─▶ Continue | Expand(plan) | Stop
//!                                             │           │
//!                                             ▼           ▼
//!                                        keep stepping  boundary surgery
//!                                                       (plan.apply_train)
//! ```
//!
//! Decisions carry a validated [`ExpansionPlan`], not a raw op list: the
//! policy commits to a predicted outcome (target config, exact param
//! delta, estimated FLOPs delta) and the boundary holds it to that.
//!
//! Three policies ship:
//! * [`FixedSchedule`] — replays the schedule's stage table verbatim. It is
//!   the **equivalence oracle** for the refactor: a fixed-policy run is
//!   bit-identical (loss trajectory and final parameters) to the
//!   pre-policy stage-wise coordinator, so every pre-existing test keeps
//!   its meaning.
//! * [`LossPlateau`] — keeps the schedule's *what* (the staged op lists)
//!   but decides *when*: a windowed eval-loss slope detector fires the next
//!   staged expansion early when progress stalls, or late (deadline) when
//!   it doesn't.
//! * [`GreedyBranch`] — decides what *and* when: branches the live
//!   checkpoint across [`crate::expand::candidate_ops`] (function
//!   preservation ⇒ every branch starts from identical quality),
//!   probe-trains each for a fixed budget on the native autodiff path, and
//!   commits the best loss-per-compute candidate.
//!
//! Policies are deliberately *observers with veto power*: they never touch
//! parameters. All surgery stays in the coordinator's boundary path, so
//! preservation probes and optimizer-moment surgery run identically no
//! matter which policy asked for the expansion.

pub mod fixed;
pub mod greedy;
pub mod plateau;

pub use fixed::FixedSchedule;
pub use greedy::GreedyBranch;
pub use plateau::{LossPlateau, PlateauDetector};

use crate::config::{GrowthSchedule, PolicyConfig, PolicyKind, TrainConfig};
use crate::data::Batcher;
use crate::expand::ExpansionPlan;
use crate::optim::Optimizer;
use crate::params::ParamStore;

/// One completed training step, as observed by a policy. Produced by
/// [`crate::train::train_segment`] after the optimizer update.
#[derive(Clone, Debug)]
pub struct TrainObs {
    /// Completed optimizer steps across the whole run.
    pub global_step: usize,
    /// Completed steps since entering the current architecture segment.
    pub arch_step: usize,
    /// This step's training loss.
    pub train_loss: f32,
    /// Held-out probe loss, populated every [`GrowthPolicy::eval_every`]
    /// steps (`None` on non-eval steps and for policies that never ask).
    pub eval_loss: Option<f32>,
    /// Tokens consumed so far across the run.
    pub tokens_seen: usize,
    /// Cumulative estimated training FLOPs (6·params·tokens per step — the
    /// 6ND-style accounting the paper's §1 cost argument uses).
    pub est_flops: f64,
    /// Current scalar parameter count.
    pub params: usize,
}

/// A policy's verdict after one observed step.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Keep training the current architecture.
    Continue,
    /// End the segment and apply this validated plan at a boundary. An
    /// identity plan splits the segment (fresh report/checkpoint) without
    /// surgery — how the fixed policy reproduces no-op schedule stages.
    Expand(ExpansionPlan),
    /// End the run.
    Stop,
}

impl Decision {
    /// Short tag for logs (`metrics::RunLogger::decision`).
    pub fn tag(&self) -> &'static str {
        match self {
            Decision::Continue => "continue",
            Decision::Expand(_) => "expand",
            Decision::Stop => "stop",
        }
    }
}

/// Read-only view of the live run state, passed alongside each
/// observation. Most policies ignore it; [`GreedyBranch`] uses it to
/// branch-and-probe candidates (clone params/optimizer/batcher, never
/// mutate the run).
pub struct PolicyCtx<'a> {
    pub params: &'a ParamStore,
    pub opt: &'a Optimizer,
    /// The live data stream; also the source of batch geometry
    /// (`batcher.batch()` / `batcher.seq()`).
    pub batcher: &'a Batcher,
    pub tcfg: &'a TrainConfig,
}

/// The growth-decision seam (see module docs).
pub trait GrowthPolicy {
    /// Policy name for logs and run metadata.
    fn name(&self) -> &'static str;

    /// Steps between eval-loss probes the trainer should feed into
    /// [`TrainObs::eval_loss`]. `None` = this policy needs no eval
    /// evidence (the trainer skips the extra forward entirely).
    fn eval_every(&self) -> Option<usize> {
        None
    }

    /// Whether the trainer should log this policy's decisions to the run
    /// log. On by default; the internal step-budget shim that implements
    /// plain `train_stage` turns it off so non-policy callers (branch
    /// finetuning, benches, probe training) don't emit decision noise.
    fn log_decisions(&self) -> bool {
        true
    }

    /// Judge one completed step.
    fn decide(&mut self, obs: &TrainObs, ctx: &PolicyCtx<'_>) -> Decision;

    /// Serializable snapshot of the policy's mutable state, captured at a
    /// checkpoint (DESIGN.md §16.3). `Null` means "this policy is
    /// stateless" — the default suits shims like the internal step-budget
    /// driver. Shipped policies override both methods so a resumed run
    /// replays the exact decision stream an uninterrupted run would emit.
    fn snapshot(&self) -> crate::json::Value {
        crate::json::Value::Null
    }

    /// Restore state captured by [`GrowthPolicy::snapshot`] on the resume
    /// path. Must accept exactly what `snapshot` produced; the default
    /// accepts only `Null`.
    fn restore(&mut self, state: &crate::json::Value) -> crate::error::Result<()> {
        match state {
            crate::json::Value::Null => Ok(()),
            _ => Err(crate::error::Error::Checkpoint(format!(
                "policy '{}' has no state to restore but the checkpoint carries some",
                self.name()
            ))),
        }
    }
}

/// Per-stage scheduled steps under the coordinator's `steps_scale`
/// (identical rounding to the pre-policy coordinator: per-stage, `max(1)`).
pub(crate) fn scaled_steps(steps: usize, steps_scale: f64) -> usize {
    ((steps as f64 * steps_scale).round() as usize).max(1)
}

/// Total scheduled steps under `steps_scale` — the compute-matched stop
/// budget shared by all three shipped policies.
pub(crate) fn scaled_total(schedule: &GrowthSchedule, steps_scale: f64) -> usize {
    schedule.stages.iter().map(|s| scaled_steps(s.steps, steps_scale)).sum()
}

/// Construct the policy selected by `pcfg.kind` for a schedule. `seed`
/// feeds the greedy policy's probe-branch initializers (normally
/// `TrainConfig::seed`).
pub fn build_policy(
    schedule: &GrowthSchedule,
    steps_scale: f64,
    pcfg: &PolicyConfig,
    seed: u64,
) -> Box<dyn GrowthPolicy> {
    match pcfg.kind {
        PolicyKind::Fixed => Box::new(FixedSchedule::new(schedule, steps_scale)),
        PolicyKind::Plateau => Box::new(LossPlateau::new(schedule, steps_scale, pcfg)),
        PolicyKind::Greedy => Box::new(GreedyBranch::new(schedule, steps_scale, pcfg, seed)),
    }
}

/// Test-only helper: drive a policy through a synthetic
/// `(train_loss, eval_loss)` observation stream against an inert context,
/// collecting every decision. Shared by the per-policy unit suites.
#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::config::ModelConfig;

    pub(crate) fn drive(
        policy: &mut dyn GrowthPolicy,
        losses: &[(f32, Option<f32>)],
    ) -> Vec<Decision> {
        let cfg = ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 };
        let params = ParamStore::zeros(&cfg);
        let tcfg = TrainConfig::default();
        let opt = Optimizer::new(&tcfg, &params);
        let batcher =
            Batcher::from_corpus(crate::data::CorpusKind::MarkovText, 2000, cfg.vocab, cfg.seq, 2, 1)
                .unwrap();
        let ctx = PolicyCtx { params: &params, opt: &opt, batcher: &batcher, tcfg: &tcfg };
        let mut out = Vec::new();
        let mut arch_step = 0usize;
        for (i, (train_loss, eval_loss)) in losses.iter().enumerate() {
            arch_step += 1;
            let obs = TrainObs {
                global_step: i + 1,
                arch_step,
                train_loss: *train_loss,
                eval_loss: *eval_loss,
                tokens_seen: (i + 1) * 16,
                est_flops: (i + 1) as f64,
                params: params.num_scalars(),
            };
            let d = policy.decide(&obs, &ctx);
            if matches!(d, Decision::Expand(_)) {
                arch_step = 0;
            }
            out.push(d);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Value;

    fn sched() -> GrowthSchedule {
        GrowthSchedule::from_json(
            &Value::parse(
                r#"{
                    "name": "p", "batch": 2, "seq": 8, "vocab": 16,
                    "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                    "stages": [
                        {"steps": 10},
                        {"steps": 20, "apply": [{"op":"mlp","p":32}]}
                    ]
                }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn scaling_matches_coordinator_rounding() {
        assert_eq!(scaled_steps(10, 1.0), 10);
        assert_eq!(scaled_steps(10, 0.25), 3); // round(2.5) = 3 (ties away)
        assert_eq!(scaled_steps(10, 0.0), 1); // clamped to 1
        assert_eq!(scaled_total(&sched(), 1.0), 30);
        assert_eq!(scaled_total(&sched(), 0.0), 2);
    }

    #[test]
    fn build_policy_honours_kind() {
        let s = sched();
        let mut pcfg = PolicyConfig::default();
        for kind in [PolicyKind::Fixed, PolicyKind::Plateau, PolicyKind::Greedy] {
            pcfg.kind = kind;
            let p = build_policy(&s, 1.0, &pcfg, 0);
            assert_eq!(p.name(), kind.name());
        }
    }

    #[test]
    fn decision_tags() {
        let cfg = crate::config::ModelConfig {
            layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16,
        };
        assert_eq!(Decision::Continue.tag(), "continue");
        assert_eq!(Decision::Expand(ExpansionPlan::identity(&cfg)).tag(), "expand");
        assert_eq!(Decision::Stop.tag(), "stop");
    }
}
