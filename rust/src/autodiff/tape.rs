//! Forward activation tape (S16b): one training-time forward pass that
//! records exactly the intermediates the backward pass needs.
//!
//! The taped forward mirrors [`crate::model::forward_one`] operation for
//! operation (same kernels — including the fused `rmsnorm_matmul` on the
//! Norm→W1 edge, the online softmax, and the tiled `attn_pv` — in the
//! same order), so its logits are bit-identical to the reference forward;
//! the test below asserts exact equality. What it saves per layer is the
//! minimal set:
//!
//! * the residual-stream input of each half (`x_in`, `x_mid`) — RMSNorm
//!   backward needs its *input*, and the normalized tiles the projection
//!   weight-grads need are *recomputed* from these in the backward pass
//!   (RMSNorm is deterministic, so recompute == stored, bit for bit —
//!   dropping `nrm1`/`nrm2` from the tape saves two `[s, h]` tiles per
//!   layer),
//! * per head: the projected `q`/`k`/`v` and the post-softmax `probs`
//!   (attention backward re-uses probabilities instead of recomputing the
//!   masked softmax),
//! * the head concatenation (`concat`) and the post-ReLU hidden tile
//!   (`hid`) — W^O / W2 weight grads and the ReLU mask.

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::model::MASK_VALUE;
use crate::params::ParamStore;
use crate::tensor::{softmax_rows_online, Tensor};

/// Saved activations for one attention head.
#[derive(Clone, Debug)]
pub struct HeadTape {
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    /// Post-softmax attention probabilities `[s, s]` (masked entries are
    /// exactly zero — the additive `-1e30` mask underflows).
    pub probs: Tensor,
}

/// Saved activations for one transformer layer.
#[derive(Clone, Debug)]
pub struct LayerTape {
    /// Residual stream entering the layer `[s, h]` (`rmsnorm(x_in, g_mha)`
    /// is recomputed by the backward pass, not stored).
    pub x_in: Tensor,
    pub heads: Vec<HeadTape>,
    /// Concatenated head outputs `[s, E*v]`.
    pub concat: Tensor,
    /// Residual stream after the MHA half `[s, h]` (`rmsnorm(x_mid,
    /// g_mlp)` is likewise recomputed on demand).
    pub x_mid: Tensor,
    /// Post-ReLU MLP hidden tile `[s, p]`.
    pub hid: Tensor,
}

/// Full forward tape for one sequence.
#[derive(Clone, Debug)]
pub struct SeqTape {
    pub tokens: Vec<u32>,
    pub layers: Vec<LayerTape>,
    /// Residual stream after the last layer `[s, h]`.
    pub x_final: Tensor,
    /// Output logits `[s, vocab]`.
    pub logits: Tensor,
}

/// Run the reference forward for one sequence, taping activations.
pub fn forward_with_tape(cfg: &ModelConfig, params: &ParamStore, tokens: &[u32]) -> Result<SeqTape> {
    if tokens.len() != cfg.seq {
        return Err(Error::Shape(format!(
            "forward_with_tape: {} tokens, seq={}",
            tokens.len(),
            cfg.seq
        )));
    }
    let embed = params.get("embed")?;
    let pos = params.get("pos")?;
    let mut x = Tensor::zeros(&[cfg.seq, cfg.hidden]);
    for (i, &t) in tokens.iter().enumerate() {
        if t as usize >= cfg.vocab {
            return Err(Error::Shape(format!("token {t} out of vocab {}", cfg.vocab)));
        }
        let erow = embed.row(t as usize);
        let prow = pos.row(i);
        let xrow = x.row_mut(i);
        for (j, r) in xrow.iter_mut().enumerate() {
            *r = erow[j] + prow[j];
        }
    }

    let s = cfg.seq;
    let scale = 1.0 / (cfg.k as f32).sqrt();
    let mut layers = Vec::with_capacity(cfg.layers);
    for n in 0..cfg.layers {
        let x_in = x.clone();
        // ---- MHA half: x += Concat_e(Att(nrm·Wq, nrm·Wk, nrm·Wv)) · Wo ----
        let nrm1 = crate::model::rmsnorm(&x, params.get(&format!("layer_{n}.g_mha"))?)?;
        let mut concat = Tensor::zeros(&[s, cfg.heads * cfg.v]);
        let mut heads = Vec::with_capacity(cfg.heads);
        for e in 0..cfg.heads {
            let q = nrm1.matmul(params.get(&format!("layer_{n}.head_{e}.wq"))?)?;
            let k = nrm1.matmul(params.get(&format!("layer_{n}.head_{e}.wk"))?)?;
            let v = nrm1.matmul(params.get(&format!("layer_{n}.head_{e}.wv"))?)?;
            let mut scores = q.matmul_bt(&k)?;
            scores.scale(scale);
            for i in 0..s {
                for j in (i + 1)..s {
                    scores.set(i, j, MASK_VALUE);
                }
            }
            softmax_rows_online(&mut scores);
            let probs = scores;
            let head = probs.attn_pv(&v)?;
            for i in 0..s {
                let dst = concat.row_mut(i);
                dst[e * cfg.v..(e + 1) * cfg.v].copy_from_slice(head.row(i));
            }
            heads.push(HeadTape { q, k, v, probs });
        }
        let mha_out = concat.matmul(params.get(&format!("layer_{n}.wo"))?)?;
        x.add_assign(&mha_out)?;
        let x_mid = x.clone();

        // ---- MLP half: x += ReLU(Norm(x)·W1 + b1)·W2 + b2, with the
        // Norm→W1 edge fused (bit-identical to the unfused pair) ----
        let mut hid = x.rmsnorm_matmul(
            params.get(&format!("layer_{n}.g_mlp"))?,
            params.get(&format!("layer_{n}.w1"))?,
        )?;
        hid.add_row_broadcast(params.get(&format!("layer_{n}.b1"))?)?;
        hid.map_inplace(|v| v.max(0.0));
        let mut mlp_out = hid.matmul(params.get(&format!("layer_{n}.w2"))?)?;
        mlp_out.add_row_broadcast(params.get(&format!("layer_{n}.b2"))?)?;
        x.add_assign(&mlp_out)?;

        layers.push(LayerTape { x_in, heads, concat, x_mid, hid });
    }

    let x_final = x.clone();
    let logits = x.matmul(params.get("w_out")?)?;
    Ok(SeqTape { tokens: tokens.to_vec(), layers, x_final, logits })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 12, vocab: 32 }
    }

    #[test]
    fn taped_forward_is_bitexact_with_reference_forward() {
        let c = cfg();
        let mut rng = Pcg32::seeded(30);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let tokens: Vec<u32> = (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect();
        let tape = forward_with_tape(&c, &params, &tokens).unwrap();
        let reference = crate::model::forward_one(&c, &params, &tokens).unwrap();
        assert_eq!(tape.logits, reference, "taped forward diverged from model::forward_one");
    }

    #[test]
    fn tape_shapes_are_complete() {
        let c = cfg();
        let mut rng = Pcg32::seeded(31);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let tokens: Vec<u32> = (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect();
        let tape = forward_with_tape(&c, &params, &tokens).unwrap();
        assert_eq!(tape.layers.len(), c.layers);
        for lt in &tape.layers {
            assert_eq!(lt.x_in.shape(), &[c.seq, c.hidden]);
            assert_eq!(lt.heads.len(), c.heads);
            for ht in &lt.heads {
                assert_eq!(ht.q.shape(), &[c.seq, c.k]);
                assert_eq!(ht.k.shape(), &[c.seq, c.k]);
                assert_eq!(ht.v.shape(), &[c.seq, c.v]);
                assert_eq!(ht.probs.shape(), &[c.seq, c.seq]);
                // each probs row is a distribution over the causal prefix
                for i in 0..c.seq {
                    let sum: f32 = ht.probs.row(i).iter().sum();
                    assert!((sum - 1.0).abs() < 1e-5, "probs row {i} sums to {sum}");
                    for j in (i + 1)..c.seq {
                        assert_eq!(ht.probs.at(i, j), 0.0, "mask leaked at ({i},{j})");
                    }
                }
            }
            assert_eq!(lt.concat.shape(), &[c.seq, c.heads * c.v]);
            assert_eq!(lt.x_mid.shape(), &[c.seq, c.hidden]);
            assert_eq!(lt.hid.shape(), &[c.seq, c.mlp]);
            assert!(lt.hid.data().iter().all(|&v| v >= 0.0), "hid must be post-ReLU");
        }
        assert_eq!(tape.x_final.shape(), &[c.seq, c.hidden]);
        assert_eq!(tape.logits.shape(), &[c.seq, c.vocab]);
    }

    #[test]
    fn taped_forward_rejects_bad_inputs() {
        let c = cfg();
        let mut rng = Pcg32::seeded(32);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let too_short = vec![0u32; c.seq - 1];
        assert!(forward_with_tape(&c, &params, &too_short).is_err());
        let mut bad = vec![0u32; c.seq];
        bad[3] = c.vocab as u32;
        assert!(forward_with_tape(&c, &params, &bad).is_err());
    }
}
