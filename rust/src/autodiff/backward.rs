//! Full-model reverse pass (S16c): taped forward → per-parameter grads.
//!
//! [`loss_and_grads`] is the native equivalent of a PJRT `step` artifact:
//! it returns `(mean cross-entropy, canonical-order gradients)` for one
//! batch. Per-sequence tapes are independent, so the batch dimension is
//! **data-parallel**: [`loss_and_grads_pooled`] fans the rows out across a
//! [`crate::parallel::Pool`], each row accumulating into its own zeroed
//! [`ParamStore`] (which buys two invariants for free: every gradient has
//! exactly its parameter's shape, and [`ParamStore::into_tensors`] exports
//! them in the canonical order [`crate::optim::Optimizer::step`] consumes).
//! The per-row stores are then merged by a **fixed-order pairwise tree
//! reduction** keyed on row index, on the calling thread — the reduction
//! order depends only on the batch shape, never on the worker count, so
//! the `(loss, grads)` result is bit-identical at any `--threads` setting
//! (DESIGN.md §11). Optional micro-batching bounds resident memory: rows
//! are processed `micro_batch` at a time and chunk gradients accumulate
//! left-to-right, trading bitwise agreement with the unchunked sum for an
//! O(1e-7)-relative reassociation difference (the loss itself stays
//! bit-identical — its f64 terms always sum in row order).
//!
//! **Within-row parallelism** (DESIGN.md §17): when a chunk has exactly
//! one row — batch-1 fine-tuning, GreedyBranch probe training — the outer
//! fan-out degenerates and the pool is handed *into* the row instead.
//! [`backward_seq_pooled`] fans the per-head attention backward (the
//! dominant cost of a layer's reverse walk) across the pool: each head's
//! four gradient tiles `(dWq, dWk, dWv, d_nrm1_e)` are a pure function of
//! the tape and the shared upstream `d_concat`, so heads compute
//! independently and merge on the calling thread in ascending head order.
//! The merge order depends only on the model shape, never the worker
//! count, so grads stay bit-identical at any `--threads` setting — same
//! argument as the batch-row tree reduction.
//!
//! The walk is the forward tape in reverse (derivations in DESIGN.md §10):
//!
//! ```text
//! d_logits = (softmax - onehot)/count          // cross_entropy_grad
//! dW_out   = x_finalᵀ·d_logits ; dx = d_logits·W_outᵀ
//! per layer, last to first:
//!   MLP half:  b2/W2/ReLU/b1/W1 grads, then rmsnorm_backward(x_mid) and
//!              the residual shortcut both add into dx
//!   MHA half:  Wo grad, per-head attention_backward + Wq/Wk/Wv grads,
//!              then rmsnorm_backward(x_in) + residual shortcut into dx
//! embed/pos: scatter-add dx rows by token id / position
//! ```

use crate::config::ModelConfig;
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::model::rmsnorm;
use crate::parallel::Pool;
use crate::params::ParamStore;
use crate::tensor::Tensor;

use super::ops::{
    attention_backward, col_sums, cross_entropy_grad_with_loss, relu_backward_inplace,
    rmsnorm_backward,
};
use super::tape::{forward_with_tape, SeqTape};

/// Add `delta` into the named gradient accumulator slot.
fn accumulate(grads: &mut ParamStore, name: &str, delta: &Tensor) -> Result<()> {
    grads.get_mut(name)?.add_assign(delta)
}

/// Backward for one taped sequence; accumulates into `grads`. Serial
/// entry point: [`backward_seq_pooled`] with a one-worker pool (the
/// per-head merge below runs in the same fixed order either way, so the
/// two are bit-identical).
pub fn backward_seq(
    cfg: &ModelConfig,
    params: &ParamStore,
    tape: &SeqTape,
    d_logits: &Tensor,
    grads: &mut ParamStore,
) -> Result<()> {
    backward_seq_pooled(cfg, params, tape, d_logits, grads, &Pool::new(1))
}

/// [`backward_seq`] with the per-head attention backward fanned out
/// across `pool` (see the module docs for the determinism argument).
pub fn backward_seq_pooled(
    cfg: &ModelConfig,
    params: &ParamStore,
    tape: &SeqTape,
    d_logits: &Tensor,
    grads: &mut ParamStore,
    pool: &Pool,
) -> Result<()> {
    if d_logits.shape() != tape.logits.shape() {
        return Err(Error::Shape(format!(
            "backward_seq: d_logits {:?} vs logits {:?}",
            d_logits.shape(),
            tape.logits.shape()
        )));
    }
    // logits = x_final · W_out
    accumulate(grads, "w_out", &tape.x_final.matmul_at(d_logits)?)?;
    let mut dx = d_logits.matmul_bt(params.get("w_out")?)?;

    for n in (0..cfg.layers).rev() {
        let lt = &tape.layers[n];

        // ---- MLP half (reverse): x_out = x_mid + ReLU(nrm2·W1+b1)·W2 + b2
        accumulate(grads, &format!("layer_{n}.b2"), &col_sums(&dx)?)?;
        accumulate(grads, &format!("layer_{n}.w2"), &lt.hid.matmul_at(&dx)?)?;
        let mut d_hid = dx.matmul_bt(params.get(&format!("layer_{n}.w2"))?)?;
        relu_backward_inplace(&mut d_hid, &lt.hid)?;
        accumulate(grads, &format!("layer_{n}.b1"), &col_sums(&d_hid)?)?;
        // normalized MLP input: recomputed from x_mid, not stored on the
        // tape (RMSNorm is deterministic — this equals the forward's tile
        // bit for bit)
        let nrm2 = rmsnorm(&lt.x_mid, params.get(&format!("layer_{n}.g_mlp"))?)?;
        accumulate(grads, &format!("layer_{n}.w1"), &nrm2.matmul_at(&d_hid)?)?;
        let d_nrm2 = d_hid.matmul_bt(params.get(&format!("layer_{n}.w1"))?)?;
        let (dx_mid, d_g_mlp) =
            rmsnorm_backward(&lt.x_mid, params.get(&format!("layer_{n}.g_mlp"))?, &d_nrm2)?;
        accumulate(grads, &format!("layer_{n}.g_mlp"), &d_g_mlp)?;
        // residual shortcut (dx passes through) + the normalized path
        dx.add_assign(&dx_mid)?;

        // ---- MHA half (reverse): x_mid = x_in + Concat_e(head_e) · Wo
        accumulate(grads, &format!("layer_{n}.wo"), &lt.concat.matmul_at(&dx)?)?;
        let d_concat = dx.matmul_bt(params.get(&format!("layer_{n}.wo"))?)?;
        // normalized MHA input, recomputed from x_in (see nrm2 above)
        let nrm1 = rmsnorm(&lt.x_in, params.get(&format!("layer_{n}.g_mha"))?)?;
        // within-row fan-out: each head's grad tiles are a pure function
        // of (tape, nrm1, d_concat), so heads run independently on the
        // pool; the subtotals merge below in ascending head order on the
        // calling thread, which keeps the result bit-identical at any
        // worker count (module docs)
        let head_ids: Vec<usize> = (0..cfg.heads).collect();
        let per_head: Vec<Result<(Tensor, Tensor, Tensor, Tensor)>> =
            pool.map(&head_ids, |_, &e| {
                let ht = &lt.heads[e];
                let d_head = d_concat.slice_cols(e * cfg.v, (e + 1) * cfg.v)?;
                let (dq, dk, dv) = attention_backward(&ht.q, &ht.k, &ht.v, &ht.probs, &d_head)?;
                let dwq = nrm1.matmul_at(&dq)?;
                let dwk = nrm1.matmul_at(&dk)?;
                let dwv = nrm1.matmul_at(&dv)?;
                // this head's d(nrm1) subtotal: q-path, then k, then v —
                // the same within-head addition order the serial walk used
                let mut d_nrm1_e =
                    dq.matmul_bt(params.get(&format!("layer_{n}.head_{e}.wq"))?)?;
                d_nrm1_e.add_assign(&dk.matmul_bt(params.get(&format!("layer_{n}.head_{e}.wk"))?)?)?;
                d_nrm1_e.add_assign(&dv.matmul_bt(params.get(&format!("layer_{n}.head_{e}.wv"))?)?)?;
                Ok((dwq, dwk, dwv, d_nrm1_e))
            });
        let mut d_nrm1 = Tensor::zeros(&[cfg.seq, cfg.hidden]);
        for (e, res) in per_head.into_iter().enumerate() {
            let (dwq, dwk, dwv, d_nrm1_e) = res?;
            accumulate(grads, &format!("layer_{n}.head_{e}.wq"), &dwq)?;
            accumulate(grads, &format!("layer_{n}.head_{e}.wk"), &dwk)?;
            accumulate(grads, &format!("layer_{n}.head_{e}.wv"), &dwv)?;
            d_nrm1.add_assign(&d_nrm1_e)?;
        }
        let (dx_in, d_g_mha) =
            rmsnorm_backward(&lt.x_in, params.get(&format!("layer_{n}.g_mha"))?, &d_nrm1)?;
        accumulate(grads, &format!("layer_{n}.g_mha"), &d_g_mha)?;
        dx.add_assign(&dx_in)?;
    }

    // x_0[i] = embed[token_i] + pos[i]
    let d_embed = grads.get_mut("embed")?;
    for (i, &t) in tape.tokens.iter().enumerate() {
        let src = dx.row(i);
        let dst = d_embed.row_mut(t as usize);
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }
    let d_pos = grads.get_mut("pos")?;
    for i in 0..cfg.seq {
        let src = dx.row(i);
        let dst = d_pos.row_mut(i);
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }
    Ok(())
}

/// Forward + backward for one batch row into a fresh zeroed store. The
/// unit of work the pool fans out; pure function of its arguments, so row
/// results cannot depend on scheduling. `inner` is the pool handed to the
/// within-row per-head fan-out — one worker when batch rows already
/// saturate the outer fan-out, the full pool when this row is the only
/// one (batch-1 fine-tuning, probe training).
fn row_loss_and_grads(
    cfg: &ModelConfig,
    params: &ParamStore,
    tokens: &[u32],
    targets: &[u32],
    count: usize,
    inner: &Pool,
) -> Result<(ParamStore, f64)> {
    let tape = forward_with_tape(cfg, params, tokens)?;
    // one pass computes both the gradient and this sequence's loss
    // terms (bit-identical to model::cross_entropy's accumulation)
    let (d_logits, seq_loss) = cross_entropy_grad_with_loss(&tape.logits, targets, count)?;
    let mut grads = ParamStore::zeros(cfg);
    backward_seq_pooled(cfg, params, &tape, &d_logits, &mut grads, inner)?;
    Ok((grads, seq_loss))
}

/// Pairwise tree reduction of per-row gradient stores in fixed index
/// order: round 1 merges (0,1), (2,3), ...; round 2 merges the survivors
/// pairwise again, until one store remains. The pairing is a function of
/// the store count alone, so the summation tree — and therefore every
/// f32 rounding step — is identical no matter how many worker threads
/// produced the inputs.
fn tree_reduce(mut stores: Vec<ParamStore>) -> Result<ParamStore> {
    while stores.len() > 1 {
        let mut next = Vec::with_capacity(stores.len());
        let mut it = stores.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                for (ta, tb) in a.tensors_mut().iter_mut().zip(b.tensors()) {
                    ta.add_assign(tb)?;
                }
            }
            next.push(a);
        }
        stores = next;
    }
    Ok(stores.pop().expect("tree_reduce needs at least one store"))
}

/// One native training step's math: forward (taped) + mean cross-entropy +
/// full backward over the batch. Returns `(loss, canonical-order grads)` —
/// the exact contract of the PJRT `step` artifact.
///
/// Batch rows fan out over `pool`; results are bit-identical at any
/// thread count (see the module docs). `micro_batch` caps how many rows
/// are resident (tape + per-row gradient store) at once: `None` processes
/// the whole batch in one chunk, `Some(m)` accumulates `ceil(rows/m)`
/// chunk gradients left-to-right — same grads to ~1e-6, loss bit-exact.
pub fn loss_and_grads_pooled(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
    pool: &Pool,
    micro_batch: Option<usize>,
) -> Result<(f32, Vec<Tensor>)> {
    if batch.tokens.is_empty() || batch.tokens.len() != batch.targets.len() {
        return Err(Error::Train(format!(
            "loss_and_grads: {} token rows vs {} target rows",
            batch.tokens.len(),
            batch.targets.len()
        )));
    }
    for (toks, tgts) in batch.tokens.iter().zip(&batch.targets) {
        if tgts.len() != toks.len() {
            return Err(Error::Train("loss_and_grads: ragged targets".into()));
        }
    }
    let rows = batch.tokens.len();
    let count: usize = batch.targets.iter().map(Vec::len).sum();
    let micro = micro_batch.unwrap_or(rows).max(1);

    let mut total: Option<ParamStore> = None;
    let mut loss_sum = 0.0f64;
    let mut lo = 0usize;
    while lo < rows {
        let hi = (lo + micro).min(rows);
        let indices: Vec<usize> = (lo..hi).collect();
        // single-row chunk: the outer fan-out has nothing to parallelize,
        // so the pool moves inside the row (per-head backward); multi-row
        // chunks keep the data-parallel fan-out and run rows serially
        // inside their worker
        let inner = if indices.len() == 1 { *pool } else { Pool::new(1) };
        let row_results: Vec<Result<(ParamStore, f64)>> = pool.map(&indices, |_, &r| {
            row_loss_and_grads(cfg, params, &batch.tokens[r], &batch.targets[r], count, &inner)
        });
        let mut stores = Vec::with_capacity(row_results.len());
        for res in row_results {
            let (grads, seq_loss) = res?;
            // fixed row order — bit-identical to the serial f64 sum
            loss_sum += seq_loss;
            stores.push(grads);
        }
        let chunk = tree_reduce(stores)?;
        total = Some(match total {
            None => chunk,
            Some(mut acc) => {
                for (ta, tb) in acc.tensors_mut().iter_mut().zip(chunk.tensors()) {
                    ta.add_assign(tb)?;
                }
                acc
            }
        });
        lo = hi;
    }
    let loss = (loss_sum / count as f64) as f32;
    Ok((loss, total.expect("validated non-empty batch").into_tensors()))
}

/// [`loss_and_grads_pooled`] with the environment-sized pool
/// (`TEXPAND_THREADS`) and no micro-batching — the drop-in serial-looking
/// entry point benches and tests share with the backend.
pub fn loss_and_grads(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
) -> Result<(f32, Vec<Tensor>)> {
    loss_and_grads_pooled(cfg, params, batch, &Pool::from_env(), None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, LayerPosition};
    use crate::expand::{ExpandOptions, ExpansionPlan};
    use crate::prop::Runner;
    use crate::rng::Pcg32;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 2, k: 4, v: 4, mlp: 8, seq: 6, vocab: 12 }
    }

    fn random_batch(cfg: &ModelConfig, rows: usize, rng: &mut Pcg32) -> Batch {
        let row = |rng: &mut Pcg32| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch {
            tokens: (0..rows).map(|_| row(rng)).collect(),
            targets: (0..rows).map(|_| row(rng)).collect(),
        }
    }

    /// Mean cross-entropy of the (f32) forward, accumulated in f64 — the
    /// finite-difference scalarizer (avoids the f32 quantization of the
    /// production loss return value poisoning small differences).
    fn loss_f64(cfg: &ModelConfig, params: &ParamStore, batch: &Batch) -> f64 {
        let mut total = 0.0f64;
        let mut count = 0usize;
        for (toks, tgts) in batch.tokens.iter().zip(&batch.targets) {
            let logits = crate::model::forward_one(cfg, params, toks).unwrap();
            for (i, &tgt) in tgts.iter().enumerate() {
                let row = logits.row(i);
                let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let lse = f64::from(row.iter().map(|x| (x - max).exp()).sum::<f32>()).ln()
                    + f64::from(max);
                total += lse - f64::from(row[tgt as usize]);
                count += 1;
            }
        }
        total / count as f64
    }

    /// Check the analytic grads of the `idx`-th coordinates with the
    /// largest |g| in every tensor against central differences.
    fn check_grads_fd(
        cfg: &ModelConfig,
        params: &ParamStore,
        batch: &Batch,
        coords_per_tensor: usize,
    ) -> Result<(), String> {
        let (_, grads) = loss_and_grads(cfg, params, batch).unwrap();
        let h = 2e-3f32;
        for (ti, (spec, _)) in params.iter().enumerate() {
            let g = &grads[ti];
            // pick the largest-|g| coordinates: best signal-to-noise
            let mut order: Vec<usize> = (0..g.numel()).collect();
            order.sort_by(|&a, &b| {
                g.data()[b].abs().partial_cmp(&g.data()[a].abs()).unwrap()
            });
            for &ci in order.iter().take(coords_per_tensor) {
                let analytic = g.data()[ci];
                let mut plus = params.clone();
                plus.get_mut(&spec.name).unwrap().data_mut()[ci] += h;
                let mut minus = params.clone();
                minus.get_mut(&spec.name).unwrap().data_mut()[ci] -= h;
                let fd =
                    ((loss_f64(cfg, &plus, batch) - loss_f64(cfg, &minus, batch)) / (2.0 * f64::from(h))) as f32;
                let tol = 1e-2 * analytic.abs().max(fd.abs()) + 1.5e-3;
                if (analytic - fd).abs() > tol {
                    return Err(format!(
                        "{}[{ci}]: analytic {analytic} vs fd {fd} (tol {tol})",
                        spec.name
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn full_model_grads_match_finite_differences() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(50);
        let params = ParamStore::init(&cfg, &mut rng, 0.15);
        let batch = random_batch(&cfg, 2, &mut rng);
        check_grads_fd(&cfg, &params, &batch, 5).unwrap();
    }

    #[test]
    fn prop_grads_match_finite_differences_across_configs() {
        // prop-harness sweep: random tiny architectures, seeds and batches;
        // size metric = parameter count so the shrink pass reports the
        // smallest failing architecture.
        Runner::new("autodiff-fd", 6).shrink_budget(10).run_sized(
            &mut |rng| {
                let cfg = ModelConfig {
                    layers: 1 + rng.below(2),
                    hidden: 4 + 4 * rng.below(2),
                    heads: 1 + rng.below(2),
                    k: 2 + 2 * rng.below(2),
                    v: 2 + 2 * rng.below(2),
                    mlp: 4 + 4 * rng.below(2),
                    seq: 4,
                    vocab: 8,
                };
                (cfg, rng.next_u64())
            },
            |(cfg, _)| cfg.num_params(),
            &mut |(cfg, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                let params = ParamStore::init(cfg, &mut rng, 0.15);
                let batch = random_batch(cfg, 1, &mut rng);
                check_grads_fd(cfg, &params, &batch, 2)
            },
        );
    }

    #[test]
    fn grads_are_finite_and_aligned_after_each_of_the_six_expansions() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(51);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 2, &mut rng);
        let (loss_before, _) = loss_and_grads(&cfg, &params, &batch).unwrap();

        let ops: [GrowthOp; 6] = [
            GrowthOp::Mlp { p: 16 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::HeadsExpand { v: 6 },
            GrowthOp::AttnExpand { k: 6 },
            GrowthOp::Hidden { h: 12 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
        ];
        for op in ops {
            let expanded = ExpansionPlan::new(params.config(), vec![op.clone()])
                .unwrap()
                .materialize(&params, &ExpandOptions::default(), &mut Pcg32::seeded(52))
                .unwrap();
            let new_cfg = *expanded.config();
            let (loss_after, grads) = loss_and_grads(&new_cfg, &expanded, &batch).unwrap();
            assert!(loss_after.is_finite(), "{op:?}: non-finite loss");
            // function preservation ⇒ the loss is unchanged by the surgery
            assert!(
                (loss_after - loss_before).abs() <= 1e-4,
                "{op:?}: loss moved {loss_before} -> {loss_after}"
            );
            assert_eq!(grads.len(), expanded.len(), "{op:?}: grad count");
            for (g, (spec, _)) in grads.iter().zip(expanded.iter()) {
                assert_eq!(g.shape(), spec.shape.as_slice(), "{op:?}: {}", spec.name);
                assert!(g.all_finite(), "{op:?}: non-finite grad in {}", spec.name);
            }
        }
    }

    #[test]
    fn gradient_descent_on_native_grads_reduces_loss() {
        // repeated SGD on one fixed batch must drive its loss down — the
        // end-to-end sanity check that the grads point downhill
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(53);
        let mut params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 2, &mut rng);
        let (first, _) = loss_and_grads(&cfg, &params, &batch).unwrap();
        for _ in 0..30 {
            let (loss, grads) = loss_and_grads(&cfg, &params, &batch).unwrap();
            assert!(loss.is_finite());
            for (p, g) in params.tensors_mut().iter_mut().zip(&grads) {
                let mut step = g.clone();
                step.scale(0.2);
                p.sub_assign(&step).unwrap();
            }
        }
        let (last, _) = loss_and_grads(&cfg, &params, &batch).unwrap();
        assert!(last < first, "SGD on native grads failed to descend: {first} -> {last}");
    }

    #[test]
    fn zero_upstream_grad_gives_zero_param_grads() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(54);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let tokens: Vec<u32> = (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
        let tape = forward_with_tape(&cfg, &params, &tokens).unwrap();
        let d_logits = Tensor::zeros(&[cfg.seq, cfg.vocab]);
        let mut grads = ParamStore::zeros(&cfg);
        backward_seq(&cfg, &params, &tape, &d_logits, &mut grads).unwrap();
        for (spec, g) in grads.iter() {
            assert_eq!(g.max_abs(), 0.0, "{} received gradient from zero upstream", spec.name);
        }
    }

    /// Bit patterns of every gradient scalar — the "byte-identical"
    /// comparison (`==` on f32 would also pass for -0.0 vs +0.0).
    fn bits_of(grads: &[Tensor]) -> Vec<Vec<u32>> {
        grads.iter().map(|g| g.data().iter().map(|x| x.to_bits()).collect()).collect()
    }

    #[test]
    fn grads_are_bit_identical_at_any_thread_count() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(60);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 5, &mut rng);
        let (l1, g1) =
            loss_and_grads_pooled(&cfg, &params, &batch, &crate::parallel::Pool::new(1), None)
                .unwrap();
        for threads in [2usize, 3, 8] {
            let pool = crate::parallel::Pool::new(threads);
            let (ln, gn) = loss_and_grads_pooled(&cfg, &params, &batch, &pool, None).unwrap();
            assert_eq!(l1.to_bits(), ln.to_bits(), "loss diverged at {threads} threads");
            assert_eq!(bits_of(&g1), bits_of(&gn), "grads diverged at {threads} threads");
        }
        // the default entry point (env-sized pool) is the same computation
        let (ld, gd) = loss_and_grads(&cfg, &params, &batch).unwrap();
        assert_eq!(l1.to_bits(), ld.to_bits());
        assert_eq!(bits_of(&g1), bits_of(&gd));

        // batch 1: the outer fan-out degenerates to one row, so the pool
        // is handed to the within-row per-head fan-out instead — the
        // fixed-order head merge must keep grads bit-identical there too
        let single = random_batch(&cfg, 1, &mut rng);
        let (sl1, sg1) =
            loss_and_grads_pooled(&cfg, &params, &single, &crate::parallel::Pool::new(1), None)
                .unwrap();
        for threads in [2usize, 4] {
            let pool = crate::parallel::Pool::new(threads);
            let (sln, sgn) = loss_and_grads_pooled(&cfg, &params, &single, &pool, None).unwrap();
            assert_eq!(sl1.to_bits(), sln.to_bits(), "batch-1 loss diverged at {threads} threads");
            assert_eq!(bits_of(&sg1), bits_of(&sgn), "batch-1 grads diverged at {threads} threads");
        }
    }

    #[test]
    fn micro_batched_accumulation_matches_full_batch() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(61);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 6, &mut rng);
        let pool = crate::parallel::Pool::new(2);
        let (full_loss, full_grads) =
            loss_and_grads_pooled(&cfg, &params, &batch, &pool, None).unwrap();
        for micro in [1usize, 2, 4] {
            let (l, g) = loss_and_grads_pooled(&cfg, &params, &batch, &pool, Some(micro)).unwrap();
            // the loss sums its f64 row terms in row order regardless of
            // chunking, so it stays bit-exact; grads reassociate
            assert_eq!(full_loss.to_bits(), l.to_bits(), "micro={micro}");
            assert_eq!(g.len(), full_grads.len(), "micro={micro}");
            for (a, b) in g.iter().zip(&full_grads) {
                assert!(a.max_abs_diff(b).unwrap() <= 1e-6, "micro={micro}");
            }
        }
        // micro >= rows degenerates to exactly the unchunked computation
        let (_, g_over) = loss_and_grads_pooled(&cfg, &params, &batch, &pool, Some(100)).unwrap();
        assert_eq!(bits_of(&g_over), bits_of(&full_grads));
    }

    #[test]
    fn micro_batched_grads_are_thread_count_independent_too() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(62);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 5, &mut rng);
        let (l1, g1) =
            loss_and_grads_pooled(&cfg, &params, &batch, &crate::parallel::Pool::new(1), Some(2))
                .unwrap();
        let (l4, g4) =
            loss_and_grads_pooled(&cfg, &params, &batch, &crate::parallel::Pool::new(4), Some(2))
                .unwrap();
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(bits_of(&g1), bits_of(&g4));
    }

    #[test]
    fn loss_and_grads_rejects_bad_batches() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(55);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        // empty batch
        let empty = Batch { tokens: vec![], targets: vec![] };
        assert!(loss_and_grads(&cfg, &params, &empty).is_err());
        // row-count mismatch
        let mut bad = random_batch(&cfg, 2, &mut rng);
        bad.targets.pop();
        assert!(loss_and_grads(&cfg, &params, &bad).is_err());
        // ragged targets
        let mut ragged = random_batch(&cfg, 2, &mut rng);
        ragged.targets[1].pop();
        assert!(loss_and_grads(&cfg, &params, &ragged).is_err());
        // out-of-vocab target
        let mut oob = random_batch(&cfg, 1, &mut rng);
        oob.targets[0][0] = cfg.vocab as u32;
        assert!(loss_and_grads(&cfg, &params, &oob).is_err());
    }
}
