//! Full-model reverse pass (S16c): taped forward → per-parameter grads.
//!
//! [`loss_and_grads`] is the native equivalent of a PJRT `step` artifact:
//! it returns `(mean cross-entropy, canonical-order gradients)` for one
//! batch. Gradients are accumulated into a zeroed [`ParamStore`], which
//! buys two invariants for free: every gradient has exactly its parameter's
//! shape, and [`ParamStore::into_tensors`] exports them in the canonical
//! order [`crate::optim::Optimizer::step`] consumes.
//!
//! The walk is the forward tape in reverse (derivations in DESIGN.md §10):
//!
//! ```text
//! d_logits = (softmax - onehot)/count          // cross_entropy_grad
//! dW_out   = x_finalᵀ·d_logits ; dx = d_logits·W_outᵀ
//! per layer, last to first:
//!   MLP half:  b2/W2/ReLU/b1/W1 grads, then rmsnorm_backward(x_mid) and
//!              the residual shortcut both add into dx
//!   MHA half:  Wo grad, per-head attention_backward + Wq/Wk/Wv grads,
//!              then rmsnorm_backward(x_in) + residual shortcut into dx
//! embed/pos: scatter-add dx rows by token id / position
//! ```

use crate::config::ModelConfig;
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::tensor::Tensor;

use super::ops::{
    attention_backward, col_sums, cross_entropy_grad_with_loss, relu_backward_inplace,
    rmsnorm_backward,
};
use super::tape::{forward_with_tape, SeqTape};

/// Add `delta` into the named gradient accumulator slot.
fn accumulate(grads: &mut ParamStore, name: &str, delta: &Tensor) -> Result<()> {
    grads.get_mut(name)?.add_assign(delta)
}

/// Backward for one taped sequence; accumulates into `grads`.
pub fn backward_seq(
    cfg: &ModelConfig,
    params: &ParamStore,
    tape: &SeqTape,
    d_logits: &Tensor,
    grads: &mut ParamStore,
) -> Result<()> {
    if d_logits.shape() != tape.logits.shape() {
        return Err(Error::Shape(format!(
            "backward_seq: d_logits {:?} vs logits {:?}",
            d_logits.shape(),
            tape.logits.shape()
        )));
    }
    // logits = x_final · W_out
    accumulate(grads, "w_out", &tape.x_final.matmul_at(d_logits)?)?;
    let mut dx = d_logits.matmul_bt(params.get("w_out")?)?;

    for n in (0..cfg.layers).rev() {
        let lt = &tape.layers[n];

        // ---- MLP half (reverse): x_out = x_mid + ReLU(nrm2·W1+b1)·W2 + b2
        accumulate(grads, &format!("layer_{n}.b2"), &col_sums(&dx)?)?;
        accumulate(grads, &format!("layer_{n}.w2"), &lt.hid.matmul_at(&dx)?)?;
        let mut d_hid = dx.matmul_bt(params.get(&format!("layer_{n}.w2"))?)?;
        relu_backward_inplace(&mut d_hid, &lt.hid)?;
        accumulate(grads, &format!("layer_{n}.b1"), &col_sums(&d_hid)?)?;
        accumulate(grads, &format!("layer_{n}.w1"), &lt.nrm2.matmul_at(&d_hid)?)?;
        let d_nrm2 = d_hid.matmul_bt(params.get(&format!("layer_{n}.w1"))?)?;
        let (dx_mid, d_g_mlp) =
            rmsnorm_backward(&lt.x_mid, params.get(&format!("layer_{n}.g_mlp"))?, &d_nrm2)?;
        accumulate(grads, &format!("layer_{n}.g_mlp"), &d_g_mlp)?;
        // residual shortcut (dx passes through) + the normalized path
        dx.add_assign(&dx_mid)?;

        // ---- MHA half (reverse): x_mid = x_in + Concat_e(head_e) · Wo
        accumulate(grads, &format!("layer_{n}.wo"), &lt.concat.matmul_at(&dx)?)?;
        let d_concat = dx.matmul_bt(params.get(&format!("layer_{n}.wo"))?)?;
        let mut d_nrm1 = Tensor::zeros(&[cfg.seq, cfg.hidden]);
        for e in 0..cfg.heads {
            let ht = &lt.heads[e];
            let d_head = d_concat.slice_cols(e * cfg.v, (e + 1) * cfg.v)?;
            let (dq, dk, dv) = attention_backward(&ht.q, &ht.k, &ht.v, &ht.probs, &d_head)?;
            accumulate(grads, &format!("layer_{n}.head_{e}.wq"), &lt.nrm1.matmul_at(&dq)?)?;
            accumulate(grads, &format!("layer_{n}.head_{e}.wk"), &lt.nrm1.matmul_at(&dk)?)?;
            accumulate(grads, &format!("layer_{n}.head_{e}.wv"), &lt.nrm1.matmul_at(&dv)?)?;
            d_nrm1.add_assign(&dq.matmul_bt(params.get(&format!("layer_{n}.head_{e}.wq"))?)?)?;
            d_nrm1.add_assign(&dk.matmul_bt(params.get(&format!("layer_{n}.head_{e}.wk"))?)?)?;
            d_nrm1.add_assign(&dv.matmul_bt(params.get(&format!("layer_{n}.head_{e}.wv"))?)?)?;
        }
        let (dx_in, d_g_mha) =
            rmsnorm_backward(&lt.x_in, params.get(&format!("layer_{n}.g_mha"))?, &d_nrm1)?;
        accumulate(grads, &format!("layer_{n}.g_mha"), &d_g_mha)?;
        dx.add_assign(&dx_in)?;
    }

    // x_0[i] = embed[token_i] + pos[i]
    let d_embed = grads.get_mut("embed")?;
    for (i, &t) in tape.tokens.iter().enumerate() {
        let src = dx.row(i);
        let dst = d_embed.row_mut(t as usize);
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }
    let d_pos = grads.get_mut("pos")?;
    for i in 0..cfg.seq {
        let src = dx.row(i);
        let dst = d_pos.row_mut(i);
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }
    Ok(())
}

/// One native training step's math: forward (taped) + mean cross-entropy +
/// full backward over the batch. Returns `(loss, canonical-order grads)` —
/// the exact contract of the PJRT `step` artifact.
pub fn loss_and_grads(
    cfg: &ModelConfig,
    params: &ParamStore,
    batch: &Batch,
) -> Result<(f32, Vec<Tensor>)> {
    if batch.tokens.is_empty() || batch.tokens.len() != batch.targets.len() {
        return Err(Error::Train(format!(
            "loss_and_grads: {} token rows vs {} target rows",
            batch.tokens.len(),
            batch.targets.len()
        )));
    }
    let count: usize = batch.targets.iter().map(Vec::len).sum();
    let mut grads = ParamStore::zeros(cfg);
    let mut loss_sum = 0.0f64;
    for (toks, tgts) in batch.tokens.iter().zip(&batch.targets) {
        if tgts.len() != toks.len() {
            return Err(Error::Train("loss_and_grads: ragged targets".into()));
        }
        let tape = forward_with_tape(cfg, params, toks)?;
        // one pass computes both the gradient and this sequence's loss
        // terms (bit-identical to model::cross_entropy's accumulation)
        let (d_logits, seq_loss) = cross_entropy_grad_with_loss(&tape.logits, tgts, count)?;
        backward_seq(cfg, params, &tape, &d_logits, &mut grads)?;
        loss_sum += seq_loss;
    }
    let loss = (loss_sum / count as f64) as f32;
    Ok((loss, grads.into_tensors()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, LayerPosition};
    use crate::expand::{apply_ops, ExpandOptions};
    use crate::prop::Runner;
    use crate::rng::Pcg32;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 2, k: 4, v: 4, mlp: 8, seq: 6, vocab: 12 }
    }

    fn random_batch(cfg: &ModelConfig, rows: usize, rng: &mut Pcg32) -> Batch {
        let row = |rng: &mut Pcg32| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
        Batch {
            tokens: (0..rows).map(|_| row(rng)).collect(),
            targets: (0..rows).map(|_| row(rng)).collect(),
        }
    }

    /// Mean cross-entropy of the (f32) forward, accumulated in f64 — the
    /// finite-difference scalarizer (avoids the f32 quantization of the
    /// production loss return value poisoning small differences).
    fn loss_f64(cfg: &ModelConfig, params: &ParamStore, batch: &Batch) -> f64 {
        let mut total = 0.0f64;
        let mut count = 0usize;
        for (toks, tgts) in batch.tokens.iter().zip(&batch.targets) {
            let logits = crate::model::forward_one(cfg, params, toks).unwrap();
            for (i, &tgt) in tgts.iter().enumerate() {
                let row = logits.row(i);
                let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let lse = f64::from(row.iter().map(|x| (x - max).exp()).sum::<f32>()).ln()
                    + f64::from(max);
                total += lse - f64::from(row[tgt as usize]);
                count += 1;
            }
        }
        total / count as f64
    }

    /// Check the analytic grads of the `idx`-th coordinates with the
    /// largest |g| in every tensor against central differences.
    fn check_grads_fd(
        cfg: &ModelConfig,
        params: &ParamStore,
        batch: &Batch,
        coords_per_tensor: usize,
    ) -> Result<(), String> {
        let (_, grads) = loss_and_grads(cfg, params, batch).unwrap();
        let h = 2e-3f32;
        for (ti, (spec, _)) in params.iter().enumerate() {
            let g = &grads[ti];
            // pick the largest-|g| coordinates: best signal-to-noise
            let mut order: Vec<usize> = (0..g.numel()).collect();
            order.sort_by(|&a, &b| {
                g.data()[b].abs().partial_cmp(&g.data()[a].abs()).unwrap()
            });
            for &ci in order.iter().take(coords_per_tensor) {
                let analytic = g.data()[ci];
                let mut plus = params.clone();
                plus.get_mut(&spec.name).unwrap().data_mut()[ci] += h;
                let mut minus = params.clone();
                minus.get_mut(&spec.name).unwrap().data_mut()[ci] -= h;
                let fd =
                    ((loss_f64(cfg, &plus, batch) - loss_f64(cfg, &minus, batch)) / (2.0 * f64::from(h))) as f32;
                let tol = 1e-2 * analytic.abs().max(fd.abs()) + 1.5e-3;
                if (analytic - fd).abs() > tol {
                    return Err(format!(
                        "{}[{ci}]: analytic {analytic} vs fd {fd} (tol {tol})",
                        spec.name
                    ));
                }
            }
        }
        Ok(())
    }

    #[test]
    fn full_model_grads_match_finite_differences() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(50);
        let params = ParamStore::init(&cfg, &mut rng, 0.15);
        let batch = random_batch(&cfg, 2, &mut rng);
        check_grads_fd(&cfg, &params, &batch, 5).unwrap();
    }

    #[test]
    fn prop_grads_match_finite_differences_across_configs() {
        // prop-harness sweep: random tiny architectures, seeds and batches;
        // size metric = parameter count so the shrink pass reports the
        // smallest failing architecture.
        Runner::new("autodiff-fd", 6).shrink_budget(10).run_sized(
            &mut |rng| {
                let cfg = ModelConfig {
                    layers: 1 + rng.below(2),
                    hidden: 4 + 4 * rng.below(2),
                    heads: 1 + rng.below(2),
                    k: 2 + 2 * rng.below(2),
                    v: 2 + 2 * rng.below(2),
                    mlp: 4 + 4 * rng.below(2),
                    seq: 4,
                    vocab: 8,
                };
                (cfg, rng.next_u64())
            },
            |(cfg, _)| cfg.num_params(),
            &mut |(cfg, seed)| {
                let mut rng = Pcg32::seeded(*seed);
                let params = ParamStore::init(cfg, &mut rng, 0.15);
                let batch = random_batch(cfg, 1, &mut rng);
                check_grads_fd(cfg, &params, &batch, 2)
            },
        );
    }

    #[test]
    fn grads_are_finite_and_aligned_after_each_of_the_six_expansions() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(51);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 2, &mut rng);
        let (loss_before, _) = loss_and_grads(&cfg, &params, &batch).unwrap();

        let ops: [GrowthOp; 6] = [
            GrowthOp::Mlp { p: 16 },
            GrowthOp::HeadsAdd { count: 1 },
            GrowthOp::HeadsExpand { v: 6 },
            GrowthOp::AttnExpand { k: 6 },
            GrowthOp::Hidden { h: 12 },
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
        ];
        for op in ops {
            let expanded = apply_ops(
                &params,
                std::slice::from_ref(&op),
                &mut Pcg32::seeded(52),
                &ExpandOptions::default(),
            )
            .unwrap();
            let new_cfg = *expanded.config();
            let (loss_after, grads) = loss_and_grads(&new_cfg, &expanded, &batch).unwrap();
            assert!(loss_after.is_finite(), "{op:?}: non-finite loss");
            // function preservation ⇒ the loss is unchanged by the surgery
            assert!(
                (loss_after - loss_before).abs() <= 1e-4,
                "{op:?}: loss moved {loss_before} -> {loss_after}"
            );
            assert_eq!(grads.len(), expanded.len(), "{op:?}: grad count");
            for (g, (spec, _)) in grads.iter().zip(expanded.iter()) {
                assert_eq!(g.shape(), spec.shape.as_slice(), "{op:?}: {}", spec.name);
                assert!(g.all_finite(), "{op:?}: non-finite grad in {}", spec.name);
            }
        }
    }

    #[test]
    fn gradient_descent_on_native_grads_reduces_loss() {
        // repeated SGD on one fixed batch must drive its loss down — the
        // end-to-end sanity check that the grads point downhill
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(53);
        let mut params = ParamStore::init(&cfg, &mut rng, 0.1);
        let batch = random_batch(&cfg, 2, &mut rng);
        let (first, _) = loss_and_grads(&cfg, &params, &batch).unwrap();
        for _ in 0..30 {
            let (loss, grads) = loss_and_grads(&cfg, &params, &batch).unwrap();
            assert!(loss.is_finite());
            for (p, g) in params.tensors_mut().iter_mut().zip(&grads) {
                let mut step = g.clone();
                step.scale(0.2);
                p.sub_assign(&step).unwrap();
            }
        }
        let (last, _) = loss_and_grads(&cfg, &params, &batch).unwrap();
        assert!(last < first, "SGD on native grads failed to descend: {first} -> {last}");
    }

    #[test]
    fn zero_upstream_grad_gives_zero_param_grads() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(54);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        let tokens: Vec<u32> = (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect();
        let tape = forward_with_tape(&cfg, &params, &tokens).unwrap();
        let d_logits = Tensor::zeros(&[cfg.seq, cfg.vocab]);
        let mut grads = ParamStore::zeros(&cfg);
        backward_seq(&cfg, &params, &tape, &d_logits, &mut grads).unwrap();
        for (spec, g) in grads.iter() {
            assert_eq!(g.max_abs(), 0.0, "{} received gradient from zero upstream", spec.name);
        }
    }

    #[test]
    fn loss_and_grads_rejects_bad_batches() {
        let cfg = tiny_cfg();
        let mut rng = Pcg32::seeded(55);
        let params = ParamStore::init(&cfg, &mut rng, 0.1);
        // empty batch
        let empty = Batch { tokens: vec![], targets: vec![] };
        assert!(loss_and_grads(&cfg, &params, &empty).is_err());
        // row-count mismatch
        let mut bad = random_batch(&cfg, 2, &mut rng);
        bad.targets.pop();
        assert!(loss_and_grads(&cfg, &params, &bad).is_err());
        // ragged targets
        let mut ragged = random_batch(&cfg, 2, &mut rng);
        ragged.targets[1].pop();
        assert!(loss_and_grads(&cfg, &params, &ragged).is_err());
        // out-of-vocab target
        let mut oob = random_batch(&cfg, 1, &mut rng);
        oob.targets[0][0] = cfg.vocab as u32;
        assert!(loss_and_grads(&cfg, &params, &oob).is_err());
    }
}
