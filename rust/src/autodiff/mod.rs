//! Native reverse-mode training backend (S16).
//!
//! The paper's subject is *progressively expanding the architecture
//! throughout training* — which makes an executable training path the
//! load-bearing wall of the whole reproduction. The PJRT path delegates
//! gradients to AOT-compiled XLA artifacts that this repo cannot build
//! offline; this subsystem removes that dependency with a hand-written
//! reverse pass over the existing [`crate::tensor`] / [`crate::model`]
//! substrate, so the full train → expand → keep-training loop runs
//! anywhere the crate compiles.
//!
//! Layout:
//!
//! * [`ops`] — backward primitives (cross-entropy, RMSNorm, causal
//!   attention, ReLU, bias/column sums), each validated against central
//!   finite differences.
//! * [`tape`] — the taping forward pass: bit-identical logits to
//!   [`crate::model::forward_one`], saving the per-layer activations the
//!   reverse walk consumes.
//! * [`backward`] — the full-model reverse pass: [`loss_and_grads`]
//!   returns `(loss, canonical-order grads)`, the exact contract of a PJRT
//!   `step` artifact, so [`crate::optim::Optimizer::step`] consumes either
//!   source unchanged. Batch rows are data-parallel over the shared
//!   [`crate::parallel::Pool`] with a deterministic fixed-order tree
//!   reduction (bit-identical grads at any thread count), and
//!   [`loss_and_grads_pooled`] adds gradient-accumulation micro-batching.
//! * [`backend`] — the [`ExecBackend`] trait (`forward` + `step` +
//!   `load_stage`) with impls for the PJRT [`crate::runtime::Runtime`] and
//!   the pure-Rust [`NativeBackend`]; `train`, `coordinator` and
//!   `generate` are written against the trait.
//!
//! Gradient correctness is property-tested (`prop`-harness finite
//! differences at 1e-2 relative tolerance, per-op and full-model) and the
//! six expansion ops are checked to keep gradients finite and shapes
//! canonical across surgery; see DESIGN.md §10 for the derivations.

pub mod backend;
pub mod backward;
pub mod ops;
pub mod tape;

pub use backend::{ExecBackend, NativeBackend};
pub use backward::{backward_seq, backward_seq_pooled, loss_and_grads, loss_and_grads_pooled};
pub use tape::{forward_with_tape, SeqTape};
