//! Execution backends (S16d): one trait, two engines.
//!
//! [`ExecBackend`] abstracts the two operations the training stack needs
//! from an execution engine — batched `forward` logits and a training
//! `step` returning `(loss, canonical-order grads)` — plus `load_stage`,
//! which resolves a stage name into an executable handle. Two impls:
//!
//! * [`crate::runtime::Runtime`] — the PJRT path: compiles the stage's AOT
//!   HLO artifacts and executes them (needs `make artifacts` + real xla
//!   bindings).
//! * [`NativeBackend`] — the pure-Rust path: interprets the reference model
//!   ([`crate::model`]) forward and runs the hand-written reverse pass
//!   ([`crate::autodiff::loss_and_grads`]). No artifacts, no Python, fully
//!   offline — `texpand train --backend native` runs the paper's whole
//!   grow-as-you-train loop on it.
//!
//! The native backend deliberately mirrors the PJRT runtime's *strictness*
//! (fixed batch size, exact seq length, config match) even though the
//! interpreter could be lax: train/coordinator/generate treat both engines
//! identically, and the integration suite runs the same scenarios against
//! either.
//!
//! Training steps are **data-parallel over batch rows**: the backend owns
//! a [`crate::parallel::Pool`] (sized by `TEXPAND_THREADS` / the CLI's
//! `--threads`) and fans [`crate::autodiff::loss_and_grads_pooled`] out
//! across it — grads are bit-identical at any thread count thanks to the
//! fixed-order tree reduction. An optional `micro_batch` (CLI
//! `--micro-batch`, or `"micro_batch"` in the schedule JSON) enables
//! gradient accumulation: rows are processed that many at a time, so the
//! schedule's effective batch can exceed what fits resident (tapes +
//! per-row grad stores) at once.

use crate::data::Batch;
use crate::error::{Error, Result};
use crate::model;
use crate::parallel::Pool;
use crate::params::ParamStore;
use crate::runtime::{Manifest, Runtime, StageExec};
use crate::tensor::Tensor;

/// An engine that can execute one architecture stage (see module docs).
pub trait ExecBackend {
    /// Human-readable engine name (run logs, `texpand info`).
    fn platform(&self) -> String;

    /// `true` when `forward` *is* the pure-Rust reference model
    /// ([`crate::model::forward`]), bit for bit. Lets callers that probe
    /// both the reference and the backend (the coordinator's boundary
    /// verification) skip the second, tautologically-identical probe.
    fn is_reference_model(&self) -> bool {
        false
    }

    /// `true` when `load_stage` resolves AOT artifact files out of the
    /// manifest (the PJRT runtime). The coordinator only cross-validates
    /// the manifest against the schedule — and only pins segments to the
    /// manifest's compiled stage table — for such backends; the native
    /// backend synthesizes stage metadata for whatever architecture a
    /// growth policy produces.
    fn needs_artifacts(&self) -> bool {
        true
    }

    /// Resolve a manifest stage into an executable handle.
    fn load_stage(&mut self, manifest: &Manifest, stage_name: &str) -> Result<StageExec>;

    /// Batched forward: one `[seq, vocab]` logits tensor per batch row.
    fn forward(&self, stage: &StageExec, params: &ParamStore, tokens: &[Vec<u32>])
        -> Result<Vec<Tensor>>;

    /// Training step: `(mean cross-entropy, canonical-order gradients)`.
    fn step(&self, stage: &StageExec, params: &ParamStore, batch: &Batch)
        -> Result<(f32, Vec<Tensor>)>;
}

impl ExecBackend for Runtime {
    fn platform(&self) -> String {
        Runtime::platform(self)
    }

    fn load_stage(&mut self, manifest: &Manifest, stage_name: &str) -> Result<StageExec> {
        Runtime::load_stage(self, manifest, stage_name)
    }

    fn forward(
        &self,
        stage: &StageExec,
        params: &ParamStore,
        tokens: &[Vec<u32>],
    ) -> Result<Vec<Tensor>> {
        Runtime::forward(self, stage, params, tokens)
    }

    fn step(&self, stage: &StageExec, params: &ParamStore, batch: &Batch) -> Result<(f32, Vec<Tensor>)> {
        Runtime::step(self, stage, params, batch)
    }
}

/// The pure-Rust autodiff engine (see module docs). No model state: the
/// model is interpreted directly from the [`ParamStore`], so "loading" a
/// stage is just adopting its metadata — the backend carries only its
/// execution policy (worker pool + micro-batch size).
#[derive(Clone, Copy, Debug)]
pub struct NativeBackend {
    pool: Pool,
    micro_batch: Option<usize>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// Environment-sized pool (`TEXPAND_THREADS`, else all cores), no
    /// micro-batching.
    pub fn new() -> NativeBackend {
        NativeBackend { pool: Pool::from_env(), micro_batch: None }
    }

    /// Backend with an explicit worker count (the CLI's `--threads`).
    pub fn with_threads(threads: usize) -> NativeBackend {
        NativeBackend { pool: Pool::new(threads), micro_batch: None }
    }

    /// Override the worker count in place.
    pub fn set_threads(&mut self, threads: usize) {
        self.pool = Pool::new(threads);
    }

    /// Gradient-accumulation chunk size (`None` = whole batch at once).
    pub fn set_micro_batch(&mut self, micro_batch: Option<usize>) {
        self.micro_batch = micro_batch;
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn micro_batch(&self) -> Option<usize> {
        self.micro_batch
    }

    /// Same input discipline as the PJRT runtime: params must match the
    /// stage config, the batch must be exactly the compiled batch size, and
    /// every row exactly `seq` tokens.
    fn check(stage: &StageExec, params: &ParamStore, rows: &[Vec<u32>]) -> Result<()> {
        if params.config() != &stage.meta.config {
            return Err(Error::Runtime(format!(
                "params for {:?} fed to stage '{}' expecting {:?}",
                params.config(),
                stage.meta.name,
                stage.meta.config
            )));
        }
        if rows.len() != stage.batch {
            return Err(Error::Runtime(format!(
                "batch {} rows, stage configured for {}",
                rows.len(),
                stage.batch
            )));
        }
        for row in rows {
            if row.len() != stage.meta.config.seq {
                return Err(Error::Runtime(format!(
                    "sequence of {} tokens, stage configured for seq {}",
                    row.len(),
                    stage.meta.config.seq
                )));
            }
        }
        Ok(())
    }
}

impl ExecBackend for NativeBackend {
    fn platform(&self) -> String {
        "native".to_string()
    }

    fn is_reference_model(&self) -> bool {
        true
    }

    fn needs_artifacts(&self) -> bool {
        false
    }

    fn load_stage(&mut self, manifest: &Manifest, stage_name: &str) -> Result<StageExec> {
        Ok(StageExec::native(manifest.stage(stage_name)?.clone(), manifest.batch))
    }

    fn forward(
        &self,
        stage: &StageExec,
        params: &ParamStore,
        tokens: &[Vec<u32>],
    ) -> Result<Vec<Tensor>> {
        Self::check(stage, params, tokens)?;
        model::forward(&stage.meta.config, params, tokens)
    }

    fn step(&self, stage: &StageExec, params: &ParamStore, batch: &Batch) -> Result<(f32, Vec<Tensor>)> {
        Self::check(stage, params, &batch.tokens)?;
        Self::check(stage, params, &batch.targets)?;
        super::backward::loss_and_grads_pooled(
            &stage.meta.config,
            params,
            batch,
            &self.pool,
            self.micro_batch,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GrowthSchedule;
    use crate::json::Value;
    use crate::rng::Pcg32;

    fn tiny_schedule() -> GrowthSchedule {
        GrowthSchedule::from_json(
            &Value::parse(
                r#"{
                    "name": "be-test", "batch": 2, "seq": 8, "vocab": 16,
                    "base": {"layers":1,"hidden":8,"heads":1,"k":4,"v":4,"mlp":16},
                    "stages": [
                        {"steps": 5},
                        {"steps": 5, "apply": [{"op":"mlp","p":32}]}
                    ]
                }"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn native_backend_runs_both_contract_methods() {
        let sched = tiny_schedule();
        let manifest = Manifest::from_schedule(&sched);
        let mut be = NativeBackend::new();
        assert_eq!(be.platform(), "native");
        let stage = be.load_stage(&manifest, "stage0").unwrap();
        let cfg = stage.meta.config;
        let mut rng = Pcg32::seeded(1);
        let params = ParamStore::init(&cfg, &mut rng, 0.05);
        let batch = Batch::random(&cfg, manifest.batch, 2);

        let logits = be.forward(&stage, &params, &batch.tokens).unwrap();
        assert_eq!(logits.len(), manifest.batch);
        assert_eq!(logits[0].shape(), &[cfg.seq, cfg.vocab]);
        // forward through the backend == the reference model, exactly
        let reference = model::forward(&cfg, &params, &batch.tokens).unwrap();
        assert_eq!(logits, reference);

        let (loss, grads) = be.step(&stage, &params, &batch).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grads.len(), params.len());
    }

    #[test]
    fn native_backend_step_is_thread_count_and_micro_batch_stable() {
        let sched = tiny_schedule();
        let manifest = Manifest::from_schedule(&sched);
        let mut be1 = NativeBackend::with_threads(1);
        let stage = be1.load_stage(&manifest, "stage0").unwrap();
        let cfg = stage.meta.config;
        let mut rng = Pcg32::seeded(7);
        let params = ParamStore::init(&cfg, &mut rng, 0.05);
        let batch = Batch::random(&cfg, manifest.batch, 9);

        let (loss1, grads1) = be1.step(&stage, &params, &batch).unwrap();
        let be4 = NativeBackend::with_threads(4);
        let (loss4, grads4) = be4.step(&stage, &params, &batch).unwrap();
        // serial vs parallel: bit-identical
        assert_eq!(loss1.to_bits(), loss4.to_bits());
        assert_eq!(grads1, grads4);

        // micro-batched accumulation: same step within 1e-6
        let mut bem = NativeBackend::with_threads(2);
        bem.set_micro_batch(Some(1));
        assert_eq!(bem.micro_batch(), Some(1));
        let (loss_m, grads_m) = bem.step(&stage, &params, &batch).unwrap();
        assert_eq!(loss1.to_bits(), loss_m.to_bits());
        for (a, b) in grads_m.iter().zip(&grads1) {
            assert!(a.max_abs_diff(b).unwrap() <= 1e-6);
        }
    }

    #[test]
    fn native_backend_is_strict_about_inputs() {
        let sched = tiny_schedule();
        let manifest = Manifest::from_schedule(&sched);
        let mut be = NativeBackend::new();
        let stage0 = be.load_stage(&manifest, "stage0").unwrap();
        let cfg0 = stage0.meta.config;
        let cfg1 = sched.stages[1].config;
        let mut rng = Pcg32::seeded(3);

        // params for the wrong stage
        let wrong = ParamStore::init(&cfg1, &mut rng, 0.05);
        let batch = Batch::random(&cfg0, manifest.batch, 4);
        assert!(be.forward(&stage0, &wrong, &batch.tokens).is_err());

        let params = ParamStore::init(&cfg0, &mut rng, 0.05);
        // wrong batch size
        let small = Batch::random(&cfg0, manifest.batch - 1, 5);
        assert!(be.forward(&stage0, &params, &small.tokens).is_err());
        // wrong seq length
        let mut ragged = Batch::random(&cfg0, manifest.batch, 6);
        ragged.tokens[0].pop();
        assert!(be.forward(&stage0, &params, &ragged.tokens).is_err());
        // unknown stage name
        assert!(be.load_stage(&manifest, "stage9").is_err());
    }
}
