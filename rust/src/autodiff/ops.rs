//! Backward primitives (S16a): hand-derived vector-Jacobian products for
//! every operation in the reference forward pass.
//!
//! Each function takes the *saved forward activations* it needs (see
//! [`crate::autodiff::tape`]) plus the upstream gradient and returns the
//! downstream gradients. Derivations are in DESIGN.md §10; every primitive
//! is validated against central finite differences in the tests below.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// d(loss)/d(logits) for the mean next-token cross-entropy of
/// [`crate::model::cross_entropy`]: `(softmax(row) - onehot(target)) / count`
/// per position, where `count` is the total number of positions the mean
/// runs over (batch × seq — *not* just this sequence's length).
pub fn cross_entropy_grad(logits: &Tensor, targets: &[u32], count: usize) -> Result<Tensor> {
    Ok(cross_entropy_grad_with_loss(logits, targets, count)?.0)
}

/// [`cross_entropy_grad`] plus this sequence's *summed* loss contribution
/// `Σ_i (lse_i − x_i[tgt_i])` in f64 — per-position terms use the exact
/// f32 formula of [`crate::model::cross_entropy`], so accumulating these
/// across a batch and dividing by `count` reproduces its value bit for
/// bit without a second pass over the logits.
pub fn cross_entropy_grad_with_loss(
    logits: &Tensor,
    targets: &[u32],
    count: usize,
) -> Result<(Tensor, f64)> {
    if logits.rank() != 2 || logits.rows() != targets.len() {
        return Err(Error::Shape(format!(
            "cross_entropy_grad: logits {:?} vs {} targets",
            logits.shape(),
            targets.len()
        )));
    }
    if count == 0 {
        return Err(Error::Shape("cross_entropy_grad: zero position count".into()));
    }
    let (s, o) = (logits.rows(), logits.cols());
    let inv = 1.0 / count as f32;
    let mut out = Tensor::zeros(&[s, o]);
    let mut loss_sum = 0.0f64;
    for i in 0..s {
        let tgt = targets[i] as usize;
        if tgt >= o {
            return Err(Error::Shape(format!("cross_entropy_grad: target {tgt} out of vocab {o}")));
        }
        let row = logits.row(i);
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f32 = row.iter().map(|x| (x - max).exp()).sum();
        let lse = sum.ln() + max;
        loss_sum += f64::from(lse - row[tgt]);
        let orow = out.row_mut(i);
        for j in 0..o {
            let p = (row[j] - max).exp() / sum;
            orow[j] = (p - if j == tgt { 1.0 } else { 0.0 }) * inv;
        }
    }
    Ok((out, loss_sum))
}

/// RMSNorm backward. Forward (Eq. 5, no epsilon): `y_ij = x_ij g_j / r_i`
/// with `r_i = sqrt(mean_j x_ij^2)`. Returns `(dx, dg)`:
///
/// ```text
/// dg_j  = Σ_i dy_ij x_ij / r_i
/// dx_il = g_l dy_il / r_i  -  x_il / (h r_i^3) · Σ_j dy_ij g_j x_ij
/// ```
pub fn rmsnorm_backward(x: &Tensor, g: &Tensor, dy: &Tensor) -> Result<(Tensor, Tensor)> {
    if x.rank() != 2 || g.rank() != 1 || g.shape()[0] != x.cols() || dy.shape() != x.shape() {
        return Err(Error::Shape(format!(
            "rmsnorm_backward: x {:?}, g {:?}, dy {:?}",
            x.shape(),
            g.shape(),
            dy.shape()
        )));
    }
    let (s, h) = (x.rows(), x.cols());
    let mut dx = Tensor::zeros(&[s, h]);
    let mut dg = Tensor::zeros(&[h]);
    for i in 0..s {
        let xrow = x.row(i);
        let dyrow = dy.row(i);
        let ms: f32 = xrow.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let r = ms.sqrt();
        // Σ_j dy_ij g_j x_ij
        let mut dot = 0.0f32;
        for j in 0..h {
            dot += dyrow[j] * g.data()[j] * xrow[j];
        }
        let coeff = dot / (h as f32 * r * r * r);
        let dxrow = dx.row_mut(i);
        for j in 0..h {
            dxrow[j] = g.data()[j] * dyrow[j] / r - xrow[j] * coeff;
        }
        let dgd = dg.data_mut();
        for j in 0..h {
            dgd[j] += dyrow[j] * xrow[j] / r;
        }
    }
    Ok((dx, dg))
}

/// ReLU backward in place: zero the upstream gradient wherever the saved
/// *post*-activation is not strictly positive (post > 0 ⇔ pre > 0, and the
/// subgradient at exactly zero is taken as zero).
pub fn relu_backward_inplace(d: &mut Tensor, act: &Tensor) -> Result<()> {
    if d.shape() != act.shape() {
        return Err(Error::Shape(format!(
            "relu_backward: d {:?} vs act {:?}",
            d.shape(),
            act.shape()
        )));
    }
    for (dv, &a) in d.data_mut().iter_mut().zip(act.data()) {
        if a <= 0.0 {
            *dv = 0.0;
        }
    }
    Ok(())
}

/// Column sums of a 2D tensor — the bias gradient of a row-broadcast add.
pub fn col_sums(t: &Tensor) -> Result<Tensor> {
    if t.rank() != 2 {
        return Err(Error::Shape(format!("col_sums: rank {} tensor", t.rank())));
    }
    let (m, n) = (t.rows(), t.cols());
    let mut out = Tensor::zeros(&[n]);
    for i in 0..m {
        let row = t.row(i);
        let od = out.data_mut();
        for j in 0..n {
            od[j] += row[j];
        }
    }
    Ok(out)
}

/// Scaled-dot-product attention backward, given the *saved* post-softmax
/// probabilities. Forward: `S = Q Kᵀ / sqrt(dk)` (+ causal mask),
/// `P = softmax(S)`, `O = P V`. Returns `(dQ, dK, dV)`.
///
/// Masked positions need no special casing: the additive `-1e30` mask
/// underflows to exactly `P = 0` after softmax, which zeroes their `dS`.
pub fn attention_backward(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    probs: &Tensor,
    d_out: &Tensor,
) -> Result<(Tensor, Tensor, Tensor)> {
    if q.rank() != 2 || k.rank() != 2 || v.rank() != 2 || probs.rank() != 2 || d_out.rank() != 2 {
        return Err(Error::Shape("attention_backward: all inputs must be rank 2".into()));
    }
    let (s, dk) = (q.rows(), q.cols());
    if k.rows() != s
        || k.cols() != dk
        || v.rows() != s
        || probs.rows() != s
        || probs.cols() != s
        || d_out.rows() != s
        || d_out.cols() != v.cols()
    {
        return Err(Error::Shape(format!(
            "attention_backward: q {:?}, k {:?}, v {:?}, probs {:?}, d_out {:?}",
            q.shape(),
            k.shape(),
            v.shape(),
            probs.shape(),
            d_out.shape()
        )));
    }
    let dv = probs.matmul_at(d_out)?; // Pᵀ · dO
    let d_probs = d_out.matmul_bt(v)?; // dO · Vᵀ
    // softmax backward row-wise: dS_ij = P_ij (dP_ij - Σ_l dP_il P_il)
    let mut d_scores = Tensor::zeros(&[s, s]);
    for i in 0..s {
        let prow = probs.row(i);
        let dprow = d_probs.row(i);
        let inner: f32 = prow.iter().zip(dprow).map(|(p, dp)| p * dp).sum();
        let dsrow = d_scores.row_mut(i);
        for j in 0..s {
            dsrow[j] = prow[j] * (dprow[j] - inner);
        }
    }
    let scale = 1.0 / (dk as f32).sqrt();
    let mut dq = d_scores.matmul(k)?; // dS · K
    dq.scale(scale);
    let mut dk_grad = d_scores.matmul_at(q)?; // dSᵀ · Q
    dk_grad.scale(scale);
    Ok((dq, dk_grad, dv))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{attention, cross_entropy, rmsnorm};
    use crate::rng::Pcg32;

    /// Central finite difference of a scalar-valued function of one tensor:
    /// perturb every coordinate by ±h and assemble d(f)/d(x).
    fn fd_grad(x: &Tensor, h: f32, mut f: impl FnMut(&Tensor) -> f64) -> Tensor {
        let mut g = Tensor::zeros(x.shape());
        for i in 0..x.numel() {
            let mut plus = x.clone();
            plus.data_mut()[i] += h;
            let mut minus = x.clone();
            minus.data_mut()[i] -= h;
            g.data_mut()[i] = ((f(&plus) - f(&minus)) / (2.0 * f64::from(h))) as f32;
        }
        g
    }

    /// `Σ out ∘ w` in f64 — a generic smooth scalarizer for FD checks.
    fn weighted_sum(out: &Tensor, w: &Tensor) -> f64 {
        out.data().iter().zip(w.data()).map(|(a, b)| f64::from(a * b)).sum()
    }

    fn assert_close(analytic: &Tensor, fd: &Tensor, rtol: f32, atol: f32, what: &str) {
        assert_eq!(analytic.shape(), fd.shape(), "{what}: shape");
        for i in 0..analytic.numel() {
            let (a, b) = (analytic.data()[i], fd.data()[i]);
            let tol = rtol * a.abs().max(b.abs()) + atol;
            assert!((a - b).abs() <= tol, "{what}[{i}]: analytic {a} vs fd {b} (tol {tol})");
        }
    }

    #[test]
    fn cross_entropy_grad_matches_finite_differences() {
        let mut rng = Pcg32::seeded(1);
        let logits = Tensor::randn(&[4, 6], &mut rng, 1.0);
        let targets = vec![2u32, 0, 5, 3];
        let analytic = cross_entropy_grad(&logits, &targets, 4).unwrap();
        let fd = fd_grad(&logits, 2e-3, |l| {
            f64::from(cross_entropy(&[l.clone()], &[targets.clone()]).unwrap())
        });
        assert_close(&analytic, &fd, 1e-2, 1e-3, "d_logits");
    }

    #[test]
    fn fused_loss_matches_model_cross_entropy_exactly() {
        // the with_loss variant must reproduce model::cross_entropy bit
        // for bit (same f32 per-position formula, same f64 accumulation)
        let mut rng = Pcg32::seeded(8);
        let logits = Tensor::randn(&[4, 6], &mut rng, 1.5);
        let targets = vec![2u32, 0, 5, 3];
        let (_, sum) = cross_entropy_grad_with_loss(&logits, &targets, targets.len()).unwrap();
        let reference = cross_entropy(&[logits.clone()], &[targets.clone()]).unwrap();
        assert_eq!((sum / targets.len() as f64) as f32, reference);
    }

    #[test]
    fn cross_entropy_grad_rows_sum_to_zero() {
        // softmax minus onehot: every row's gradient sums to exactly zero
        let mut rng = Pcg32::seeded(2);
        let logits = Tensor::randn(&[3, 8], &mut rng, 2.0);
        let g = cross_entropy_grad(&logits, &[1, 7, 4], 6).unwrap();
        for i in 0..3 {
            let s: f32 = g.row(i).iter().sum();
            assert!(s.abs() < 1e-6, "row {i} sums to {s}");
        }
    }

    #[test]
    fn cross_entropy_grad_rejects_bad_inputs() {
        let logits = Tensor::zeros(&[2, 4]);
        assert!(cross_entropy_grad(&logits, &[0], 2).is_err()); // row mismatch
        assert!(cross_entropy_grad(&logits, &[0, 4], 2).is_err()); // target oob
        assert!(cross_entropy_grad(&logits, &[0, 1], 0).is_err()); // zero count
    }

    #[test]
    fn rmsnorm_backward_matches_finite_differences() {
        let mut rng = Pcg32::seeded(3);
        let x = Tensor::randn(&[3, 5], &mut rng, 1.0);
        let g = Tensor::randn(&[5], &mut rng, 1.0);
        let w = Tensor::randn(&[3, 5], &mut rng, 1.0); // scalarizer weights
        let (dx, dg) = rmsnorm_backward(&x, &g, &w).unwrap();

        let fd_x = fd_grad(&x, 2e-3, |xp| weighted_sum(&rmsnorm(xp, &g).unwrap(), &w));
        assert_close(&dx, &fd_x, 1e-2, 1e-3, "rmsnorm dx");

        let fd_g = fd_grad(&g, 2e-3, |gp| weighted_sum(&rmsnorm(&x, gp).unwrap(), &w));
        assert_close(&dg, &fd_g, 1e-2, 1e-3, "rmsnorm dg");
    }

    #[test]
    fn rmsnorm_backward_rejects_shape_mismatch() {
        let x = Tensor::zeros(&[2, 4]);
        let g = Tensor::zeros(&[4]);
        assert!(rmsnorm_backward(&x, &g, &Tensor::zeros(&[2, 3])).is_err());
        assert!(rmsnorm_backward(&x, &Tensor::zeros(&[3]), &x).is_err());
    }

    #[test]
    fn attention_backward_matches_finite_differences() {
        // causal attention with saved probs; scalarize with fixed weights
        let (s, dk, dv) = (5, 3, 4);
        let mut rng = Pcg32::seeded(4);
        let q = Tensor::randn(&[s, dk], &mut rng, 1.0);
        let k = Tensor::randn(&[s, dk], &mut rng, 1.0);
        let v = Tensor::randn(&[s, dv], &mut rng, 1.0);
        let w = Tensor::randn(&[s, dv], &mut rng, 1.0);

        // recompute probs the way the tape does
        let probs = {
            let mut scores = q.matmul_bt(&k).unwrap();
            scores.scale(1.0 / (dk as f32).sqrt());
            for i in 0..s {
                for j in (i + 1)..s {
                    scores.set(i, j, crate::model::MASK_VALUE);
                }
            }
            crate::tensor::softmax_rows(&mut scores);
            scores
        };
        let (dq, dk_grad, dv_grad) = attention_backward(&q, &k, &v, &probs, &w).unwrap();

        let fd_q = fd_grad(&q, 2e-3, |qp| weighted_sum(&attention(qp, &k, &v, true).unwrap(), &w));
        assert_close(&dq, &fd_q, 1e-2, 1e-3, "attention dq");
        let fd_k = fd_grad(&k, 2e-3, |kp| weighted_sum(&attention(&q, kp, &v, true).unwrap(), &w));
        assert_close(&dk_grad, &fd_k, 1e-2, 1e-3, "attention dk");
        let fd_v = fd_grad(&v, 2e-3, |vp| weighted_sum(&attention(&q, &k, vp, true).unwrap(), &w));
        assert_close(&dv_grad, &fd_v, 1e-2, 1e-3, "attention dv");
    }

    #[test]
    fn attention_backward_masked_positions_get_zero_score_grad() {
        // dK rows can only receive signal from queries at or after them;
        // in particular the last key row receives signal only from the last
        // query, and dV of the last row likewise. Check the strictly-causal
        // consequence: zeroing d_out's last row kills dK/dV's last row.
        let (s, dk, dv) = (4, 2, 3);
        let mut rng = Pcg32::seeded(5);
        let q = Tensor::randn(&[s, dk], &mut rng, 1.0);
        let k = Tensor::randn(&[s, dk], &mut rng, 1.0);
        let v = Tensor::randn(&[s, dv], &mut rng, 1.0);
        let probs = {
            let mut scores = q.matmul_bt(&k).unwrap();
            scores.scale(1.0 / (dk as f32).sqrt());
            for i in 0..s {
                for j in (i + 1)..s {
                    scores.set(i, j, crate::model::MASK_VALUE);
                }
            }
            crate::tensor::softmax_rows(&mut scores);
            scores
        };
        let mut d_out = Tensor::randn(&[s, dv], &mut rng, 1.0);
        for j in 0..dv {
            d_out.set(s - 1, j, 0.0);
        }
        let (_, dk_grad, dv_grad) = attention_backward(&q, &k, &v, &probs, &d_out).unwrap();
        for j in 0..dk {
            assert_eq!(dk_grad.at(s - 1, j), 0.0, "masked dK leaked at col {j}");
        }
        for j in 0..dv {
            assert_eq!(dv_grad.at(s - 1, j), 0.0, "masked dV leaked at col {j}");
        }
    }

    #[test]
    fn relu_backward_zeroes_inactive_units() {
        let act = Tensor::from_vec(&[1, 4], vec![0.0, 2.0, 0.0, 0.5]).unwrap();
        let mut d = Tensor::from_vec(&[1, 4], vec![1.0, 1.0, -3.0, 2.0]).unwrap();
        relu_backward_inplace(&mut d, &act).unwrap();
        assert_eq!(d.data(), &[0.0, 1.0, 0.0, 2.0]);
        assert!(relu_backward_inplace(&mut d, &Tensor::zeros(&[4])).is_err());
    }

    #[test]
    fn col_sums_matches_manual() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]).unwrap();
        assert_eq!(col_sums(&t).unwrap().data(), &[11.0, 22.0, 33.0]);
        assert!(col_sums(&Tensor::ones(&[3])).is_err());
    }

    #[test]
    fn matmul_gradient_identities_hold() {
        // For C = A·B and scalar L = Σ C∘W: dA = W·Bᵀ and dB = Aᵀ·W.
        // This pins the matmul_bt / matmul_at grad-product idioms used by
        // the backward pass to their finite-difference meaning.
        let mut rng = Pcg32::seeded(6);
        let a = Tensor::randn(&[3, 4], &mut rng, 1.0);
        let b = Tensor::randn(&[4, 5], &mut rng, 1.0);
        let w = Tensor::randn(&[3, 5], &mut rng, 1.0);
        let da = w.matmul_bt(&b).unwrap();
        let db = a.matmul_at(&w).unwrap();
        let fd_a = fd_grad(&a, 1e-3, |ap| weighted_sum(&ap.matmul(&b).unwrap(), &w));
        let fd_b = fd_grad(&b, 1e-3, |bp| weighted_sum(&a.matmul(bp).unwrap(), &w));
        assert_close(&da, &fd_a, 1e-2, 1e-3, "dA");
        assert_close(&db, &fd_b, 1e-2, 1e-3, "dB");
    }
}
