//! Env-gated crash injection for the durability tests (DESIGN.md §16.5).
//!
//! `TEXPAND_FAULT=<site>:<nth>` makes the `nth` (1-based) hit of the named
//! [`fault_point`] abort the process — `std::process::abort()`, no
//! destructors, no buffered-writer flush — simulating a SIGKILL/power-cut
//! at an exactly reproducible program point. Sites currently wired:
//!
//! * `train_step`      — top of every optimizer step (coordinator loop)
//! * `ckpt_mid_write`  — inside the checkpoint tmp-file write, after the
//!   header+partial payload have been flushed (a torn file exists on disk)
//! * `ckpt_pre_rename` — tmp file complete and fsynced, rename not issued
//!
//! The variable is read once per process (the first `fault_point` call)
//! and hit counts are per-site globals, so a single env setting arms
//! exactly one crash per run. Unset, the fast path is one relaxed atomic
//! load — cheap enough to sit on the training hot path.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::OnceLock;

/// Parsed `TEXPAND_FAULT` value: which site fires, on which hit.
struct Armed {
    site: String,
    nth: u64,
}

fn armed() -> Option<&'static Armed> {
    static ARMED: OnceLock<Option<Armed>> = OnceLock::new();
    ARMED
        .get_or_init(|| {
            let spec = std::env::var("TEXPAND_FAULT").ok()?;
            let (site, nth) = spec.split_once(':')?;
            let nth: u64 = nth.parse().ok().filter(|&n| n > 0)?;
            Some(Armed { site: site.to_string(), nth })
        })
        .as_ref()
}

/// Fast pre-check: 0 = unknown, 1 = disarmed (env absent/unparseable),
/// 2 = armed. Keeps the common no-fault path to one atomic load after
/// the first call.
static STATE: AtomicU8 = AtomicU8::new(0);

/// A named crash-injection point. No-op unless `TEXPAND_FAULT=<site>:<nth>`
/// names this site, in which case the `nth` hit aborts the process.
pub fn fault_point(site: &str) {
    match STATE.load(Ordering::Relaxed) {
        1 => return,
        2 => {}
        _ => {
            let s = if armed().is_some() { 2 } else { 1 };
            STATE.store(s, Ordering::Relaxed);
            if s == 1 {
                return;
            }
        }
    }
    let Some(a) = armed() else { return };
    if a.site != site {
        return;
    }
    static HITS: AtomicU64 = AtomicU64::new(0);
    let hit = HITS.fetch_add(1, Ordering::Relaxed) + 1;
    if hit == a.nth {
        eprintln!("TEXPAND_FAULT: aborting at fault point '{site}' (hit {hit})");
        std::process::abort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The abort path can't run in-process; what is testable here is that
    // unarmed fault points are free of side effects and panic-free. The
    // armed path is exercised by `rust/tests/integration_ckpt.rs`, which
    // arms TEXPAND_FAULT on a spawned child binary.
    #[test]
    fn unarmed_fault_points_are_noops() {
        for _ in 0..3 {
            fault_point("train_step");
            fault_point("ckpt_mid_write");
            fault_point("nonexistent_site");
        }
    }
}
