//! # texpand — composable function-preserving expansions for transformers
//!
//! A progressive-growth transformer training framework reproducing
//! *Composable Function-preserving Expansions for Transformer Architectures*
//! (Gesmundo & Maile, 2023). The Rust side is **Layer 3** of the stack:
//! it owns all run-time state (parameters, optimizer moments, data, growth
//! schedule) and executes AOT-compiled HLO artifacts via PJRT; the JAX/Pallas
//! side (`python/compile/`) runs only at build time.
//!
//! ## Module map
//!
//! Substrates (built from scratch — the offline crate set has no serde /
//! clap / criterion / proptest):
//! * [`json`] — JSON parser/serializer (manifests, configs, metrics).
//! * [`rng`] — deterministic PCG32/normal sampling shared by init, data
//!   synthesis and property tests.
//! * [`tensor`] — host `f32` tensors with the linear algebra the reference
//!   model and the expansion surgery need; the tuned hot-path kernels
//!   (blocked matmuls, fused `rmsnorm_matmul`, register-tiled `attn_pv`,
//!   single-pass online softmax) each keep a naive oracle in-tree and are
//!   bit-identical to it, except the online softmax's documented
//!   ≤ 1e-6/element bound (DESIGN.md §17).
//! * [`prop`] — a miniature property-testing harness.
//! * [`bench_util`] — wall-clock benchmark harness (used by `benches/`).
//! * [`parallel`] — scoped-thread worker pool (`TEXPAND_THREADS` /
//!   `--threads`); the single parallelism seam shared by native training
//!   (across batch rows, and within a single row across attention heads
//!   in the backward pass) and the serve decode loop.
//!
//! Framework:
//! * [`config`] — architecture configs, growth schedules, training config.
//! * [`params`] — the canonical-order parameter store + checkpoint codec.
//! * [`model`] — pure-Rust reference transformer forward (paper Eqs. 1–5),
//!   the PJRT-independent oracle for preservation checks.
//! * [`expand`] — **the paper's contribution**: the six function-preserving
//!   transformations (Defs. 3.1–3.6) as parameter surgery, plus
//!   composition. The one public entry point is [`expand::ExpansionPlan`]
//!   (S18): a validated, inspectable op composition carrying the predicted
//!   config, exact param delta and estimated FLOPs delta, applied
//!   transactionally to params, optimizer moments and live KV caches
//!   through the [`expand::Expandable`] seam (DESIGN.md §13).
//! * [`runtime`] — PJRT client wrapper: loads `artifacts/*.hlo.txt`,
//!   compiles once, executes on the training hot path.
//! * [`autodiff`] — **native training backend** (S16): hand-written
//!   reverse-mode gradients over the reference model (activation taping +
//!   per-op backwards, finite-difference checked), and the [`autodiff::ExecBackend`]
//!   trait with its two engines — the PJRT [`runtime::Runtime`] and the
//!   pure-Rust [`autodiff::NativeBackend`] — so the full grow-as-you-train
//!   loop runs offline (`texpand train --backend native`). A batch-1 step
//!   still parallelizes: `backward_seq_pooled` fans the MHA backward over
//!   heads with a fixed-order merge, bit-identical at any thread count.
//! * [`optim`] — SGD/Adam with expansion-aware moment surgery.
//! * [`data`] — synthetic corpus generators, byte tokenizer, batcher.
//! * [`train`] — the training loop for one architecture segment
//!   (backend-generic), producing the per-step [`growth::TrainObs`] stream.
//! * [`growth`] — **growth policies** (S17): the [`growth::GrowthPolicy`]
//!   seam deciding when/what to expand — fixed stage-table replay,
//!   loss-plateau triggering, and greedy branch-probe search
//!   (`--policy fixed|plateau|greedy`).
//! * [`coordinator`] — the growth coordinator: a policy-driven loop over
//!   segments, applying boundary surgery and verifying preservation.
//! * [`metrics`] — CSV/JSONL run logging, timers, serving counters.
//! * [`ckpt`] — **durable run state** (S21): atomic, versioned, checksummed
//!   whole-run checkpoints (params + Adam moments + every live RNG +
//!   batcher cursor + policy state + last applied plan) written
//!   tmp+fsync+rename into a retained generation chain, so
//!   `texpand train --resume` is bit-identical to an uninterrupted run
//!   and a torn/corrupted file falls back to the previous good
//!   generation (DESIGN.md §16).
//! * [`faults`] — env-gated crash-injection points
//!   (`TEXPAND_FAULT=<site>:<nth>`) backing the crash-recovery tests.
//! * [`obs`] — live observability (S19/S20): lock-free metrics registry
//!   (counters/gauges/fixed-bucket latency histograms with p50/p95/p99
//!   estimation and per-bucket request-id exemplars), Prometheus text
//!   exposition served over a `std::net` HTTP listener (`/metrics`,
//!   `/healthz`, plus chunked live span streaming at `/spans` from a
//!   bounded [`obs::SpanRing`]), per-request queued→prefill→decode span
//!   tracing on the serve path, and the [`obs::RunStore`] — append-only
//!   ingestion of run event logs into `runs/.store` with per-run
//!   aggregate stats backing `texpand runs` and the `texpand report`
//!   growth-timeline / preservation-drift reporter (DESIGN.md §14–§15).
//! * [`cli`] — argument parsing for the `texpand` binary.
//!
//! Serving & hot-swap (S15; `texpand serve`):
//! * [`serve`] — KV-cached batched inference engine: per-sequence KV +
//!   residual-stream caches ([`serve::kv`], generic over a
//!   [`serve::KvStorage`] backend — exact f32, half-precision f16 or
//!   block-quantized int8 via `--kv-quant=TIER`, down to several-fold
//!   fewer resident bytes per sequence) driven by the incremental forward
//!   ([`model::forward_incremental`], bit-compatible with
//!   [`model::forward_one`]); a continuous-batching scheduler
//!   ([`serve::scheduler`]) with per-request deadlines and an incremental
//!   [`serve::Engine::partial`] view; and zero-downtime
//!   function-preserving model hot-swap ([`serve::hotswap`]) that applies
//!   `expand` surgery to the live parameters, verifies a preservation
//!   probe, and **remaps the in-flight KV caches through the same
//!   expansion ops** — every storage tier — so greedy generations
//!   continue token-identically (DESIGN.md §9, §17).
//! * [`serve::http`] — the network face (S21, `serve --http-addr`): a
//!   multi-client `std::net` HTTP/1.1 server streaming `POST /v1/generate`
//!   tokens as chunked NDJSON, mapping wall-clock `deadline_ms` onto
//!   tick-denominated engine timeouts, and shedding overload through an
//!   AIMD admission window ([`serve::http::AimdController`]) driven by
//!   per-token latency gradients + rejection rate, exported live through
//!   the [`obs`] registry (DESIGN.md §18).
//! * [`serve::loadgen`] — synthetic open/closed-loop client fleet
//!   (`texpand loadgen`): concurrent workers, seeded reproducible request
//!   streams, client-observed p50/p95/p99 + tokens/sec appended to
//!   `runs/bench.jsonl` as the `serve_http_load` series.

pub mod autodiff;
pub mod bench_util;
pub mod ckpt;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod expand;
pub mod faults;
pub mod generate;
pub mod growth;
pub mod json;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod parallel;
pub mod params;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;

pub use error::{Error, Result};
