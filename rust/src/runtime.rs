//! PJRT runtime (S7): artifact manifest, executable cache, marshalling.
//!
//! The AOT boundary: `python/compile/aot.py` wrote `artifacts/manifest.json`
//! plus per-stage HLO **text** files (the interchange format — jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids). This module:
//!
//! 1. parses the manifest ([`Manifest`]) and re-validates every stage's
//!    declared parameter list against our own canonical `param_specs` —
//!    build drift between the Python and Rust sides fails loudly at load;
//! 2. compiles each stage's `fwd` / `step` computation once on a shared
//!    [`xla::PjRtClient`] ([`StageExec`]); compilation is cached per path;
//! 3. marshals [`Tensor`]s / token batches to `xla::Literal`s and back.
//!
//! Python never runs here: this is the entire training hot path.

use std::collections::HashMap;

use crate::config::{param_specs, GrowthSchedule, ModelConfig};
use crate::data::Batch;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::params::ParamStore;
use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

/// One stage entry from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestStage {
    pub name: String,
    pub steps: usize,
    pub config: ModelConfig,
    pub num_params: usize,
    pub fwd_file: String,
    pub step_file: String,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub schedule: String,
    pub batch: usize,
    pub kernels: String,
    pub stages: Vec<ManifestStage>,
    /// Directory the manifest was loaded from (artifact paths are relative).
    pub dir: String,
}

impl Manifest {
    /// Load and validate `<dir>/<name>` (default name `manifest.json`).
    pub fn load(dir: &str, name: &str) -> Result<Manifest> {
        let path = format!("{dir}/{name}");
        let v = Value::load(&path)?;
        let version = v.req("version")?.as_i64()?;
        if version != 1 {
            return Err(Error::Manifest(format!("{path}: unsupported manifest version {version}")));
        }
        let mut stages = Vec::new();
        for sj in v.req("stages")?.as_arr()? {
            let config = ModelConfig::from_json(sj.req("config")?)?;
            let stage = ManifestStage {
                name: sj.req("name")?.as_str()?.to_string(),
                steps: sj.req("steps")?.as_usize()?,
                config,
                num_params: sj.req("num_params")?.as_usize()?,
                fwd_file: sj.req("fwd")?.as_str()?.to_string(),
                step_file: sj.req("step")?.as_str()?.to_string(),
            };
            // Cross-language contract check: the Python-side param list must
            // equal our canonical order exactly (DESIGN.md §7).
            let ours = param_specs(&config);
            let theirs = sj.req("params")?.as_arr()?;
            if theirs.len() != ours.len() {
                return Err(Error::Manifest(format!(
                    "{}: {} params in manifest, {} canonical",
                    stage.name,
                    theirs.len(),
                    ours.len()
                )));
            }
            for (pj, spec) in theirs.iter().zip(&ours) {
                let name = pj.req("name")?.as_str()?;
                let shape: Vec<usize> =
                    pj.req("shape")?.as_arr()?.iter().map(|d| d.as_usize()).collect::<Result<_>>()?;
                if name != spec.name || shape != spec.shape {
                    return Err(Error::Manifest(format!(
                        "{}: param '{name}' {shape:?} != canonical '{}' {:?}",
                        stage.name, spec.name, spec.shape
                    )));
                }
            }
            if stage.num_params != config.num_params() {
                return Err(Error::Manifest(format!(
                    "{}: num_params {} != computed {}",
                    stage.name,
                    stage.num_params,
                    config.num_params()
                )));
            }
            stages.push(stage);
        }
        if stages.is_empty() {
            return Err(Error::Manifest(format!("{path}: no stages")));
        }
        Ok(Manifest {
            schedule: v.req("schedule")?.as_str()?.to_string(),
            batch: v.req("batch")?.as_usize()?,
            kernels: v.req("kernels")?.as_str()?.to_string(),
            stages,
            dir: dir.to_string(),
        })
    }

    /// Synthesize a manifest directly from a growth schedule — the native
    /// backend's stage source. Stage metadata matches what the AOT build
    /// would have written for the same schedule; artifact paths are empty
    /// (the native backend never reads them), so feeding this manifest to
    /// the PJRT runtime fails loudly at compile time rather than silently.
    pub fn from_schedule(schedule: &GrowthSchedule) -> Manifest {
        Manifest {
            schedule: schedule.name.clone(),
            batch: schedule.batch,
            kernels: "native".to_string(),
            stages: schedule
                .stages
                .iter()
                .map(|s| ManifestStage {
                    name: s.name.clone(),
                    steps: s.steps,
                    config: s.config,
                    num_params: s.config.num_params(),
                    fwd_file: String::new(),
                    step_file: String::new(),
                })
                .collect(),
            dir: String::new(),
        }
    }

    /// Find a stage by name.
    pub fn stage(&self, name: &str) -> Result<&ManifestStage> {
        self.stages
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| Error::Manifest(format!("no stage named '{name}'")))
    }
}

// ---------------------------------------------------------------------------
// Marshalling
// ---------------------------------------------------------------------------

/// Host tensor → f32 literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let bytes: Vec<u8> = t.data().iter().flat_map(|x| x.to_le_bytes()).collect();
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), &bytes)?)
}

/// Token matrix → i32 literal of shape `[batch, seq]`.
pub fn tokens_to_literal(rows: &[Vec<u32>]) -> Result<xla::Literal> {
    if rows.is_empty() {
        return Err(Error::Runtime("tokens_to_literal: empty batch".into()));
    }
    let seq = rows[0].len();
    let mut bytes = Vec::with_capacity(rows.len() * seq * 4);
    for row in rows {
        if row.len() != seq {
            return Err(Error::Runtime("tokens_to_literal: ragged batch".into()));
        }
        for &t in row {
            bytes.extend_from_slice(&(t as i32).to_le_bytes());
        }
    }
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[rows.len(), seq],
        &bytes,
    )?)
}

/// f32 literal → host tensor with the given shape (element count checked).
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let vals: Vec<f32> = lit.to_vec()?;
    Tensor::from_vec(shape, vals)
}

// ---------------------------------------------------------------------------
// Stage executables
// ---------------------------------------------------------------------------

/// Handle for one architecture stage's compiled executables. The actual
/// `PjRtLoadedExecutable`s live in the [`Runtime`] cache (they are neither
/// `Clone` nor `Send` in the `xla` crate), so a handle is cheap metadata and
/// all execution goes through `Runtime::{forward, step}`.
#[derive(Clone, Debug)]
pub struct StageExec {
    pub meta: ManifestStage,
    pub batch: usize,
    fwd_key: String,
    step_key: String,
}

impl StageExec {
    /// Artifact-free handle for backends that interpret the model directly
    /// (the native autodiff backend). The executable-cache keys stay empty:
    /// feeding such a handle to the PJRT [`Runtime`] errors with a cache
    /// miss instead of executing the wrong thing.
    pub fn native(meta: ManifestStage, batch: usize) -> StageExec {
        StageExec { meta, batch, fwd_key: String::new(), step_key: String::new() }
    }
}

/// Shared PJRT client + per-file compilation cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()?, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }

    fn compile_file(&mut self, dir: &str, file: &str) -> Result<String> {
        let path = format!("{dir}/{file}");
        if !self.cache.contains_key(&path) {
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::Runtime(format!("loading {path}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compiling {path}: {e}")))?;
            self.cache.insert(path.clone(), exe);
        }
        Ok(path)
    }

    /// Compile (or fetch cached) both executables for a stage.
    pub fn load_stage(&mut self, manifest: &Manifest, stage_name: &str) -> Result<StageExec> {
        let meta = manifest.stage(stage_name)?.clone();
        let fwd_key = self.compile_file(&manifest.dir, &meta.fwd_file)?;
        let step_key = self.compile_file(&manifest.dir, &meta.step_file)?;
        Ok(StageExec { meta, batch: manifest.batch, fwd_key, step_key })
    }

    fn exec(&self, key: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.cache
            .get(key)
            .ok_or_else(|| Error::Runtime(format!("executable '{key}' not in cache (stale handle?)")))
    }

    fn param_literals(stage: &StageExec, params: &ParamStore) -> Result<Vec<xla::Literal>> {
        if params.config() != &stage.meta.config {
            return Err(Error::Runtime(format!(
                "params for {:?} fed to stage '{}' expecting {:?}",
                params.config(),
                stage.meta.name,
                stage.meta.config
            )));
        }
        params.tensors().iter().map(tensor_to_literal).collect()
    }

    fn check_batch(stage: &StageExec, rows: &[Vec<u32>]) -> Result<()> {
        if rows.len() != stage.batch {
            return Err(Error::Runtime(format!(
                "batch {} rows, artifact compiled for {}",
                rows.len(),
                stage.batch
            )));
        }
        for row in rows {
            if row.len() != stage.meta.config.seq {
                return Err(Error::Runtime(format!(
                    "sequence of {} tokens, artifact compiled for seq {}",
                    row.len(),
                    stage.meta.config.seq
                )));
            }
        }
        Ok(())
    }

    /// Forward pass: logits as one `[seq, vocab]` tensor per batch row.
    pub fn forward(&self, stage: &StageExec, params: &ParamStore, tokens: &[Vec<u32>]) -> Result<Vec<Tensor>> {
        Self::check_batch(stage, tokens)?;
        let mut inputs = Self::param_literals(stage, params)?;
        inputs.push(tokens_to_literal(tokens)?);
        let result = self.exec(&stage.fwd_key)?.execute::<xla::Literal>(&inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let logits_lit = tuple.to_tuple1()?;
        let cfg = &stage.meta.config;
        let flat: Vec<f32> = logits_lit.to_vec()?;
        let per_row = cfg.seq * cfg.vocab;
        if flat.len() != stage.batch * per_row {
            return Err(Error::Runtime(format!(
                "forward returned {} values, expected {}",
                flat.len(),
                stage.batch * per_row
            )));
        }
        (0..stage.batch)
            .map(|b| Tensor::from_vec(&[cfg.seq, cfg.vocab], flat[b * per_row..(b + 1) * per_row].to_vec()))
            .collect()
    }

    /// Train step: returns `(loss, canonical-order gradients)`.
    pub fn step(&self, stage: &StageExec, params: &ParamStore, batch: &Batch) -> Result<(f32, Vec<Tensor>)> {
        Self::check_batch(stage, &batch.tokens)?;
        Self::check_batch(stage, &batch.targets)?;
        let mut inputs = Self::param_literals(stage, params)?;
        inputs.push(tokens_to_literal(&batch.tokens)?);
        inputs.push(tokens_to_literal(&batch.targets)?);
        let result = self.exec(&stage.step_key)?.execute::<xla::Literal>(&inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 1 + params.len() {
            return Err(Error::Runtime(format!(
                "step returned {} outputs, expected {}",
                parts.len(),
                1 + params.len()
            )));
        }
        let loss: f32 = parts[0].to_vec::<f32>()?[0];
        let grads: Vec<Tensor> = parts[1..]
            .iter()
            .zip(params.specs())
            .map(|(lit, spec)| literal_to_tensor(lit, &spec.shape))
            .collect::<Result<_>>()?;
        Ok((loss, grads))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit, &[2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn tokens_literal_shape() {
        let lit = tokens_to_literal(&[vec![1, 2, 3], vec![4, 5, 6]]).unwrap();
        let vals: Vec<i32> = lit.to_vec().unwrap();
        assert_eq!(vals, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn tokens_literal_rejects_ragged_and_empty() {
        assert!(tokens_to_literal(&[]).is_err());
        assert!(tokens_to_literal(&[vec![1, 2], vec![3]]).is_err());
    }

    #[test]
    fn manifest_from_schedule_mirrors_stage_metadata() {
        let sched = GrowthSchedule::from_json(
            &Value::parse(
                r#"{
                    "name": "synth", "batch": 4, "seq": 8, "vocab": 16,
                    "base": {"layers":1,"hidden":8,"heads":2,"k":4,"v":4,"mlp":16},
                    "stages": [
                        {"steps": 10},
                        {"steps": 20, "apply": [{"op":"hidden","h":12}]}
                    ]
                }"#,
            )
            .unwrap(),
        )
        .unwrap();
        let m = Manifest::from_schedule(&sched);
        assert_eq!(m.schedule, "synth");
        assert_eq!(m.batch, 4);
        assert_eq!(m.kernels, "native");
        assert_eq!(m.stages.len(), 2);
        for (ms, ss) in m.stages.iter().zip(&sched.stages) {
            assert_eq!(ms.name, ss.name);
            assert_eq!(ms.config, ss.config);
            assert_eq!(ms.steps, ss.steps);
            assert_eq!(ms.num_params, ss.config.num_params());
            assert!(ms.fwd_file.is_empty() && ms.step_file.is_empty());
        }
        assert!(m.stage("stage1").is_ok());
        assert!(m.stage("stage7").is_err());
    }
}
