//! `texpand` — progressive-growth transformer training CLI (L3 leader).
//!
//! Subcommands:
//!   train     run a growth schedule end to end (the paper's §5 pipeline)
//!   verify    preservation matrix over all boundaries, no training
//!   family    branch a checkpoint into a family of sizes (§5 use case b)
//!   generate  sample text from a trained checkpoint via the fwd artifact
//!   serve     KV-cached batched inference engine on the pure-Rust path,
//!             with optional mid-run function-preserving hot-swap;
//!             --http-addr turns it into a streaming HTTP front-end with
//!             adaptive admission control
//!   loadgen   synthetic open/closed-loop client fleet against a serve
//!             --http-addr listener; reports client-observed latency
//!             percentiles + tokens/sec to runs/bench.jsonl
//!   scrape    std::net HTTP GET against a running --metrics-addr
//!             listener (curl-free metrics client for CI); --spans tails
//!             the live span stream
//!   runs      ingest run event logs into the runs/.store run store and
//!             list/show/aggregate them
//!   ckpt      list a durable run's checkpoint chain or verify every
//!             retained generation's checksums without resuming
//!   report    growth-timeline report for one stored run: per-stage loss
//!             curve, expansions with predicted-vs-actual deltas, and the
//!             preservation-drift monitor per boundary
//!   plan      dry-run a growth schedule as ExpansionPlans: config /
//!             param / FLOP trajectory, no training
//!   inspect   print a checkpoint's config and tensor statistics
//!   info      print the artifact manifest summary
//!
//! Run `texpand <subcommand> --help-flags` is not needed: unknown flags are
//! rejected with an explicit error, and this header documents the surface.

use texpand::autodiff::{ExecBackend, NativeBackend};
use texpand::cli::Args;
use texpand::config::{GrowthSchedule, OptimKind, PolicyKind, TrainConfig};
use texpand::coordinator::{Coordinator, CoordinatorOptions};
use texpand::data::CorpusKind;
use texpand::error::{Error, Result};
use texpand::json::Value;
use texpand::params::ParamStore;
use texpand::runtime::{Manifest, Runtime};

const USAGE: &str = "\
texpand — composable function-preserving transformer expansions

USAGE:
  texpand train   [--backend native|pjrt] [--schedule P] [--artifacts D]
                  [--policy fixed|plateau|greedy]
                  [--run-name N] [--runs D]
                  [--steps-scale F] [--lr F] [--optimizer adam|sgd]
                  [--seed N] [--corpus markov|copy|arithmetic]
                  [--corpus-len N] [--no-verify] [--no-checkpoints]
                  [--checkpoint-every N] [--checkpoint-keep K] [--resume]
                  [--threads N] [--micro-batch N]
                  [--metrics-addr HOST:PORT]
  texpand verify  [--backend native|pjrt] [--schedule P] [--artifacts D]
                  [--seed N]
  texpand family  --base CKPT [--backend native|pjrt] [--schedule P]
                  [--artifacts D] [--steps N]
                  [--runs D] [--run-name N] [--lr F] [--seed N]
  texpand generate --ckpt PATH [--backend native|pjrt] [--prompt S]
                   [--tokens N] [--temperature F]
                   [--top-k N] [--seed N] [--schedule P] [--artifacts D]
  texpand serve   [--ckpt PATH] [--checkpoint PATH]
                  [--requests N] [--tokens N] [--slots N]
                  [--temperature F] [--top-k N] [--seed N] [--serial]
                  [--corpus markov|copy|arithmetic]
                  [--kv-quant[=f32|f16|int8]]
                  [--max-pending N] [--timeout-ticks N]
                  [--swap-ops SPEC] [--swap-after-ticks N]
                  (SPEC e.g. \"mlp=256,heads_add=1,layers_add=1@top\")
                  [--metrics-addr HOST:PORT] [--metrics-linger-ms N]
                  [--runs D] [--run-name N] [--span-sample N]
                  [--http-addr HOST:PORT] [--http-max-secs N]
                  [--admission adaptive|static] [--window-init F]
                  [--window-min F] [--window-max F]
  texpand loadgen --addr HOST:PORT [--clients N] [--requests N]
                  [--rate F] [--tokens N] [--prompt-mix A,B,C]
                  [--deadline-ms N] [--vocab N] [--seed N]
                  [--timeout-ms N] [--case LABEL]
  texpand scrape  --addr HOST:PORT [--path /metrics] [--timeout-ms N]
                  [--spans] [--count N]
  texpand runs    [list|show|stats|compact] [RUN] [--runs D] [--keep N]
  texpand ckpt    list|verify DIR
  texpand report  RUN [--runs D]
  texpand plan    [--schedule P] [--json]
  texpand inspect --ckpt PATH
  texpand info    [--backend native|pjrt] [--schedule P] [--artifacts D]

Backends: `pjrt` (default) executes AOT-compiled HLO artifacts and needs
`make artifacts`; `native` interprets the model in pure Rust with
hand-written reverse-mode gradients — fully offline, no artifacts.

Native-backend parallelism: training steps fan batch rows out across
worker threads (--threads, or the TEXPAND_THREADS env var; default all
cores) with bit-identical gradients at any thread count. --micro-batch N
(or \"micro_batch\" in the schedule JSON) accumulates gradients N rows at
a time so the schedule's batch can exceed resident memory.

Growth policies (--policy, or \"policy\" block in the schedule JSON):
`fixed` (default) replays the schedule's stage table verbatim; `plateau`
fires the next staged expansion when the eval loss stops improving
(window/cooldown/deadline knobs in the JSON policy block); `greedy`
branch-probes candidate expansions and commits the best loss-per-compute
one. plateau/greedy decide architectures at run time, so they need
--backend native; pjrt executes a fixed AOT stage table only.

Observability: --metrics-addr (train, serve) binds a std::net HTTP
listener exposing the live metrics registry as Prometheus text at
/metrics (plus /healthz); port 0 picks a free port, printed at startup.
serve additionally logs per-request span events to
runs/<name>/events.jsonl, streams them live over chunked HTTP at /spans
(tail with `texpand scrape --spans`; --span-sample N keeps 1-in-N
traces without thinning any counter), and --metrics-linger-ms keeps the
listener up after serving drains so late scrapes still land (GET /quitz
releases it early). `texpand scrape` is the matching curl-free client.
Latency histogram buckets carry the most recent request id as an
exemplar annotation in the /metrics text.

HTTP serving: serve --http-addr binds a multi-client streaming HTTP
front-end — POST /v1/generate with a JSON body ({\"tokens\":[..]} or
{\"prompt\":\"..\"}, plus max_new_tokens / deadline_ms / temperature /
top_k / seed) streams decoded tokens back incrementally as chunked
NDJSON lines, finishing with a terminal done chunk whose finish field
is max_tokens or timeout (deadline_ms maps onto engine ticks via a
live EWMA of tick duration). Admission is an AIMD controller over the
per-token latency gradient (--admission adaptive, the default) or a
fixed window (--admission static); requests beyond the live window get
429 + Retry-After. --window-init/--window-min/--window-max bound the
controller. The listener also serves /metrics, /healthz and /quitz
(quit releases the server; --http-max-secs N is the CI safety cap).
`texpand loadgen` is the matching synthetic-client driver: N
concurrent clients (--clients), closed-loop by default or open-loop at
--rate req/s, prompt lengths cycling --prompt-mix, reporting client-
observed p50/p95/p99 latency, tokens/sec and the 429/timeout/error
breakdown, appended to runs/bench.jsonl as a serve_http_load row.

Run store: `texpand runs` ingests runs/<name>/events.jsonl into an
append-only indexed store at runs/.store (list/show/stats), and
`texpand runs compact --keep N` retires all but the newest N runs'
record payloads from the store (stats summaries survive; a compacted
run re-ingests only if its source log grows), and
`texpand report RUN` renders the growth timeline — per-stage loss
curves, each expansion's predicted-vs-actual param/FLOP deltas, a
preservation-drift row per boundary checked against the probe
tolerance, and the run's durable recovery points. Corrupted source-log
lines are counted (runs list `bad` column), never fatal.

Durable runs: train --checkpoint-every N writes an atomic, checksummed
run checkpoint (params, optimizer moments, RNG streams, policy state)
to runs/<name>/ckpt/gen-NNNNNN.txck every N global steps and at every
expansion boundary, keeping the last --checkpoint-keep (default 3)
generations. --resume restarts bit-identically from the newest valid
generation — a torn or corrupted latest file falls back to the previous
one. serve --checkpoint PATH warm-starts the engine from a run
checkpoint file (or the newest valid generation when PATH is a ckpt
directory); --ckpt stays the plain .txpd weights loader. `texpand ckpt
list DIR` tabulates a chain's retained generations (step, params,
checksum verdict) and `texpand ckpt verify DIR` exits nonzero when no
generation is resumable — a chain health check that never loads the
model into an engine.

Raw-speed serving: serve --kv-quant=TIER picks the per-sequence K/V
storage tier: f32 (exact, default), f16 (IEEE binary16, exactly 2×
fewer resident bytes), or int8 (block-quantized, QUANT_BLOCK scalars
per f32 scale, several-fold fewer; bare --kv-quant keeps meaning
int8). In every tier the residual stream stays exact f32, so hot-swap
remaps and pending logits are computed from exact state and
compression error never compounds across swaps (DESIGN.md §17). The
engine reports peak KV bytes per sequence for each tier.

Defaults: --schedule configs/growth_default.json, --artifacts artifacts,
          --runs runs, --backend pjrt.";

fn main() {
    let code = match run() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            if matches!(e, Error::Cli(_)) {
                eprintln!("\n{USAGE}");
            }
            1
        }
    };
    std::process::exit(code);
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("verify") => cmd_verify(&args),
        Some("family") => cmd_family(&args),
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("scrape") => cmd_scrape(&args),
        Some("runs") => cmd_runs(&args),
        Some("ckpt") => cmd_ckpt(&args),
        Some("report") => cmd_report(&args),
        Some("plan") => cmd_plan(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("info") => cmd_info(&args),
        Some(other) => Err(Error::Cli(format!("unknown subcommand '{other}'"))),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn train_config(args: &Args) -> Result<TrainConfig> {
    let mut t = TrainConfig::default();
    if let Some(lr) = args.get_f64("lr")? {
        t.lr = lr as f32;
    }
    if let Some(seed) = args.get_u64("seed")? {
        t.seed = seed;
    }
    if let Some(opt) = args.get_choice("optimizer", &["adam", "sgd"])? {
        t.optimizer = if opt == "adam" { OptimKind::Adam } else { OptimKind::Sgd };
    }
    if let Some(le) = args.get_usize("log-every")? {
        t.log_every = le.max(1);
    }
    Ok(t)
}

/// Resolve `--backend` into its manifest and a human-readable source
/// label, WITHOUT constructing an execution engine — `texpand info` needs
/// only this. `pjrt` loads `manifest.json` from the artifacts dir;
/// `native` synthesizes the manifest from the schedule — reusing
/// `schedule` when the caller already loaded it, loading it lazily
/// otherwise (a pjrt run never touches the schedule file, and a native
/// run never touches the artifacts dir). The single dispatch site for the
/// backend flag: every subcommand resolves through this.
fn resolve_manifest(args: &Args, schedule: Option<&GrowthSchedule>) -> Result<(Manifest, String)> {
    let artifacts_dir = args.get_or("artifacts", "artifacts");
    let schedule_path = args.get_or("schedule", "configs/growth_default.json");
    match args.get_or("backend", "pjrt").as_str() {
        "native" => {
            let manifest = match schedule {
                Some(s) => Manifest::from_schedule(s),
                None => Manifest::from_schedule(&GrowthSchedule::load(&schedule_path)?),
            };
            Ok((manifest, format!("synthesized from {schedule_path} (native backend)")))
        }
        "pjrt" => Ok((
            Manifest::load(&artifacts_dir, "manifest.json")?,
            format!("{artifacts_dir}/manifest.json"),
        )),
        other => Err(Error::Cli(format!("unknown backend '{other}' (expected native|pjrt)"))),
    }
}

/// [`resolve_manifest`] plus the execution engine itself, for subcommands
/// that actually run the model.
fn backend_for(
    args: &Args,
    schedule: Option<&GrowthSchedule>,
) -> Result<(Manifest, Box<dyn ExecBackend>, String)> {
    let (manifest, source) = resolve_manifest(args, schedule)?;
    let backend: Box<dyn ExecBackend> = match args.get_or("backend", "pjrt").as_str() {
        "native" => {
            let mut be = NativeBackend::new();
            if let Some(threads) = args.get_usize("threads")? {
                if threads == 0 {
                    return Err(Error::Cli("--threads must be >= 1".into()));
                }
                be.set_threads(threads);
            }
            // precedence: CLI flag > schedule JSON > none
            match args.get_usize("micro-batch")? {
                Some(0) => return Err(Error::Cli("--micro-batch must be >= 1".into())),
                Some(m) => be.set_micro_batch(Some(m)),
                None => be.set_micro_batch(schedule.and_then(|s| s.micro_batch)),
            }
            Box::new(be)
        }
        // the flag was already validated by resolve_manifest
        _ => {
            // fail rather than silently ignore native-only knobs: a pjrt
            // run accepting --micro-batch would fake gradient accumulation
            if args.get_usize("threads")?.is_some() || args.get_usize("micro-batch")?.is_some() {
                return Err(Error::Cli(
                    "--threads / --micro-batch apply to --backend native only".into(),
                ));
            }
            // a schedule-sourced micro_batch is a tuning hint shared with
            // the native backend, not a user flag — warn instead of fail
            if schedule.is_some_and(|s| s.micro_batch.is_some()) {
                eprintln!(
                    "warning: the schedule's micro_batch applies to --backend native only; \
                     the pjrt step runs full-batch"
                );
            }
            Box::new(Runtime::cpu()?)
        }
    };
    Ok((manifest, backend, source))
}

fn backend_and_manifest(args: &Args) -> Result<(Manifest, Box<dyn ExecBackend>, String)> {
    backend_for(args, None)
}

/// Flag hygiene before backend resolution: consume the backend-selection
/// flags without acting on them yet, then reject leftovers — so a typo'd
/// flag reports as such on every subcommand instead of surfacing as a
/// missing manifest or schedule.
fn reject_unknown_after_backend_flags(args: &Args) -> Result<()> {
    let _ = (args.get("artifacts"), args.get("schedule"), args.get("backend"));
    args.reject_unknown()
}

fn build_coordinator(args: &Args) -> Result<Coordinator> {
    let schedule_path = args.get_or("schedule", "configs/growth_default.json");
    // training knobs, applied by backend_for after the reject below; the
    // forward-only subcommands (generate, info) never consume these, so
    // `texpand generate --threads 8` still fails as an unknown flag
    // instead of being silently ignored
    let _ = (args.get("threads"), args.get("micro-batch"));
    let tcfg = train_config(args)?;
    let mut opts = CoordinatorOptions::default();
    if let Some(scale) = args.get_f64("steps-scale")? {
        opts.steps_scale = scale;
    }
    if args.has("no-verify") {
        opts.verify_boundaries = false;
    }
    if args.has("no-checkpoints") {
        opts.save_checkpoints = false;
    }
    if let Some(c) = args.get("corpus") {
        opts.corpus = CorpusKind::parse(&c)?;
    }
    if let Some(n) = args.get_usize("corpus-len")? {
        opts.corpus_len = n;
    }
    // callers consume their own flags before this call, so everything a
    // coordinator subcommand accepts is registered by now
    reject_unknown_after_backend_flags(args)?;
    let schedule = GrowthSchedule::load(&schedule_path)?;
    let (manifest, backend, _) = backend_for(args, Some(&schedule))?;
    Coordinator::new(schedule, manifest, backend, tcfg, opts)
}

fn cmd_train(args: &Args) -> Result<()> {
    let runs_root = args.get_or("runs", "runs");
    let run_name = args.get_or("run-name", "train");
    // consumed here (before build_coordinator rejects unknown flags);
    // bound after the coordinator is constructed so flag errors win
    let metrics_addr = args.get("metrics-addr");
    // durable-run knobs (DESIGN.md §16): applied to the coordinator
    // options after construction, like the other train-only flags
    let checkpoint_every = args.get_usize("checkpoint-every")?;
    let checkpoint_keep = args.get_usize("checkpoint-keep")?;
    let resume = args.has("resume");
    if checkpoint_keep == Some(0) {
        return Err(Error::Cli("--checkpoint-keep must be >= 1".into()));
    }
    // adaptive policies synthesize architectures at run time; the pjrt
    // backend can only execute its precompiled stage table — reject the
    // combination up front, BEFORE any manifest/artifact resolution, so
    // the error is about the policy and not about missing artifacts
    let policy_flag = args
        .get_choice("policy", &["fixed", "plateau", "greedy"])?
        .map(|p| PolicyKind::parse(&p))
        .transpose()?;
    let backend_is_native = args.get_or("backend", "pjrt") == "native";
    let reject_adaptive_on_pjrt = |kind: PolicyKind| -> Result<()> {
        if kind != PolicyKind::Fixed && !backend_is_native {
            return Err(Error::Cli(format!(
                "--policy {} grows architectures at run time and needs --backend native; \
                 the pjrt backend executes a fixed stage table of AOT artifacts (--policy fixed)",
                kind.name()
            )));
        }
        Ok(())
    };
    if let Some(kind) = policy_flag {
        reject_adaptive_on_pjrt(kind)?;
    } else if !backend_is_native {
        // the schedule JSON's policy block can also select an adaptive
        // kind; peek at it before artifact resolution so the error talks
        // about the policy, not about missing artifacts. An unreadable
        // schedule falls through to build_coordinator's own error.
        if let Ok(s) = GrowthSchedule::load(&args.get_or("schedule", "configs/growth_default.json")) {
            reject_adaptive_on_pjrt(s.policy.kind)?;
        }
    }
    let mut coord = build_coordinator(args)?; // rejects unknown flags
    if let Some(n) = checkpoint_every {
        coord.opts.checkpoint_every = n;
    }
    if let Some(k) = checkpoint_keep {
        coord.opts.checkpoint_keep = k;
    }
    coord.opts.resume = resume;
    let mut pcfg = coord.schedule.policy.clone();
    if let Some(kind) = policy_flag {
        pcfg.kind = kind;
    }
    // belt-and-braces: nothing adaptive may reach a pjrt run
    reject_adaptive_on_pjrt(pcfg.kind)?;
    let mut policy =
        texpand::growth::build_policy(&coord.schedule, coord.opts.steps_scale, &pcfg, coord.tcfg.seed);
    // live scrape target for the whole run: train_segment publishes
    // step/loss/throughput gauges into the same global registry
    let metrics_server = match &metrics_addr {
        Some(addr) => {
            let srv = texpand::obs::MetricsServer::bind(addr, texpand::obs::global().clone())?;
            println!("metrics listening on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let summary = coord.run_with_policy(&runs_root, &run_name, policy.as_mut())?;
    if let Some(srv) = metrics_server {
        srv.shutdown();
    }
    println!("\n=== run summary ({}, policy {}) ===", summary.run_dir, summary.policy);
    println!("{:<10} {:>8} {:>10} {:>10} {:>12} {:>10}", "stage", "steps", "first", "final", "tok/s", "ms/step");
    for s in &summary.stages {
        println!(
            "{:<10} {:>8} {:>10.4} {:>10.4} {:>12.0} {:>10.1}",
            s.stage, s.steps_run, s.first_loss, s.final_loss, s.tokens_per_sec, s.step_ms_mean
        );
    }
    if !summary.boundaries.is_empty() {
        println!("\n{:<12} {:>5} {:>12} {:>12} {:>10} {:>10}", "boundary", "ops", "rustΔ", "pjrtΔ", "loss_pre", "loss_post");
        for b in &summary.boundaries {
            println!(
                "{:<12} {:>5} {:>12.3e} {:>12.3e} {:>10.4} {:>10.4}",
                b.into_stage, b.ops, b.rust_delta, b.pjrt_delta, b.loss_before, b.loss_after
            );
        }
    }
    println!("\nfinal eval loss: {:.4} over {} steps", summary.final_eval_loss, summary.total_steps);
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let mut coord = build_coordinator(args)?; // rejects unknown flags
    // no-training verification: run the schedule with ~0 steps per stage
    coord.opts.steps_scale = 0.0; // clamps to 1 step, keep tiny
    coord.opts.save_checkpoints = false;
    let summary = coord.run("runs", "verify")?;
    println!("\n=== preservation verification ===");
    let tol = coord.tcfg.preserve_tol;
    let mut ok = true;
    for b in &summary.boundaries {
        let pass = b.rust_delta <= tol && b.pjrt_delta <= tol;
        ok &= pass;
        println!(
            "boundary into {:<10} rustΔ={:.3e} pjrtΔ={:.3e} [{}]",
            b.into_stage,
            b.rust_delta,
            b.pjrt_delta,
            if pass { "PASS" } else { "FAIL" }
        );
    }
    if ok {
        println!("all boundaries function-preserving (tol {tol:.0e})");
        Ok(())
    } else {
        Err(Error::Train("preservation verification failed".into()))
    }
}

fn cmd_family(args: &Args) -> Result<()> {
    let base_path = args.require("base")?;
    let steps = args.get_usize("steps")?.unwrap_or(50);
    let runs_root = args.get_or("runs", "runs");
    let run_name = args.get_or("run-name", "family");
    let mut coord = build_coordinator(args)?; // rejects unknown flags
    let (base, meta) = ParamStore::load(&base_path)?;
    println!("base checkpoint: {base_path} ({} params, meta {})", base.num_scalars(), meta.to_string());

    // find which stage the base matches, then branch to every later stage
    let base_idx = coord
        .schedule
        .stages
        .iter()
        .position(|s| &s.config == base.config())
        .ok_or_else(|| Error::Config("checkpoint config matches no schedule stage".into()))?;
    let probe = {
        let st = &coord.schedule.stages[base_idx];
        texpand::data::Batcher::from_corpus(
            coord.opts.corpus,
            coord.opts.corpus_len,
            st.config.vocab,
            st.config.seq,
            coord.schedule.batch,
            coord.tcfg.seed ^ 0xC0DE,
        )?
        .probe(coord.tcfg.seed ^ 0xE7A1)
    };
    println!("\n{:<10} {:>12} {:>10} {:>12}", "branch", "params", "eval", "tok/s");
    for i in base_idx..coord.schedule.stages.len() {
        let stage = coord.schedule.stages[i].clone();
        let ops: Vec<_> =
            coord.schedule.stages[base_idx + 1..=i].iter().flat_map(|s| s.apply.clone()).collect();
        let (branched, report, eval) = coord.branch(
            &base,
            &ops,
            &stage.name,
            steps,
            &runs_root,
            &format!("{run_name}-{}", stage.name),
            &probe,
        )?;
        println!(
            "{:<10} {:>12} {:>10.4} {:>12.0}",
            stage.name,
            branched.num_scalars(),
            eval,
            report.tokens_per_sec
        );
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let ckpt = args.require("ckpt")?;
    let prompt = args.get_or("prompt", "the ");
    let tokens = args.get_usize("tokens")?.unwrap_or(200);
    let mut sampler = texpand::generate::Sampler::default();
    if let Some(t) = args.get_f64("temperature")? {
        sampler.temperature = t as f32;
    }
    if let Some(k) = args.get_usize("top-k")? {
        sampler.top_k = if k == 0 { None } else { Some(k) };
    }
    if let Some(s) = args.get_u64("seed")? {
        sampler.seed = s;
    }
    reject_unknown_after_backend_flags(args)?;
    let (manifest, mut backend, _) = backend_and_manifest(args)?;

    let (params, _) = ParamStore::load(&ckpt)?;
    let stage_meta = manifest
        .stages
        .iter()
        .find(|s| &s.config == params.config())
        .ok_or_else(|| Error::Config("checkpoint config matches no manifest stage".into()))?
        .clone();
    let stage = backend.load_stage(&manifest, &stage_meta.name)?;

    let tok = texpand::data::ByteTokenizer::new(params.config().vocab)?;
    let ids = tok.encode(prompt.as_bytes());
    // the stage executes a fixed batch: replicate the prompt
    let prompts = vec![ids; manifest.batch];
    let out =
        texpand::generate::generate(backend.as_ref(), &stage, &params, &prompts, tokens, &sampler)?;
    let text = String::from_utf8_lossy(&tok.decode(&out[0])).into_owned();
    println!(
        "--- {} ({} params, stage {}) | temp {} top-k {:?} ---",
        ckpt,
        params.num_scalars(),
        stage_meta.name,
        sampler.temperature,
        sampler.top_k
    );
    println!("{text}");
    Ok(())
}

/// `texpand serve` — the KV-cached batched inference engine on the
/// pure-Rust reference path (no artifacts needed). Loads a checkpoint (or
/// random-initializes a small demo model), feeds it corpus-derived
/// prompts, and optionally hot-swaps a function-preserving expansion onto
/// the live model mid-run.
fn cmd_serve(args: &Args) -> Result<()> {
    use texpand::serve::{Engine, EngineOptions};

    let requests = args.get_usize("requests")?.unwrap_or(8).max(1);
    let tokens = args.get_usize("tokens")?.unwrap_or(48).max(1);
    let slots = args.get_usize("slots")?.unwrap_or(4);
    let seed = args.get_u64("seed")?.unwrap_or(0);
    let corpus = match args.get("corpus") {
        Some(c) => texpand::data::CorpusKind::parse(&c)?,
        None => texpand::data::CorpusKind::MarkovText,
    };
    let mut sampler = texpand::generate::Sampler { seed, ..Default::default() };
    if let Some(t) = args.get_f32("temperature")? {
        sampler.temperature = t;
    }
    if let Some(k) = args.get_usize("top-k")? {
        sampler.top_k = if k == 0 { None } else { Some(k) };
    }
    let swap_ops = args.get("swap-ops").map(|s| texpand::serve::parse_swap_spec(&s)).transpose()?;
    let swap_after = args.get_u64("swap-after-ticks")?.unwrap_or(tokens as u64 / 2);
    let serial = args.has("serial");
    // --kv-quant=f32|f16|int8 picks the storage tier; the bare switch
    // keeps its original int8 meaning
    let kv_tier = match args.get("kv-quant") {
        Some(v) => texpand::serve::KvTier::parse(&v)?,
        None if args.has("kv-quant") => texpand::serve::KvTier::Int8,
        None => texpand::serve::KvTier::F32,
    };
    let http_addr = args.get("http-addr");
    let http_max_secs = args.get_u64("http-max-secs")?.unwrap_or(0);
    let admission = args.get_choice("admission", &["adaptive", "static"])?;
    let window_init = args.get_f64("window-init")?;
    let window_min = args.get_f64("window-min")?;
    let window_max = args.get_f64("window-max")?;
    if http_addr.is_none()
        && (admission.is_some()
            || window_init.is_some()
            || window_min.is_some()
            || window_max.is_some()
            || http_max_secs > 0)
    {
        return Err(Error::Cli(
            "--admission/--window-*/--http-max-secs apply to --http-addr serving only".into(),
        ));
    }
    let max_pending = args.get_usize("max-pending")?;
    let timeout_ticks = args.get_u64("timeout-ticks")?;
    let ckpt = args.get("ckpt");
    let warm = args.get("checkpoint");
    if ckpt.is_some() && warm.is_some() {
        return Err(Error::Cli(
            "--ckpt and --checkpoint both select the model; pass one".into(),
        ));
    }
    let metrics_addr = args.get("metrics-addr");
    let linger_ms = args.get_u64("metrics-linger-ms")?.unwrap_or(0);
    let span_sample = args.get_u64("span-sample")?.unwrap_or(1).max(1);
    let runs_root = args.get_or("runs", "runs");
    let run_name = args.get_or("run-name", "serve");
    args.reject_unknown()?;

    let (params, source) = match (&warm, &ckpt) {
        // warm start: the durable run checkpoint's trained weights go
        // straight into the engine (DESIGN.md §16). A directory means
        // "the run's ckpt chain" — serve the newest valid generation.
        (Some(path), _) => {
            let p = std::path::Path::new(path);
            let (label, ck) = if p.is_dir() {
                let (gen, ck) = texpand::ckpt::Chain::open(p, 1)?
                    .load_latest_valid()?
                    .ok_or_else(|| {
                        Error::Checkpoint(format!("no valid checkpoint generation under {path}"))
                    })?;
                (format!("{path} (gen {gen})"), ck)
            } else {
                (path.clone(), texpand::ckpt::RunCheckpoint::load(path)?)
            };
            let label =
                format!("{label}, warm-start at global step {}", ck.global_step);
            (ck.params, label)
        }
        (None, Some(path)) => (ParamStore::load(path)?.0, path.clone()),
        (None, None) => {
            // demo model: untrained, but every serving mechanism is live
            let cfg = texpand::config::ModelConfig {
                layers: 2, hidden: 32, heads: 2, k: 16, v: 16, mlp: 64, seq: 48, vocab: 128,
            };
            let params =
                ParamStore::init(&cfg, &mut texpand::rng::Pcg32::seeded(seed), 0.02);
            (params, "<random demo model>".to_string())
        }
    };
    let cfg = *params.config();
    println!("serving {source} ({} params, {cfg:?})", params.num_scalars());

    let mut opts = EngineOptions {
        max_slots: slots,
        parallel: !serial,
        span_sample,
        kv_tier,
        ..Default::default()
    };
    if let Some(n) = max_pending {
        opts.max_pending = n;
    }
    if let Some(n) = timeout_ticks {
        opts.request_timeout_ticks = n;
    }
    let mut engine = Engine::new(params, opts);

    // live scrape target + span log: the engine publishes into the global
    // registry, so one listener covers counters, gauges and latency
    // histograms; per-request spans land in runs/<name>/events.jsonl and
    // (when a listener is up) stream live from a bounded ring at /spans
    let span_ring = metrics_addr
        .as_ref()
        .map(|_| std::sync::Arc::new(texpand::obs::SpanRing::new(1024)));
    let metrics_server = match &metrics_addr {
        Some(addr) => {
            let srv = texpand::obs::MetricsServer::bind_with_spans(
                addr,
                texpand::obs::global().clone(),
                span_ring.clone(),
            )?;
            println!("metrics listening on http://{}/metrics (spans at /spans)", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    if let Some(ring) = &span_ring {
        engine.set_span_ring(std::sync::Arc::clone(ring));
    }
    let mut logger = texpand::metrics::RunLogger::create(&runs_root, &run_name)?.quiet();
    logger.event(
        "serve_start",
        vec![
            ("requests", Value::num(requests as f64)),
            ("tokens", Value::num(tokens as f64)),
            ("slots", Value::num(slots as f64)),
        ],
    );

    // --http-addr: hand the engine to the streaming HTTP front-end and
    // serve until /quitz (or the --http-max-secs safety cap)
    if let Some(addr) = &http_addr {
        use texpand::serve::http::{AimdOptions, HttpServer, HttpServerOptions};
        let mut aimd = AimdOptions { adaptive: admission.as_deref() != Some("static"), ..Default::default() };
        if let Some(w) = window_init {
            aimd.initial_window = w;
        }
        if let Some(w) = window_min {
            aimd.min_window = w;
        }
        if let Some(w) = window_max {
            aimd.max_window = w;
        }
        if aimd.min_window < 1.0 || aimd.max_window < aimd.min_window {
            return Err(Error::Cli(
                "admission windows need 1 <= --window-min <= --window-max".into(),
            ));
        }
        let hopts = HttpServerOptions {
            aimd,
            max_new_tokens_cap: 0, // server default cap
            span_ring: span_ring.clone(),
        };
        let server = HttpServer::bind(addr, engine, hopts)?;
        // the machine-parseable line ci.sh and loadgen scripts key on
        println!("serving on http://{}", server.local_addr());
        println!(
            "POST /v1/generate streams chunked NDJSON; admission {} (GET /quitz to stop)",
            if admission.as_deref() == Some("static") { "static" } else { "adaptive" }
        );
        logger.event(
            "serve_http_start",
            vec![
                ("addr", Value::str(server.local_addr().to_string())),
                ("admission", Value::str(admission.as_deref().unwrap_or("adaptive"))),
            ],
        );
        let started = std::time::Instant::now();
        loop {
            if server.wait_for_quit(std::time::Duration::from_millis(500)) {
                break;
            }
            if http_max_secs > 0 && started.elapsed().as_secs() >= http_max_secs {
                println!("--http-max-secs {http_max_secs} reached; shutting down");
                break;
            }
        }
        let (engine, summary) = server.shutdown()?;
        println!(
            "http summary: {} requests, {} streamed, {} rejected, {} errors, \
             {} admission verdicts, final window {}",
            summary.requests,
            summary.streamed,
            summary.rejected,
            summary.errors,
            summary.adjustments,
            summary.final_window
        );
        println!("counters: {}", engine.counters().to_json().to_pretty());
        println!(
            "peak kv bytes/seq: {} ({} tier)",
            engine.peak_kv_bytes_per_seq(),
            kv_tier.label()
        );
        logger.event(
            "serve_http_done",
            vec![
                ("requests", Value::num(summary.requests as f64)),
                ("streamed", Value::num(summary.streamed as f64)),
                ("rejected", Value::num(summary.rejected as f64)),
                ("errors", Value::num(summary.errors as f64)),
                ("adjustments", Value::num(summary.adjustments as f64)),
                ("final_window", Value::num(summary.final_window as f64)),
                ("counters", engine.counters().to_json()),
            ],
        );
        logger.flush();
        if let Some(e) = logger.take_write_error() {
            eprintln!(
                "warning: serve log writes failed ({} lines dropped): {e}",
                logger.dropped_lines()
            );
        }
        if let Some(srv) = metrics_server {
            srv.shutdown();
        }
        return Ok(());
    }

    // corpus-derived prompts: staggered windows over synthesized text
    let tok = texpand::data::ByteTokenizer::new(cfg.vocab)?;
    let text = texpand::data::generate_corpus(corpus, 4096, seed ^ 0x5E7E);
    let prompt_len = 8.min(cfg.seq - 1);
    let mut ids = Vec::with_capacity(requests);
    for i in 0..requests {
        let start = (i * 97) % (text.len() - prompt_len);
        let prompt = tok.encode(&text[start..start + prompt_len]);
        // backpressure-aware feeding: when the engine is at capacity,
        // drain ticks until a slot frees instead of aborting the run
        while !engine.has_capacity() {
            engine.tick()?;
        }
        ids.push(engine.submit(prompt, tokens, sampler)?);
    }

    let mut swap_rng = texpand::rng::Pcg32::new(seed, 0x5A4B);
    let mut swapped = false;
    while !engine.is_idle() {
        engine.tick()?;
        for span in engine.take_spans() {
            logger.event("span", span.fields());
        }
        if let (false, Some(ops)) = (swapped, &swap_ops) {
            if engine.ticks() >= swap_after {
                let plan = texpand::expand::ExpansionPlan::new(engine.config(), ops.clone())?;
                println!("hot-swap plan: {}", plan.summary());
                let expand_opts = texpand::expand::ExpandOptions::default();
                let report = engine.hot_swap(&plan, &mut swap_rng, &expand_opts)?;
                println!(
                    "hot-swap committed mid-flight: {} ops, probe max|Δ| = {:.3e}, \
                     params {} -> {} (predicted {}), {} in-flight caches remapped, {:.1} ms",
                    report.ops,
                    report.probe_delta,
                    report.params_before,
                    report.params_after,
                    report.params_predicted,
                    report.remapped_sequences,
                    report.swap_ms
                );
                // the serve-side preservation monitor: same event shape
                // the training coordinator logs at every boundary
                let within_tol = report.probe_delta <= opts.preserve_tol;
                logger.event(
                    "preservation",
                    vec![
                        ("boundary", Value::str("hot_swap")),
                        ("probe_delta", Value::num(f64::from(report.probe_delta))),
                        ("backend_delta", Value::num(f64::from(report.probe_delta))),
                        ("tol", Value::num(f64::from(opts.preserve_tol))),
                        ("within_tol", Value::Bool(within_tol)),
                    ],
                );
                swapped = true;
            }
        }
    }
    if let (false, Some(_)) = (swapped, &swap_ops) {
        eprintln!(
            "warning: --swap-ops never fired — serving drained before tick {swap_after}; \
             lower --swap-after-ticks or raise --tokens to swap under load"
        );
    }

    println!("\n--- completions (temp {} top-k {:?}) ---", sampler.temperature, sampler.top_k);
    for id in ids {
        let c = engine.poll(id).expect("engine idle implies all requests completed");
        let text = String::from_utf8_lossy(&tok.decode(&c.tokens)).into_owned();
        let tag = match c.finish {
            texpand::serve::FinishReason::MaxTokens => "",
            texpand::serve::FinishReason::TimedOut => " [TIMED OUT]",
        };
        println!(
            "[req {id}] {} prompt + {} generated in {} ticks{tag}: {text:?}",
            c.prompt_len, c.generated, c.ticks_in_flight
        );
    }
    println!("\ncounters: {}", engine.counters().to_json().to_pretty());
    println!("peak kv bytes/seq: {} ({} tier)", engine.peak_kv_bytes_per_seq(), kv_tier.label());
    // backpressure-drain ticks finish requests before the main loop runs;
    // sweep any spans still buffered in the engine into the log
    for span in engine.take_spans() {
        logger.event("span", span.fields());
    }
    logger.event("serve_done", vec![("counters", engine.counters().to_json())]);
    logger.flush();
    if let Some(e) = logger.take_write_error() {
        eprintln!(
            "warning: serve log writes failed ({} lines dropped): {e}",
            logger.dropped_lines()
        );
    }
    if let Some(srv) = metrics_server {
        if linger_ms > 0 {
            println!(
                "metrics lingering on http://{} for {linger_ms} ms (GET /quitz to release)",
                srv.local_addr()
            );
            srv.wait_for_quit(std::time::Duration::from_millis(linger_ms));
        }
        srv.shutdown();
    }
    Ok(())
}

/// `texpand loadgen` — synthetic client fleet against a `serve
/// --http-addr` listener (see [`texpand::serve::loadgen`]). Prints the
/// client-observed outcome and appends a `serve_http_load` row to
/// runs/bench.jsonl, so adaptive-vs-static admission comparisons land in
/// the same series the benches use.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use texpand::serve::loadgen::{self, LoadgenOptions};
    let addr = args.require("addr")?;
    let mut opts = LoadgenOptions { addr, ..Default::default() };
    if let Some(n) = args.get_usize("clients")? {
        opts.clients = n;
    }
    if let Some(n) = args.get_usize("requests")? {
        opts.requests = n;
    }
    if let Some(r) = args.get_f64("rate")? {
        if r < 0.0 {
            return Err(Error::Cli("--rate must be >= 0 (0 = closed loop)".into()));
        }
        opts.rate_per_sec = r;
    }
    if let Some(n) = args.get_usize("tokens")? {
        opts.tokens = n.max(1);
    }
    if let Some(mix) = args.get("prompt-mix") {
        let mut lens = Vec::new();
        for part in mix.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            lens.push(part.parse::<usize>().map_err(|_| {
                Error::Cli(format!("--prompt-mix entry '{part}' is not an integer"))
            })?);
        }
        opts.prompt_mix = lens;
    }
    if let Some(d) = args.get_u64("deadline-ms")? {
        opts.deadline_ms = d;
    }
    if let Some(v) = args.get_usize("vocab")? {
        opts.vocab = v;
    }
    if let Some(s) = args.get_u64("seed")? {
        opts.seed = s;
    }
    if let Some(t) = args.get_u64("timeout-ms")? {
        opts.timeout = std::time::Duration::from_millis(t.max(1));
    }
    let case = args.get("case");
    args.reject_unknown()?;

    let report = loadgen::run(&opts)?;
    println!(
        "loadgen ({} loop): {} sent -> {} completed, {} rejected (429), {} timeouts, {} errors",
        report.mode, report.sent, report.completed, report.rejected, report.timeouts, report.errors
    );
    println!(
        "streamed {} tokens in {:.0} ms ({:.1} tok/s)",
        report.tokens_streamed, report.wall_ms, report.tokens_per_sec
    );
    let case = case.unwrap_or_else(|| {
        format!("{}c-{}r-{}", opts.clients, opts.requests, report.mode)
    });
    let mut reporter = texpand::bench_util::Reporter::new("serve_http_load");
    let streamed = report.completed + report.timeouts;
    if streamed > 0 {
        let stats = texpand::bench_util::Stats {
            iters: streamed,
            mean_ns: report.mean_ms * 1e6,
            p50_ns: report.p50_ms * 1e6,
            p95_ns: report.p95_ms * 1e6,
            p99_ns: report.p99_ms * 1e6,
            min_ns: 0.0,
            max_ns: report.max_ms * 1e6,
        };
        reporter.row(
            &case,
            &stats,
            vec![
                ("kind", Value::str("serve_http_load")),
                ("sent", Value::num(report.sent as f64)),
                ("completed", Value::num(report.completed as f64)),
                ("rejected", Value::num(report.rejected as f64)),
                ("timeouts", Value::num(report.timeouts as f64)),
                ("errors", Value::num(report.errors as f64)),
                ("tokens_streamed", Value::num(report.tokens_streamed as f64)),
                ("tokens_per_sec", Value::num(report.tokens_per_sec)),
                ("mode", Value::str(report.mode)),
                ("clients", Value::num(opts.clients as f64)),
                ("rate_per_sec", Value::num(opts.rate_per_sec)),
            ],
        );
    } else {
        // nothing streamed (all rejected/errored): still record the run
        reporter.value_row(
            &case,
            "tokens_per_sec",
            report.tokens_per_sec,
            vec![
                ("kind", Value::str("serve_http_load")),
                ("sent", Value::num(report.sent as f64)),
                ("completed", Value::num(report.completed as f64)),
                ("rejected", Value::num(report.rejected as f64)),
                ("timeouts", Value::num(report.timeouts as f64)),
                ("errors", Value::num(report.errors as f64)),
                ("mode", Value::str(report.mode)),
            ],
        );
    }
    reporter.flush();
    if report.completed == 0 && report.timeouts == 0 && report.rejected == 0 {
        return Err(Error::Serve(format!(
            "no request succeeded against {} ({} errors)",
            opts.addr, report.errors
        )));
    }
    Ok(())
}

/// `texpand scrape` — one HTTP GET against a `--metrics-addr` listener
/// using the std::net client in [`texpand::obs`]; CI images have no curl,
/// so the binary is its own scraper. Prints the response body verbatim.
/// `--spans` switches to the chunked `/spans` stream and tails it — one
/// JSON span per line — until `--count N` lines arrive, the server
/// stops, or the stream goes quiet for `--timeout-ms`.
fn cmd_scrape(args: &Args) -> Result<()> {
    let addr = args.require("addr")?;
    let spans = args.has("spans");
    let count = args.get_usize("count")?;
    let path = args.get_or("path", if spans { "/spans" } else { "/metrics" });
    let timeout_ms = args.get_u64("timeout-ms")?.unwrap_or(5000);
    args.reject_unknown()?;
    let timeout = std::time::Duration::from_millis(timeout_ms.max(1));
    if spans {
        let n = texpand::obs::http_stream_lines(&addr, &path, timeout, count, &mut |line| {
            println!("{line}");
        })?;
        if n == 0 {
            eprintln!("(no spans arrived before the stream went quiet)");
        }
        return Ok(());
    }
    if count.is_some() {
        return Err(Error::Cli("--count applies to --spans streaming only".into()));
    }
    let (status, body) = texpand::obs::http_get(&addr, &path, timeout)?;
    if status != 200 {
        return Err(Error::Serve(format!("GET {path} on {addr} returned HTTP {status}")));
    }
    print!("{body}");
    Ok(())
}

/// `texpand runs` — the run store CLI. `list` ingests every run under
/// the runs root (plus bench.jsonl) and tabulates them; `show RUN`
/// prints the run's aggregate summary as JSON; `stats RUN` prints it as
/// greppable `key: value` lines (ci.sh keys on `expansions:` and
/// `params_delta_total:`). Every action ingests first, so the store is
/// always current with the source logs.
fn cmd_runs(args: &Args) -> Result<()> {
    use texpand::obs::RunStore;
    let action = args.positional(0).unwrap_or_else(|| "list".to_string());
    let runs_root = args.get_or("runs", "runs");
    match action.as_str() {
        "list" => {
            args.reject_unknown()?;
            let store = RunStore::open(&runs_root)?;
            let reports = store.ingest_all()?;
            if reports.is_empty() {
                println!("(no runs with events.jsonl under {runs_root})");
                return Ok(());
            }
            println!("{:<28} {:>9} {:>6} {:>12} {:>5}", "run", "records", "new", "bytes", "bad");
            for (name, r) in &reports {
                println!(
                    "{:<28} {:>9} {:>6} {:>12} {:>5}",
                    name, r.total_records, r.new_records, r.source_bytes, r.parse_errors
                );
            }
            Ok(())
        }
        "show" | "stats" => {
            let run = args.require_positional(1, "RUN")?;
            args.reject_unknown()?;
            let store = RunStore::open(&runs_root)?;
            let rep = store.ingest(&run)?;
            if rep.parse_errors > 0 {
                eprintln!(
                    "warning: {} corrupted line(s) in {run}'s event log were counted and \
                     skipped during ingest",
                    rep.parse_errors
                );
            }
            let s = store.stats(&run)?;
            if action == "show" {
                println!("{}", s.to_json().to_pretty());
                return Ok(());
            }
            println!("run: {}", s.run);
            println!("policy: {}", s.policy.as_deref().unwrap_or("?"));
            println!("schedule: {}", s.schedule.as_deref().unwrap_or("?"));
            println!("records: {}", s.records);
            println!("malformed: {}", s.malformed);
            println!("segments: {}", s.segments.len());
            println!("loss_points: {}", s.loss_points.len());
            println!("expansions: {}", s.expansions.len());
            println!("params_delta_total: {}", s.params_delta_total());
            let within = s.preservation.iter().filter(|p| p.within_tol).count();
            println!("preservation_within_tol: {within}/{}", s.preservation.len());
            println!("decisions: {} (expand: {})", s.decisions, s.expand_decisions);
            println!("checkpoints: {}", s.checkpoints.len());
            println!("resumes: {}", s.resumes.len());
            println!("spans: {}", s.spans);
            if let Some(sv) = &s.serve {
                println!(
                    "serve: completed {} / {} tokens / {:.0} tok/s / {} swaps",
                    sv.completed, sv.tokens_generated, sv.tokens_per_sec, sv.swaps
                );
            }
            if let Some(f) = s.final_eval_loss {
                println!("final_eval_loss: {f:.4}");
            }
            if let Some(n) = s.total_steps {
                println!("total_steps: {n}");
            }
            Ok(())
        }
        "compact" => {
            let keep = args
                .get_usize("keep")?
                .ok_or_else(|| Error::Cli("runs compact needs --keep N".into()))?;
            args.reject_unknown()?;
            let store = RunStore::open(&runs_root)?;
            store.ingest_all()?;
            let rep = store.compact(keep)?;
            println!(
                "compacted {} of {} run(s): kept {} with full records, freed {} bytes \
                 (summaries retained for all)",
                rep.compacted, rep.examined, rep.kept, rep.bytes_freed
            );
            Ok(())
        }
        other => Err(Error::Cli(format!(
            "unknown runs action '{other}' (expected list|show|stats|compact)"
        ))),
    }
}

/// `texpand ckpt` — durable-chain inspection (DESIGN.md §16.4) without
/// resuming anything. `list DIR` prints one row per retained generation:
/// global step, parameter count, file size and the full-checksum verdict
/// (the same validation `--resume` performs, minus the engine). `verify
/// DIR` prints the same table and exits nonzero iff *no* generation
/// passes — the corrupt-only condition `Chain::load_latest_valid` treats
/// as fatal — so CI can assert a crash/resume chain stayed healthy.
fn cmd_ckpt(args: &Args) -> Result<()> {
    let action = args.require_positional(0, "ACTION (list|verify)")?;
    let dir = args.require_positional(1, "DIR")?;
    args.reject_unknown()?;
    if action != "list" && action != "verify" {
        return Err(Error::Cli(format!("unknown ckpt action '{action}' (expected list|verify)")));
    }
    let path = std::path::Path::new(&dir);
    // Chain::open mkdirs; an inspection command must not invent a chain
    // out of a typo'd path
    if !path.is_dir() {
        return Err(Error::Cli(format!("'{dir}' is not a checkpoint chain directory")));
    }
    // keep=MAX: inspection never prunes, whatever the run's retention was
    let chain = texpand::ckpt::Chain::open(path, usize::MAX)?;
    let gens = chain.generations()?;
    if gens.is_empty() {
        println!("(no checkpoint generations under {dir})");
        return if action == "verify" {
            Err(Error::Checkpoint(format!("{dir} holds no checkpoint generations to verify")))
        } else {
            Ok(())
        };
    }
    println!("chain {dir}: {} retained generation(s)", gens.len());
    println!("{:<12} {:>8} {:>12} {:>12}  status", "gen", "step", "params", "bytes");
    let mut valid = 0usize;
    let mut newest_valid = None;
    for &gen in &gens {
        let gpath = chain.path_of(gen);
        let bytes = std::fs::metadata(&gpath).map(|m| m.len()).unwrap_or(0);
        // full checksum validation: header, per-section and payload sums
        match texpand::ckpt::RunCheckpoint::load(&gpath.display().to_string()) {
            Ok(ck) => {
                valid += 1;
                newest_valid = Some(gen);
                println!(
                    "gen-{gen:06}   {:>8} {:>12} {:>12}  valid  {}",
                    ck.global_step,
                    ck.params.num_scalars(),
                    bytes,
                    ck.fingerprint.to_string()
                );
            }
            Err(e) => {
                println!("gen-{gen:06}   {:>8} {:>12} {:>12}  CORRUPT ({e})", "-", "-", bytes);
            }
        }
    }
    println!("\n{valid}/{} generation(s) pass full checksum validation", gens.len());
    match newest_valid {
        Some(gen) => println!("chain resumable from gen-{gen:06}"),
        None if action == "verify" => {
            return Err(Error::Checkpoint(format!(
                "all {} retained generation(s) under {dir} are corrupt — chain is not resumable",
                gens.len()
            )));
        }
        None => println!("chain is NOT resumable (every generation corrupt)"),
    }
    Ok(())
}

/// Compress a loss trajectory into a fixed-width unicode sparkline
/// (bucket means, darker = higher loss). Empty when nothing is finite.
fn sparkline(values: &[f64], width: usize) -> String {
    const LEVELS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let finite: Vec<f64> = values.iter().copied().filter(|v| v.is_finite()).collect();
    if finite.is_empty() {
        return String::new();
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in &finite {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-12);
    let n = finite.len();
    let w = width.max(1).min(n);
    let mut out = String::with_capacity(w * 3);
    for i in 0..w {
        let a = i * n / w;
        let b = ((i + 1) * n / w).max(a + 1).min(n);
        let mean = finite[a..b].iter().sum::<f64>() / (b - a) as f64;
        let lvl = (((mean - lo) / span) * 7.0).round().clamp(0.0, 7.0) as usize;
        out.push(LEVELS[lvl]);
    }
    out
}

/// `texpand report RUN` — the growth-timeline reporter. Renders, from
/// the run store: every trained stage with its loss sparkline, each
/// expansion boundary with the plan's predicted param/FLOP deltas next
/// to the measured ones, a preservation-drift row per boundary checked
/// against the probe tolerance, and the serve phase percentiles when
/// the run served traffic.
fn cmd_report(args: &Args) -> Result<()> {
    use texpand::obs::RunStore;
    let run = args.require_positional(0, "RUN")?;
    let runs_root = args.get_or("runs", "runs");
    args.reject_unknown()?;
    let store = RunStore::open(&runs_root)?;
    store.ingest(&run)?;
    let s = store.stats(&run)?;
    println!(
        "=== growth timeline: {run} (policy {}, schedule {}) ===",
        s.policy.as_deref().unwrap_or("?"),
        s.schedule.as_deref().unwrap_or("?")
    );
    if s.malformed > 0 {
        println!("({} malformed record(s) skipped)", s.malformed);
    }

    let print_expansion = |e: &texpand::obs::store::ExpansionRecord| {
        println!("  └─ expansion into '{}' ({} op(s), {:.1} ms surgery)", e.into_stage, e.ops, e.surgery_ms);
        let measured = e
            .param_delta
            .or(e.params_before.map(|b| e.params_after.saturating_sub(b)));
        let predicted = e
            .plan
            .as_ref()
            .map(|p| p.param_delta() as u64)
            .or(e.params_before.map(|b| e.params_predicted.saturating_sub(b)));
        let verdict = match (measured, predicted) {
            (Some(m), Some(p)) if m == p => "exact",
            (Some(_), Some(_)) => "MISMATCH",
            _ => "unrecorded",
        };
        println!(
            "       params -> {} (measured Δ {}, predicted Δ {}; {verdict})",
            e.params_after,
            measured.map_or("?".to_string(), |m| format!("+{m}")),
            predicted.map_or("?".to_string(), |p| format!("+{p}")),
        );
        println!("       est fwd FLOP/tok Δ {:+.3e}", e.flops_delta_est);
        if let Some(err) = &e.plan_error {
            println!("       plan evidence INVALID: {err}");
        }
        match s.preservation.iter().find(|p| p.boundary == e.into_stage) {
            Some(p) => {
                let status = if p.within_tol { "ok" } else { "DRIFT EXCEEDS TOL" };
                println!(
                    "       preservation: probe Δ {:.3e} / backend Δ {:.3e} vs tol {:.0e} \
                     [{status}]; eval {:.4} -> {:.4} (drift {:+.4})",
                    p.probe_delta, p.backend_delta, p.tol, p.eval_before, p.eval_after, p.eval_drift
                );
            }
            None => println!("       preservation: (no measurement recorded at this boundary)"),
        }
    };

    for (i, seg) in s.segments.iter().enumerate() {
        let pts: Vec<f64> = s
            .loss_points
            .iter()
            .filter(|p| p.stage == seg.stage)
            .map(|p| p.loss)
            .collect();
        println!(
            "\n{:<10} {:>5} steps  loss {:.4} -> {:.4}  {:>10} params  {:>8.0} tok/s  {}",
            seg.stage,
            seg.steps,
            seg.first_loss,
            seg.final_loss,
            seg.params,
            seg.tokens_per_sec,
            sparkline(&pts, 40)
        );
        if let Some(e) = s.expansions.get(i) {
            print_expansion(e);
        }
    }
    // boundaries past the last recorded segment (crashed/partial runs,
    // or serve-only logs with boundary events but no stage_done rows)
    for e in s.expansions.iter().skip(s.segments.len()) {
        print_expansion(e);
    }
    // serve-side preservation measurements (hot swaps) have no segment row
    for p in &s.preservation {
        if !s.expansions.iter().any(|e| e.into_stage == p.boundary) {
            let status = if p.within_tol { "ok" } else { "DRIFT EXCEEDS TOL" };
            println!(
                "\npreservation ({}): probe Δ {:.3e} vs tol {:.0e} [{status}]",
                p.boundary, p.probe_delta, p.tol
            );
        }
    }

    // the run's durable recovery points: where a crash could have been
    // resumed from, and any resume that actually happened
    if !s.checkpoints.is_empty() || !s.resumes.is_empty() {
        println!("\nrecovery points ({} checkpoint(s) written):", s.checkpoints.len());
        for c in &s.checkpoints {
            println!(
                "  gen {:>4}  step {:>6}  segment {:<3} [{}]  {} bytes in {:.1} ms",
                c.gen, c.global_step, c.segment, c.trigger, c.bytes, c.write_ms
            );
        }
        for r in &s.resumes {
            println!(
                "  ↻ resumed from gen {} at step {} (segment {})",
                r.gen, r.global_step, r.segment
            );
        }
    }

    if let Some(sv) = &s.serve {
        println!(
            "\nserve: {} completed, {} tokens, {:.0} tok/s, {} swaps, {} rejected, {} timeouts",
            sv.completed, sv.tokens_generated, sv.tokens_per_sec, sv.swaps, sv.rejected, sv.timeouts
        );
        println!("  {:<8} {:>9} {:>9} {:>9}", "phase", "p50 ms", "p95 ms", "p99 ms");
        for (name, p) in [
            ("queue", &sv.queue_latency),
            ("prefill", &sv.prefill_latency),
            ("decode", &sv.decode_latency),
            ("total", &sv.total_latency),
        ] {
            println!("  {:<8} {:>9.2} {:>9.2} {:>9.2}", name, p.p50_ms, p.p95_ms, p.p99_ms);
        }
    }

    let within = s.preservation.iter().filter(|p| p.within_tol).count();
    println!(
        "\n{} expansion(s), Δparams total {}; preservation within tol at {within}/{} boundaries",
        s.expansions.len(),
        s.params_delta_total(),
        s.preservation.len()
    );
    if let (Some(f), Some(n)) = (s.final_eval_loss, s.total_steps) {
        println!("final eval loss {f:.4} over {n} steps");
    }
    Ok(())
}

/// `texpand plan` — dry-run a growth schedule as a chain of
/// `ExpansionPlan`s, printing the config / param / FLOP trajectory without
/// training anything. The printed final param count is exact (ci.sh
/// cross-checks it against a trained run's final `StageReport.params`);
/// the FLOPs column is the plans' cost-model estimate. `--json` emits the
/// full plan metadata (ops round-trip through `GrowthOp::from_json`).
fn cmd_plan(args: &Args) -> Result<()> {
    let schedule_path = args.get_or("schedule", "configs/growth_default.json");
    let as_json = args.has("json");
    args.reject_unknown()?;
    let schedule = GrowthSchedule::load(&schedule_path)?;

    let mut cfg = schedule.stages[0].config;
    let mut plans = Vec::new();
    for stage in &schedule.stages[1..] {
        let plan = texpand::expand::ExpansionPlan::new(&cfg, stage.apply.clone())?;
        cfg = *plan.target_config();
        plans.push((stage.name.clone(), plan));
    }

    if as_json {
        // machine-readable mode: stdout is exactly one JSON document
        let doc = Value::obj(vec![
            ("schedule", Value::str(schedule.name.clone())),
            ("final_params", Value::num(cfg.num_params() as f64)),
            (
                "plans",
                Value::Arr(
                    plans
                        .iter()
                        .map(|(name, p)| {
                            // splice the plan fields into the stage row
                            let mut fields =
                                vec![("into_stage".to_string(), Value::str(name.clone()))];
                            if let Value::Obj(plan_fields) = p.to_json() {
                                fields.extend(plan_fields);
                            }
                            Value::Obj(fields)
                        })
                        .collect(),
                ),
            ),
        ]);
        println!("{}", doc.to_pretty());
    } else {
        println!("schedule '{}' ({}): {} stages, dry-run", schedule.name, schedule_path, schedule.stages.len());
        println!(
            "\n{:<10} {:>30} {:>12} {:>10} {:>14}",
            "stage", "ops", "params", "Δparams", "fwd MFLOP/tok"
        );
        let base = &schedule.stages[0];
        println!(
            "{:<10} {:>30} {:>12} {:>10} {:>14.2}",
            base.name,
            "(base)",
            base.config.num_params(),
            "-",
            texpand::expand::plan::est_fwd_flops_per_token(&base.config) / 1e6
        );
        for (name, plan) in &plans {
            let ops: Vec<&str> = plan.ops().iter().map(|o| o.kind()).collect();
            println!(
                "{:<10} {:>30} {:>12} {:>10} {:>14.2}",
                name,
                if ops.is_empty() { "(none)".to_string() } else { ops.join("+") },
                plan.params_after(),
                format!("+{}", plan.param_delta()),
                plan.flops_after() / 1e6
            );
        }
        println!(
            "\nparam counts are exact (plan postcondition); FLOPs are the cost-model \
             estimate (DESIGN.md §13)."
        );
        // the machine-greppable line ci.sh keys on
        println!("final params: {}", cfg.num_params());
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let path = args.require("ckpt")?;
    args.reject_unknown()?;
    let (params, meta) = ParamStore::load(&path)?;
    println!("checkpoint: {path}");
    println!("config: {:?}", params.config());
    println!("meta:   {}", meta.to_pretty());
    println!("{} tensors, {} scalars", params.len(), params.num_scalars());
    println!("\n{:<28} {:>16} {:>12} {:>12}", "param", "shape", "max|x|", "finite");
    for (spec, t) in params.iter() {
        println!(
            "{:<28} {:>16} {:>12.4e} {:>12}",
            spec.name,
            format!("{:?}", spec.shape),
            t.max_abs(),
            t.all_finite()
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    reject_unknown_after_backend_flags(args)?;
    // metadata only: never constructs an execution engine
    let (manifest, source) = resolve_manifest(args, None)?;
    println!("manifest: {source}");
    println!("schedule: {}  batch: {}  kernels: {}", manifest.schedule, manifest.batch, manifest.kernels);
    println!("\n{:<10} {:>8} {:>12} {:>40}", "stage", "steps", "params", "config");
    for s in &manifest.stages {
        println!(
            "{:<10} {:>8} {:>12} {:>40}",
            s.name,
            s.steps,
            s.num_params,
            format!(
                "N={} h={} E={} k={} v={} p={}",
                s.config.layers, s.config.hidden, s.config.heads, s.config.k, s.config.v, s.config.mlp
            )
        );
    }
    let _ = Value::Null; // keep import used if sections above change
    Ok(())
}
