//! Function-preserving hot-swap (S15d): live-model surgery between ticks.
//!
//! The swap is the serving-side payoff of the paper: because every
//! expansion op is function-preserving, a grown model can replace its
//! smaller predecessor **under live traffic** with zero output drift —
//! in-flight generations continue as if nothing happened. The sequence,
//! mirroring the growth coordinator's boundary protocol:
//!
//! 1. **Surgery** — `expand::apply_ops` on a copy of the live store (the
//!    live params serve every tick until the swap commits).
//! 2. **Preservation probe** — the pure-Rust oracle forward on a held-out
//!    probe batch, before vs after; `max|Δ logits| > tol` rejects the swap
//!    with the live state untouched (e.g. an op sequence built with
//!    constraint-violating init, the paper's E6 ablation).
//! 3. **KV-cache remap** — every in-flight sequence's cache is remapped
//!    through the same ops ([`crate::serve::kv::KvCache::remap`]) into
//!    fresh copies, and pending logits are recomputed from the remapped
//!    final hidden state.
//! 4. **Atomic commit** — params and caches swap together, only after
//!    every remap succeeded; a failure at any point leaves the engine
//!    serving the old model.

use crate::config::GrowthOp;
use crate::error::{Error, Result};
use crate::expand::{apply_ops, ExpandOptions};
use crate::metrics::Timer;
use crate::model;
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::serve::scheduler::Slot;

/// Outcome of a committed hot-swap.
#[derive(Clone, Debug)]
pub struct SwapReport {
    /// Ops applied.
    pub ops: usize,
    /// `max|Δ logits|` on the probe batch (old vs expanded params).
    pub probe_delta: f32,
    pub params_before: usize,
    pub params_after: usize,
    /// In-flight KV caches remapped through the ops.
    pub remapped_sequences: usize,
    /// Wall time of surgery + probe + remap + commit.
    pub swap_ms: f64,
}

/// Grow `params` by `ops` under live traffic (see module docs). `probe`
/// rows must be full-`seq` token rows; `slots` are the in-flight sequences
/// whose caches ride through the swap.
pub(crate) fn hot_swap(
    params: &mut ParamStore,
    slots: &mut [Slot],
    ops: &[GrowthOp],
    rng: &mut Pcg32,
    expand_opts: &ExpandOptions,
    probe: &[Vec<u32>],
    tol: f32,
) -> Result<SwapReport> {
    if ops.is_empty() {
        return Err(Error::Serve("hot-swap with no ops".into()));
    }
    let timer = Timer::start();

    // 1. surgery on a copy — the live store keeps serving until commit
    let before = model::forward(params.config(), params, probe)?;
    let new_params = apply_ops(params, ops, rng, expand_opts)
        .map_err(|e| Error::Serve(format!("hot-swap surgery failed: {e}")))?;

    // 2. preservation probe (coordinator-style, pure-Rust oracle)
    let after = model::forward(new_params.config(), &new_params, probe)?;
    let probe_delta = model::max_logit_delta(&before, &after)?;
    if probe_delta > tol {
        return Err(Error::Serve(format!(
            "hot-swap rejected: probe max|Δ logits| = {probe_delta:.3e} > tol {tol:.0e}; \
             live params unchanged"
        )));
    }

    // 3. remap every in-flight cache into a staged copy (commit is all-or-
    //    nothing: a half-remapped engine must be unreachable)
    let mut staged = Vec::with_capacity(slots.len());
    for slot in slots.iter() {
        let mut cache = slot.cache.clone();
        cache.remap(ops, &new_params)?;
        let logits = cache.last_logits(&new_params)?.into_vec();
        staged.push((cache, logits));
    }

    // 4. commit
    let params_before = params.num_scalars();
    for (slot, (cache, logits)) in slots.iter_mut().zip(staged) {
        slot.cache = cache;
        slot.logits = logits;
    }
    *params = new_params;

    Ok(SwapReport {
        ops: ops.len(),
        probe_delta,
        params_before,
        params_after: params.num_scalars(),
        remapped_sequences: slots.len(),
        swap_ms: timer.ms(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::expand::Init;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn probe(c: &ModelConfig, rows: usize) -> Vec<Vec<u32>> {
        let mut rng = Pcg32::seeded(6);
        (0..rows).map(|_| (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect()).collect()
    }

    #[test]
    fn swap_without_traffic_succeeds_and_reports() {
        let c = cfg();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let n0 = params.num_scalars();
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let report = hot_swap(
            &mut params,
            &mut [],
            &[GrowthOp::Mlp { p: 32 }],
            &mut Pcg32::seeded(7),
            &opts,
            &probe(&c, 2),
            1e-4,
        )
        .unwrap();
        assert_eq!(report.ops, 1);
        assert_eq!(report.remapped_sequences, 0);
        assert!(report.probe_delta <= 1e-4);
        assert_eq!(report.params_before, n0);
        assert_eq!(report.params_after, params.num_scalars());
        assert_eq!(params.config().mlp, 32);
        assert!(report.swap_ms >= 0.0);
    }

    #[test]
    fn empty_op_list_is_rejected() {
        let c = cfg();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let opts = ExpandOptions::default();
        assert!(hot_swap(
            &mut params,
            &mut [],
            &[],
            &mut Pcg32::seeded(7),
            &opts,
            &probe(&c, 1),
            1e-4
        )
        .is_err());
    }

    #[test]
    fn violating_surgery_is_rejected_and_params_kept() {
        let c = cfg();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let opts = ExpandOptions {
            init: Init::Normal(0.5),
            zero_constrained: false,
            ..Default::default()
        };
        let err = hot_swap(
            &mut params,
            &mut [],
            &[GrowthOp::Mlp { p: 32 }],
            &mut Pcg32::seeded(7),
            &opts,
            &probe(&c, 2),
            1e-4,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("rejected"), "{err}");
        assert_eq!(params.config(), &c);
    }
}
