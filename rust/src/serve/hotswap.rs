//! Function-preserving hot-swap (S15d): live-model surgery between ticks.
//!
//! The swap is the serving-side payoff of the paper: because every
//! expansion op is function-preserving, a grown model can replace its
//! smaller predecessor **under live traffic** with zero output drift —
//! in-flight generations continue as if nothing happened. The whole swap
//! speaks [`ExpansionPlan`], the same currency as the training boundary:
//!
//! 1. **Plan-gated surgery + probe** — [`ExpansionPlan::apply_probed`]
//!    stages the expanded parameters from a copy of the live store and
//!    verifies preservation on a held-out probe batch; a violating plan
//!    (e.g. built with constraint-breaking init, the paper's E6 ablation)
//!    is rejected with the live state untouched.
//! 2. **KV-cache remap** — every in-flight sequence's cache is staged
//!    through the same plan ([`StagedKv`]'s `Expandable::apply_plan`) and
//!    its pending logits recomputed from the remapped final hidden state.
//! 3. **Atomic commit** — params and caches swap together, only after
//!    every stage succeeded; a failure at any point leaves the engine
//!    serving the old model.
//!
//! The report carries the plan's *predicted* deltas next to the measured
//! outcome, so a drifting cost model is visible in serving logs.

use crate::error::{Error, Result};
use crate::expand::{Expandable, ExpandOptions, ExpansionPlan, StagedKv};
use crate::metrics::Timer;
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::serve::scheduler::{Slot, SlotCache};

/// Outcome of a committed hot-swap, predicted-vs-actual.
#[derive(Clone, Debug)]
pub struct SwapReport {
    /// Ops applied.
    pub ops: usize,
    /// `max|Δ logits|` on the probe batch (old vs expanded params).
    pub probe_delta: f32,
    pub params_before: usize,
    pub params_after: usize,
    /// The plan's predicted post-swap param count — equals `params_after`
    /// by the plan postcondition; reported so logs show the prediction
    /// held.
    pub params_predicted: usize,
    /// The plan's estimated per-token forward-FLOPs delta (an estimate,
    /// unlike the exact param delta — DESIGN.md §13).
    pub flops_delta_est: f64,
    /// In-flight KV caches remapped through the plan.
    pub remapped_sequences: usize,
    /// Wall time of surgery + probe + remap + commit.
    pub swap_ms: f64,
}

/// Grow `params` by `plan` under live traffic (see module docs). `probe`
/// rows must be full-`seq` token rows; `slots` are the in-flight sequences
/// whose caches ride through the swap.
pub(crate) fn hot_swap(
    params: &mut ParamStore,
    slots: &mut [Slot],
    plan: &ExpansionPlan,
    rng: &mut Pcg32,
    expand_opts: &ExpandOptions,
    probe: &[Vec<u32>],
    tol: f32,
) -> Result<SwapReport> {
    if plan.is_identity() {
        return Err(Error::Serve("hot-swap with an identity plan (no ops)".into()));
    }
    let timer = Timer::start();

    // 1. plan-gated surgery on a staged copy — the live store keeps
    //    serving until commit; the preservation probe is the plan's own
    let staged_params = plan
        .apply_probed(params, expand_opts, rng, probe, tol)
        .map_err(|e| Error::Serve(format!("hot-swap {e}")))?;

    // 2. remap every in-flight cache into a staged copy (commit is all-or-
    //    nothing: a half-remapped engine must be unreachable). Every storage
    //    tier rides the same plan seam: StagedKv is generic over the
    //    backend, and the remap reads the exact f32 stream buffers in all
    //    tiers, so lossy caches lose nothing extra at a swap.
    let mut staged: Vec<(SlotCache, Vec<f32>)> = Vec::with_capacity(slots.len());
    for slot in slots.iter() {
        let (cache, logits) = match &slot.cache {
            SlotCache::F32(c) => {
                let mut kv = StagedKv { cache: c.clone(), new_params: &staged_params.params };
                kv.apply_plan(plan, expand_opts, rng)?;
                let logits = kv.cache.last_logits(&staged_params.params)?.into_vec();
                (SlotCache::F32(kv.cache), logits)
            }
            SlotCache::F16(c) => {
                let mut kv = StagedKv { cache: c.clone(), new_params: &staged_params.params };
                kv.apply_plan(plan, expand_opts, rng)?;
                let logits = kv.cache.last_logits(&staged_params.params)?.into_vec();
                (SlotCache::F16(kv.cache), logits)
            }
            SlotCache::Quant(c) => {
                let mut kv = StagedKv { cache: c.clone(), new_params: &staged_params.params };
                kv.apply_plan(plan, expand_opts, rng)?;
                let logits = kv.cache.last_logits(&staged_params.params)?.into_vec();
                (SlotCache::Quant(kv.cache), logits)
            }
        };
        staged.push((cache, logits));
    }

    // 3. commit
    let params_before = params.num_scalars();
    for (slot, (cache, logits)) in slots.iter_mut().zip(staged) {
        slot.cache = cache;
        slot.logits = logits;
    }
    *params = staged_params.params;

    Ok(SwapReport {
        ops: plan.ops().len(),
        probe_delta: staged_params.probe_delta,
        params_before,
        params_after: params.num_scalars(),
        params_predicted: plan.params_after(),
        flops_delta_est: plan.flops_delta(),
        remapped_sequences: slots.len(),
        swap_ms: timer.ms(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, ModelConfig};
    use crate::expand::Init;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn probe(c: &ModelConfig, rows: usize) -> Vec<Vec<u32>> {
        let mut rng = Pcg32::seeded(6);
        (0..rows).map(|_| (0..c.seq).map(|_| rng.below(c.vocab) as u32).collect()).collect()
    }

    #[test]
    fn swap_without_traffic_succeeds_and_reports_predictions() {
        let c = cfg();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let n0 = params.num_scalars();
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let plan = ExpansionPlan::new(&c, vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        let report =
            hot_swap(&mut params, &mut [], &plan, &mut Pcg32::seeded(7), &opts, &probe(&c, 2), 1e-4)
                .unwrap();
        assert_eq!(report.ops, 1);
        assert_eq!(report.remapped_sequences, 0);
        assert!(report.probe_delta <= 1e-4);
        assert_eq!(report.params_before, n0);
        assert_eq!(report.params_after, params.num_scalars());
        assert_eq!(report.params_predicted, report.params_after, "plan prediction must hold");
        assert!(report.flops_delta_est > 0.0);
        assert_eq!(params.config().mlp, 32);
        assert!(report.swap_ms >= 0.0);
    }

    #[test]
    fn identity_plan_is_rejected() {
        let c = cfg();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let opts = ExpandOptions::default();
        let plan = ExpansionPlan::identity(&c);
        assert!(hot_swap(
            &mut params,
            &mut [],
            &plan,
            &mut Pcg32::seeded(7),
            &opts,
            &probe(&c, 1),
            1e-4
        )
        .is_err());
    }

    #[test]
    fn violating_surgery_is_rejected_and_params_kept() {
        let c = cfg();
        let mut params = ParamStore::init(&c, &mut Pcg32::seeded(5), 0.05);
        let opts = ExpandOptions {
            init: Init::Normal(0.5),
            zero_constrained: false,
            ..Default::default()
        };
        let plan = ExpansionPlan::new(&c, vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        let err = hot_swap(
            &mut params,
            &mut [],
            &plan,
            &mut Pcg32::seeded(7),
            &opts,
            &probe(&c, 2),
            1e-4,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("rejected"), "{err}");
        assert_eq!(params.config(), &c);
    }
}
