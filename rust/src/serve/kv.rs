//! Per-sequence KV cache for incremental decode (S15a), remappable through
//! the paper's expansion ops, with pluggable K/V storage.
//!
//! For each transformer layer the cache holds (a) the layer's **pre-norm
//! residual-stream input rows** `[t, h]` (plus one extra buffer for the
//! final hidden state feeding `w_out`) and (b) each head's projected K/V
//! rows `[t, k]` / `[t, v]`. The K/V buffers make a decode step cost one
//! position of attention instead of a full re-forward; the input buffers
//! are what make **hot-swap** possible: every cached K/V row is a pure
//! function of the layer input and the live `W^K`/`W^V`, so after
//! parameter surgery ([`KvCacheImpl::remap`]) the projections are
//! *recomputed* from the structurally-remapped inputs instead of being
//! rebuilt from the token history with a full re-forward.
//!
//! The structural remap leans on the residual-stream invariants of the
//! preservation theorems (argument in DESIGN.md §9.3):
//!
//! * `mlp` / `heads_add` / `heads_expand` / `attn_expand` leave every
//!   residual-stream value bit-identical → inputs unchanged;
//! * `hidden` extends the residual stream with **exact zeros** (embed/pos/
//!   `W^O`/`W2`/`b2` extensions are all zero) → append zero columns;
//! * `layers_add` inserts identity layers (`I_n + 0`) → insert *copies* of
//!   the stream value at the insertion point.
//!
//! Numerics: `attend` replicates [`crate::model::attention`]'s operation
//! order exactly (ascending-k dot, scale, the *same* online-softmax row
//! pass — [`crate::tensor::softmax_row_online`] — and a weighted V sum
//! with the same zero-skip and ascending order as `attn_pv`), so with the
//! exact f32 storage incremental logits are bit-identical to the matching
//! [`crate::model::forward_one`] row — see the cross-check test in
//! `model.rs`.
//!
//! # Storage tiers ([`KvStorage`])
//!
//! The per-head K/V buffers are generic over a storage backend:
//!
//! * [`GrowBuf`] (→ [`KvCache`]) — exact f32 rows; every bit-identity
//!   guarantee above holds.
//! * [`QuantBuf`] (→ [`QuantKvCache`]) — i8 values with one f32 scale per
//!   [`QUANT_BLOCK`]-column block: `scale = max|block| / 127`,
//!   `q = round(x / scale)` clamped to `[-127, 127]`, dequantized as
//!   `q · scale` (all-zero blocks store `scale = 0` and skip on read).
//!   Per-element round-trip error is ≤ `scale/2 = max|block|/254` (the
//!   property test below bounds it at `0.501 · scale` to absorb fp
//!   rounding), and resident K/V bytes drop from `4` to
//!   `1 + 4/QUANT_BLOCK = 1.125` per scalar — **3.56×** smaller at
//!   realistic head dims. Decode logits drift by a bounded amount instead
//!   of being bit-identical (DESIGN.md §17 documents the bound and the
//!   serve-side tolerance argument).
//!
//! The residual-stream (`xs`) buffers stay exact f32 in *both* tiers, on
//! purpose: they are what the structural remap and [`KvCacheImpl::
//! last_logits`] read, so hot-swap remaps and post-swap logit refreshes
//! lose nothing to quantization — phase 2 of `remap` rebuilds K/V from
//! the exact stream and *re*-quantizes, which keeps quantization error
//! from compounding across swaps.

use crate::config::{GrowthOp, LayerPosition, ModelConfig};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::tensor::Tensor;

/// Pluggable K/V row storage: append-only `[rows, cols]` matrices that can
/// be dotted against a query and accumulated into an output row without
/// the caller knowing the representation. The two read primitives keep
/// per-element operations in ascending index order with an
/// exact-zero skip, so swapping backends never changes *operation order*
/// — only (for lossy backends) the stored values themselves.
pub trait KvStorage: Clone + std::fmt::Debug + Send {
    /// Empty storage for rows of width `cols`.
    fn new(cols: usize) -> Self;
    /// Encode every row of a `[rows, cols]` tensor (row-at-a-time, exactly
    /// as repeated [`KvStorage::push_row`] calls would).
    fn from_tensor(t: &Tensor) -> Self;
    /// Logical row width.
    fn cols(&self) -> usize;
    /// Number of stored rows.
    fn rows(&self) -> usize;
    /// Append one row (encoding it for lossy backends).
    fn push_row(&mut self, row: &[f32]);
    /// Dot product of stored row `i` with `q` (ascending-index adds).
    fn dot(&self, i: usize, q: &[f32]) -> f32;
    /// `out[c] += w * row_i[c]` for every column (ascending order).
    fn add_scaled(&self, i: usize, w: f32, out: &mut [f32]);
    /// Decoded copy of row `i` (dequantized for lossy backends).
    fn row_f32(&self, i: usize) -> Vec<f32>;
    /// Bytes resident for the stored rows (values + any scales).
    fn resident_bytes(&self) -> usize;
}

/// Append-only row buffer: a `[rows, cols]` f32 matrix grown one row at a
/// time (no per-step reallocation of the whole matrix). The exact storage
/// backend, and always the representation of the residual-stream buffers.
#[derive(Clone, Debug)]
pub struct GrowBuf {
    cols: usize,
    data: Vec<f32>,
}

impl GrowBuf {
    pub(crate) fn rows(&self) -> usize {
        if self.cols == 0 { 0 } else { self.data.len() / self.cols }
    }

    pub(crate) fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
    }

    /// Materialize as a `[rows, cols]` tensor (copies).
    fn as_tensor(&self) -> Tensor {
        Tensor::from_vec(&[self.rows(), self.cols], self.data.clone())
            .expect("GrowBuf invariant: data.len() == rows*cols")
    }

    /// Widen every row by `extra` zero columns (hidden-expansion remap).
    fn append_zero_cols(&mut self, extra: usize) {
        let rows = self.rows();
        let new_cols = self.cols + extra;
        let mut data = Vec::with_capacity(rows * new_cols);
        for i in 0..rows {
            data.extend_from_slice(self.row(i));
            data.extend(std::iter::repeat(0.0f32).take(extra));
        }
        self.cols = new_cols;
        self.data = data;
    }
}

impl KvStorage for GrowBuf {
    fn new(cols: usize) -> GrowBuf {
        GrowBuf { cols, data: Vec::new() }
    }

    fn from_tensor(t: &Tensor) -> GrowBuf {
        GrowBuf { cols: t.cols(), data: t.data().to_vec() }
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn rows(&self) -> usize {
        self.rows()
    }

    fn push_row(&mut self, row: &[f32]) {
        self.push_row(row);
    }

    fn dot(&self, i: usize, q: &[f32]) -> f32 {
        let krow = self.row(i);
        let mut acc = 0.0f32;
        for kk in 0..krow.len() {
            acc += q[kk] * krow[kk];
        }
        acc
    }

    fn add_scaled(&self, i: usize, w: f32, out: &mut [f32]) {
        let vrow = self.row(i);
        for c in 0..vrow.len() {
            out[c] += w * vrow[c];
        }
    }

    fn row_f32(&self, i: usize) -> Vec<f32> {
        self.row(i).to_vec()
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Columns per quantization block (one f32 scale amortized over this many
/// i8 values: 1.125 bytes/scalar vs f32's 4).
pub const QUANT_BLOCK: usize = 32;

/// Block-quantized i8 storage: per row, columns are split into
/// [`QUANT_BLOCK`]-wide blocks, each with its own f32 scale (see the
/// module docs for the format and error bound).
#[derive(Clone, Debug)]
pub struct QuantBuf {
    cols: usize,
    /// Scales per row: `ceil(cols / QUANT_BLOCK)`.
    blocks_per_row: usize,
    /// `rows * cols` quantized values, row-major.
    data: Vec<i8>,
    /// `rows * blocks_per_row` scales, row-major.
    scales: Vec<f32>,
}

impl KvStorage for QuantBuf {
    fn new(cols: usize) -> QuantBuf {
        QuantBuf { cols, blocks_per_row: cols.div_ceil(QUANT_BLOCK), data: Vec::new(), scales: Vec::new() }
    }

    fn from_tensor(t: &Tensor) -> QuantBuf {
        let mut out = <QuantBuf as KvStorage>::new(t.cols());
        for i in 0..t.rows() {
            out.push_row(t.row(i));
        }
        out
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn rows(&self) -> usize {
        if self.cols == 0 { 0 } else { self.data.len() / self.cols }
    }

    fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols);
        for block in row.chunks(QUANT_BLOCK) {
            let amax = block.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let scale = amax / 127.0;
            self.scales.push(scale);
            if scale == 0.0 {
                // all-zero block (or denormal amax underflowing the scale):
                // store zeros; reads skip the block entirely
                self.data.resize(self.data.len() + block.len(), 0);
            } else {
                for &x in block {
                    self.data.push((x / scale).round().clamp(-127.0, 127.0) as i8);
                }
            }
        }
    }

    fn dot(&self, i: usize, q: &[f32]) -> f32 {
        let row = &self.data[i * self.cols..(i + 1) * self.cols];
        let srow = &self.scales[i * self.blocks_per_row..(i + 1) * self.blocks_per_row];
        let mut acc = 0.0f32;
        for (b, block) in row.chunks(QUANT_BLOCK).enumerate() {
            let scale = srow[b];
            if scale == 0.0 {
                continue;
            }
            let base = b * QUANT_BLOCK;
            for (kk, &qv) in block.iter().enumerate() {
                acc += q[base + kk] * (f32::from(qv) * scale);
            }
        }
        acc
    }

    fn add_scaled(&self, i: usize, w: f32, out: &mut [f32]) {
        let row = &self.data[i * self.cols..(i + 1) * self.cols];
        let srow = &self.scales[i * self.blocks_per_row..(i + 1) * self.blocks_per_row];
        for (b, block) in row.chunks(QUANT_BLOCK).enumerate() {
            let scale = srow[b];
            if scale == 0.0 {
                continue;
            }
            let base = b * QUANT_BLOCK;
            for (c, &qv) in block.iter().enumerate() {
                out[base + c] += w * (f32::from(qv) * scale);
            }
        }
    }

    fn row_f32(&self, i: usize) -> Vec<f32> {
        let row = &self.data[i * self.cols..(i + 1) * self.cols];
        let srow = &self.scales[i * self.blocks_per_row..(i + 1) * self.blocks_per_row];
        let mut out = vec![0.0f32; self.cols];
        for (b, block) in row.chunks(QUANT_BLOCK).enumerate() {
            let scale = srow[b];
            let base = b * QUANT_BLOCK;
            for (c, &qv) in block.iter().enumerate() {
                out[base + c] = f32::from(qv) * scale;
            }
        }
        out
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<i8>() + self.scales.len() * std::mem::size_of::<f32>()
    }
}

/// Encode an f32 as IEEE 754 binary16 bits (round-to-nearest-even;
/// overflow saturates to ±Inf, underflow flushes through the subnormal
/// range to ±0). Hand-rolled: the offline crate set has no `half`.
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp8 = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp8 == 255 {
        // Inf / NaN (NaN keeps a nonzero mantissa)
        let m = if mant == 0 { 0 } else { 0x200 | ((mant >> 13) as u16) };
        return sign | 0x7c00 | m;
    }
    let exp = exp8 - 127 + 15;
    if exp >= 31 {
        return sign | 0x7c00; // overflow → ±Inf
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // below the smallest subnormal → ±0
        }
        // subnormal: shift the (implicit-1) mantissa into place, round RNE
        let m = mant | 0x0080_0000;
        let shift = (14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let mut out = (m >> shift) as u16;
        let rem = m & ((1u32 << shift) - 1);
        if rem > half || (rem == half && (out & 1) == 1) {
            out += 1;
        }
        return sign | out;
    }
    let mut out = (((exp as u32) << 10) as u16) | ((mant >> 13) as u16);
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out = out.wrapping_add(1); // mantissa carry rolls into the exponent correctly
    }
    sign | out
}

/// Decode IEEE 754 binary16 bits to f32 (exact: every f16 value is
/// representable in f32).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = (u32::from(h) & 0x8000) << 16;
    let exp = u32::from((h >> 10) & 0x1f);
    let mant = u32::from(h & 0x3ff);
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: renormalize into f32's ample exponent range
            let mut e = 113u32; // 127 - 15 + 1
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Half-precision storage: each K/V scalar kept as IEEE binary16 — the
/// 2-byte middle tier between exact f32 (4 bytes) and block-int8 (1.125
/// bytes). Per-element relative error ≤ 2⁻¹¹ in the normal range, with
/// no block structure and no scales to amortize, so the 2× saving holds
/// at any row width (int8's 3.56× ceiling needs wide rows).
#[derive(Clone, Debug)]
pub struct F16Buf {
    cols: usize,
    data: Vec<u16>,
}

impl KvStorage for F16Buf {
    fn new(cols: usize) -> F16Buf {
        F16Buf { cols, data: Vec::new() }
    }

    fn from_tensor(t: &Tensor) -> F16Buf {
        let mut out = <F16Buf as KvStorage>::new(t.cols());
        for i in 0..t.rows() {
            out.push_row(t.row(i));
        }
        out
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn rows(&self) -> usize {
        if self.cols == 0 { 0 } else { self.data.len() / self.cols }
    }

    fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols);
        for &x in row {
            self.data.push(f32_to_f16_bits(x));
        }
    }

    fn dot(&self, i: usize, q: &[f32]) -> f32 {
        let row = &self.data[i * self.cols..(i + 1) * self.cols];
        let mut acc = 0.0f32;
        for (kk, &h) in row.iter().enumerate() {
            acc += q[kk] * f16_bits_to_f32(h);
        }
        acc
    }

    fn add_scaled(&self, i: usize, w: f32, out: &mut [f32]) {
        let row = &self.data[i * self.cols..(i + 1) * self.cols];
        for (c, &h) in row.iter().enumerate() {
            out[c] += w * f16_bits_to_f32(h);
        }
    }

    fn row_f32(&self, i: usize) -> Vec<f32> {
        self.data[i * self.cols..(i + 1) * self.cols].iter().map(|&h| f16_bits_to_f32(h)).collect()
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<u16>()
    }
}

/// Which storage backend in-flight K/V caches use (`serve --kv-quant=TIER`;
/// [`crate::serve::EngineOptions::kv_tier`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvTier {
    /// Exact f32 ([`KvCache`]) — every bit-identity guarantee holds.
    #[default]
    F32,
    /// IEEE binary16 ([`F16KvCache`]) — 2× fewer resident bytes, ≤ 2⁻¹¹
    /// relative per-element error.
    F16,
    /// Block-quantized i8 ([`QuantKvCache`]) — ~3.6× fewer resident
    /// bytes, drift bounded per DESIGN.md §17.
    Int8,
}

impl KvTier {
    /// Parse the `--kv-quant` tier value.
    pub fn parse(s: &str) -> crate::error::Result<KvTier> {
        match s {
            "f32" => Ok(KvTier::F32),
            "f16" => Ok(KvTier::F16),
            "int8" => Ok(KvTier::Int8),
            other => Err(crate::error::Error::Cli(format!(
                "unknown KV tier '{other}' (f32|f16|int8)"
            ))),
        }
    }

    /// Human-readable tier name (CLI summaries, bench rows).
    pub fn label(self) -> &'static str {
        match self {
            KvTier::F32 => "f32",
            KvTier::F16 => "f16",
            KvTier::Int8 => "int8",
        }
    }
}

/// KV + residual-stream cache for one in-flight sequence, generic over
/// the K/V storage backend (see the module docs; [`KvCache`] and
/// [`QuantKvCache`] are the two instantiations).
#[derive(Clone, Debug)]
pub struct KvCacheImpl<S: KvStorage> {
    cfg: ModelConfig,
    /// `xs[n]` = pre-norm input rows of layer `n`; `xs[layers]` = the final
    /// hidden state (input to `w_out`). Always exact f32.
    xs: Vec<GrowBuf>,
    /// `heads[n][e]` = (K rows, V rows) for layer `n`, head `e`.
    heads: Vec<Vec<(S, S)>>,
    len: usize,
}

/// Exact f32 cache — every decode bit-identity guarantee holds.
pub type KvCache = KvCacheImpl<GrowBuf>;

/// Block-quantized i8 cache — ~3.6× smaller resident K/V bytes, decode
/// drift bounded as documented (DESIGN.md §17).
pub type QuantKvCache = KvCacheImpl<QuantBuf>;

/// Half-precision cache — exactly 2× smaller resident K/V bytes at
/// ≤ 2⁻¹¹ relative per-element error (the f32/int8 middle tier).
pub type F16KvCache = KvCacheImpl<F16Buf>;

impl<S: KvStorage> KvCacheImpl<S> {
    /// Empty cache for one sequence under `cfg`.
    pub fn new(cfg: &ModelConfig) -> KvCacheImpl<S> {
        let xs = (0..=cfg.layers).map(|_| <GrowBuf as KvStorage>::new(cfg.hidden)).collect();
        let heads = (0..cfg.layers)
            .map(|_| (0..cfg.heads).map(|_| (S::new(cfg.k), S::new(cfg.v))).collect())
            .collect();
        KvCacheImpl { cfg: *cfg, xs, heads, len: 0 }
    }

    /// Number of cached positions (== the next token's position index).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The architecture this cache is laid out for.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Drop all cached positions, keeping the layout (window re-prime).
    pub fn reset(&mut self) {
        *self = KvCacheImpl::new(&self.cfg);
    }

    /// Total cached scalars (capacity accounting / tests) — the *logical*
    /// element count, independent of the storage representation.
    pub fn num_cached_scalars(&self) -> usize {
        self.xs.iter().map(|b| b.rows() * KvStorage::cols(b)).sum::<usize>()
            + self
                .heads
                .iter()
                .flatten()
                .map(|(k, v)| k.rows() * k.cols() + v.rows() * v.cols())
                .sum::<usize>()
    }

    /// Resident bytes of the K/V storage proper — the quantity `--kv-quant`
    /// shrinks. The exact-f32 residual-stream buffers are excluded: they
    /// back remap/`last_logits` exactness and are identical across tiers.
    pub fn kv_resident_bytes(&self) -> usize {
        self.heads
            .iter()
            .flatten()
            .map(|(k, v)| k.resident_bytes() + v.resident_bytes())
            .sum()
    }

    // ---- incremental-forward hooks (crate-internal; see model.rs) ---------

    pub(crate) fn push_x(&mut self, layer: usize, row: &[f32]) {
        self.xs[layer].push_row(row);
    }

    pub(crate) fn push_kv(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        let (kb, vb) = &mut self.heads[layer][head];
        kb.push_row(k);
        vb.push_row(v);
    }

    /// Mark one full token as cached (called once per incremental forward).
    pub(crate) fn bump(&mut self) {
        self.len += 1;
    }

    /// Causal attention of one query row over every cached position of
    /// `(layer, head)`, replicating `model::attention`'s op order exactly:
    /// ascending-k dots, the shared online-softmax row pass, and the same
    /// zero-skipping ascending weighted V sum as `attn_pv`.
    pub(crate) fn attend(&self, layer: usize, head: usize, q: &[f32]) -> Vec<f32> {
        let (kb, vb) = &self.heads[layer][head];
        let t = kb.rows();
        debug_assert!(t > 0, "attend on empty cache");
        let scale = 1.0 / (kb.cols() as f32).sqrt();
        // scores = (q · K^T) * 1/sqrt(k)
        let mut scores = Vec::with_capacity(t);
        for j in 0..t {
            scores.push(kb.dot(j, q) * scale);
        }
        // same row pass as tensor::softmax_rows_online — a full-tile row's
        // masked suffix is a bitwise no-op there, so both paths agree
        crate::tensor::softmax_row_online(&mut scores);
        // weighted V sum (same ascending order + zero-skip as attn_pv)
        let mut out = vec![0.0f32; vb.cols()];
        for (j, &w) in scores.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            vb.add_scaled(j, w, &mut out);
        }
        out
    }

    /// Logits of the most recently cached position, recomputed from the
    /// cached final hidden state (used to refresh a sequence's pending
    /// logits after a hot-swap). Exact in both storage tiers: the final
    /// hidden state lives in the f32 stream buffers.
    pub fn last_logits(&self, params: &ParamStore) -> Result<Tensor> {
        if self.len == 0 {
            return Err(Error::Serve("last_logits on an empty cache".into()));
        }
        let last = Tensor::from_vec(&[1, self.cfg.hidden], self.xs[self.cfg.layers].row(self.len - 1).to_vec())?;
        last.matmul(params.get("w_out")?)
    }

    // ---- hot-swap remap ----------------------------------------------------

    /// Remap the cache through an expansion-op sequence so that decoding
    /// continues under `new_params` as if the whole history had been fed to
    /// the expanded model. Crate-internal mechanism: the public entry is
    /// [`crate::expand::StagedKv`]'s `Expandable::apply_plan`.
    ///
    /// Two phases: (1) structural remap of the residual-stream buffers
    /// (zero-column extension under `hidden`, copy insertion under
    /// `layers_add`); (2) rebuild of every head's K/V from the remapped
    /// inputs and the *new* projection weights — which also covers new
    /// heads, widened K/V dims and the `sqrt(k̂/k)` key rescaling without
    /// op-specific K/V surgery. Exactness argument: DESIGN.md §9.3. For
    /// quantized storage, phase 2 re-encodes from the exact f32 stream, so
    /// quantization error never compounds across swaps, and the re-encoded
    /// rows are bitwise what a fresh quantized prime under `new_params`
    /// would store (the per-row math is identical).
    pub(crate) fn remap(&mut self, ops: &[GrowthOp], new_params: &ParamStore) -> Result<()> {
        let mut cfg = self.cfg;
        for op in ops {
            let next = op
                .apply_to_config(&cfg)
                .map_err(|e| Error::Serve(format!("kv remap: {e}")))?;
            match *op {
                GrowthOp::Hidden { h } => {
                    let extra = h - cfg.hidden;
                    for x in &mut self.xs {
                        x.append_zero_cols(extra);
                    }
                }
                GrowthOp::LayersAdd { count, position } => {
                    let pos = match position {
                        LayerPosition::Top => cfg.layers,
                        LayerPosition::Bottom => 0,
                        LayerPosition::At(p) => p,
                    };
                    // an inserted identity layer sees — and passes through —
                    // the stream value at its position
                    for _ in 0..count {
                        let copy = self.xs[pos].clone();
                        self.xs.insert(pos, copy);
                    }
                }
                // mlp / heads_add / heads_expand / attn_expand leave the
                // residual stream untouched
                _ => {}
            }
            cfg = next;
        }
        if &cfg != new_params.config() {
            return Err(Error::Serve(format!(
                "kv remap: ops produce {:?} but new params are {:?}",
                cfg,
                new_params.config()
            )));
        }

        // phase 2: rebuild K/V from remapped inputs + new weights
        let mut heads = Vec::with_capacity(cfg.layers);
        for n in 0..cfg.layers {
            let x = self.xs[n].as_tensor();
            let nrm = crate::model::rmsnorm(&x, new_params.get(&format!("layer_{n}.g_mha"))?)?;
            let mut layer_heads = Vec::with_capacity(cfg.heads);
            for e in 0..cfg.heads {
                let k = nrm.matmul(new_params.get(&format!("layer_{n}.head_{e}.wk"))?)?;
                let v = nrm.matmul(new_params.get(&format!("layer_{n}.head_{e}.wv"))?)?;
                layer_heads.push((S::from_tensor(&k), S::from_tensor(&v)));
            }
            heads.push(layer_heads);
        }
        self.heads = heads;
        self.cfg = cfg;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expand::{Expandable, ExpandOptions, ExpansionPlan, Init, StagedKv};
    use crate::model::{forward_incremental, forward_one};
    use crate::prop::Runner;
    use crate::rng::Pcg32;

    /// Remap `cache` through `ops` via the plan seam (the only entry).
    fn remap_via_plan<S: KvStorage>(
        cache: &mut KvCacheImpl<S>,
        ops: &[GrowthOp],
        new_params: &ParamStore,
    ) -> Result<()> {
        let plan = ExpansionPlan::new(cache.config(), ops.to_vec())
            .map_err(|e| Error::Serve(format!("kv remap: {e}")))?;
        let mut staged = StagedKv { cache: cache.clone(), new_params };
        staged.apply_plan(&plan, &ExpandOptions::default(), &mut Pcg32::seeded(0))?;
        *cache = staged.cache;
        Ok(())
    }

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
    }

    /// Like [`cfg`] but with head dims wide enough that the 4-byte
    /// per-block scale overhead amortizes (the quant memory-ratio tests).
    fn wide_cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 16, v: 16, mlp: 32, seq: 16, vocab: 32 }
    }

    fn feed<S: KvStorage>(cache: &mut KvCacheImpl<S>, params: &ParamStore, tokens: &[u32]) -> Tensor {
        let cfg = *cache.config();
        let mut logits = None;
        for &t in tokens {
            logits = Some(forward_incremental(&cfg, params, cache, t).unwrap());
        }
        logits.expect("at least one token")
    }

    #[test]
    fn cache_grows_and_resets() {
        let c = cfg();
        let mut rng = Pcg32::seeded(3);
        let params = ParamStore::init(&c, &mut rng, 0.02);
        let mut cache = KvCache::new(&c);
        assert!(cache.is_empty());
        feed(&mut cache, &params, &[1, 2, 3]);
        assert_eq!(cache.len(), 3);
        // xs: (layers+1) buffers of [3, h]; heads: layers*heads*(K+V)
        let expect = (c.layers + 1) * 3 * c.hidden + c.layers * c.heads * 3 * (c.k + c.v);
        assert_eq!(cache.num_cached_scalars(), expect);
        cache.reset();
        assert!(cache.is_empty());
        assert_eq!(cache.num_cached_scalars(), 0);
    }

    #[test]
    fn last_logits_matches_incremental_output() {
        let c = cfg();
        let mut rng = Pcg32::seeded(4);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let mut cache = KvCache::new(&c);
        let logits = feed(&mut cache, &params, &[5, 6, 7, 8]);
        let again = cache.last_logits(&params).unwrap();
        assert_eq!(again, logits);
        assert!(KvCache::new(&c).last_logits(&params).is_err());
    }

    /// The central hot-swap property: remap(ops) then decode ≡ feeding the
    /// whole history to the expanded model from scratch.
    #[test]
    fn remap_agrees_with_fresh_prime_under_new_params() {
        use crate::config::GrowthOp::*;
        let c = cfg();
        let cases: Vec<(&str, Vec<GrowthOp>)> = vec![
            ("mlp", vec![Mlp { p: 64 }]),
            ("heads_add", vec![HeadsAdd { count: 2 }]),
            ("heads_expand", vec![HeadsExpand { v: 16 }]),
            ("attn_expand", vec![AttnExpand { k: 16 }]),
            ("hidden", vec![Hidden { h: 24 }]),
            ("layers_top", vec![LayersAdd { count: 1, position: LayerPosition::Top }]),
            ("layers_bottom", vec![LayersAdd { count: 2, position: LayerPosition::Bottom }]),
            ("layers_mid", vec![LayersAdd { count: 1, position: LayerPosition::At(1) }]),
            (
                "composed",
                vec![
                    Mlp { p: 64 },
                    HeadsAdd { count: 1 },
                    AttnExpand { k: 16 },
                    Hidden { h: 24 },
                    LayersAdd { count: 1, position: LayerPosition::Top },
                ],
            ),
        ];
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        for (name, ops) in cases {
            let mut rng = Pcg32::seeded(11);
            let params = ParamStore::init(&c, &mut rng, 0.05);
            let history: Vec<u32> = (0..6).map(|_| rng.below(c.vocab) as u32).collect();
            let new_params = ExpansionPlan::new(&c, ops.clone())
                .unwrap()
                .materialize(&params, &opts, &mut rng)
                .unwrap();

            // path A: prime under old params, remap, feed one more token
            let mut remapped = KvCache::new(&c);
            feed(&mut remapped, &params, &history);
            remap_via_plan(&mut remapped, &ops, &new_params).unwrap();
            let next = 9u32;
            let a = forward_incremental(new_params.config(), &new_params, &mut remapped, next).unwrap();

            // path B: feed the full history + token to the expanded model
            let mut fresh = KvCache::new(new_params.config());
            feed(&mut fresh, &new_params, &history);
            let b = forward_incremental(new_params.config(), &new_params, &mut fresh, next).unwrap();

            let delta = a.max_abs_diff(&b).unwrap();
            assert!(delta <= 1e-4, "{name}: remap vs fresh prime max|Δ| = {delta}");
            assert_eq!(remapped.len(), fresh.len(), "{name}");
            assert_eq!(remapped.config(), new_params.config(), "{name}");
        }
    }

    /// For ops that do not touch attention inputs, the remap is not just
    /// within tolerance but *bit-identical* to a fresh prime.
    #[test]
    fn remap_is_bitexact_for_stream_preserving_ops() {
        use crate::config::GrowthOp::*;
        let c = cfg();
        let ops = vec![
            Mlp { p: 64 },
            HeadsAdd { count: 1 },
            HeadsExpand { v: 16 },
            LayersAdd { count: 1, position: LayerPosition::At(1) },
        ];
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let mut rng = Pcg32::seeded(13);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..5).map(|_| rng.below(c.vocab) as u32).collect();
        let new_params = ExpansionPlan::new(&c, ops.clone())
            .unwrap()
            .materialize(&params, &opts, &mut rng)
            .unwrap();

        let mut remapped = KvCache::new(&c);
        feed(&mut remapped, &params, &history);
        remap_via_plan(&mut remapped, &ops, &new_params).unwrap();
        let a = forward_incremental(new_params.config(), &new_params, &mut remapped, 3).unwrap();

        let mut window: Vec<u32> = history.clone();
        window.push(3);
        window.resize(new_params.config().seq, 0);
        let full = forward_one(new_params.config(), &new_params, &window).unwrap();
        let row = full.slice_rows(history.len(), history.len() + 1).unwrap();
        assert_eq!(a, row, "stream-preserving remap must be bit-identical to the full forward");
    }

    #[test]
    fn remap_rejects_mismatched_params() {
        let c = cfg();
        let mut rng = Pcg32::seeded(17);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let mut cache = KvCache::new(&c);
        feed(&mut cache, &params, &[1, 2]);
        // ops say mlp=64 but hand the cache the *old* params
        let ops = vec![GrowthOp::Mlp { p: 64 }];
        let err = remap_via_plan(&mut cache, &ops, &params).unwrap_err().to_string();
        assert!(err.contains("kv remap"), "{err}");
    }

    // ---- quantized storage -------------------------------------------------

    #[test]
    fn quant_roundtrip_error_is_bounded() {
        // per element: |x − dequant(x)| ≤ scale/2 where scale = max|block|/127
        // (0.501 absorbs the fp rounding in the encode/decode arithmetic);
        // random shapes AND random magnitude scales, via the prop harness
        Runner::new("quant-kv-roundtrip", 64).run_sized(
            &mut |rng| {
                let rows = 1 + rng.below(5);
                let cols = 1 + rng.below(80); // crosses the QUANT_BLOCK=32 boundary
                let mag = match rng.below(5) {
                    0 => 1e-3,
                    1 => 0.05,
                    2 => 1.0,
                    3 => 40.0,
                    _ => 1e4,
                };
                let mut t = Tensor::zeros(&[rows, cols]);
                rng.fill_normal(t.data_mut(), mag);
                if rng.below(4) == 0 {
                    // an all-zero row exercises the scale == 0 skip path
                    for x in t.row_mut(0) {
                        *x = 0.0;
                    }
                }
                t
            },
            |t| t.numel(),
            &mut |t| {
                let qb = <QuantBuf as KvStorage>::from_tensor(t);
                if qb.rows() != t.rows() || qb.cols() != t.cols() {
                    return Err("shape mismatch after encode".into());
                }
                for i in 0..t.rows() {
                    let back = qb.row_f32(i);
                    let row = t.row(i);
                    for (b, block) in row.chunks(QUANT_BLOCK).enumerate() {
                        let amax = block.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                        let bound = 0.501 * (amax / 127.0);
                        for (c, &x) in block.iter().enumerate() {
                            let y = back[b * QUANT_BLOCK + c];
                            if (x - y).abs() > bound {
                                return Err(format!(
                                    "row {i} col {} : |{x} - {y}| > {bound}",
                                    b * QUANT_BLOCK + c
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quant_dot_and_add_scaled_match_dequantized_rows() {
        // the read primitives must be plain f32 math over the *dequantized*
        // values, in the same ascending order as GrowBuf — so a GrowBuf
        // built from row_f32 copies reproduces them bit for bit
        let mut rng = Pcg32::seeded(21);
        let t = Tensor::randn(&[4, 40], &mut rng, 0.7);
        let qb = <QuantBuf as KvStorage>::from_tensor(&t);
        let mut deq = <GrowBuf as KvStorage>::new(40);
        for i in 0..4 {
            KvStorage::push_row(&mut deq, &qb.row_f32(i));
        }
        let q: Vec<f32> = (0..40).map(|_| rng.normal_f32(1.0)).collect();
        for i in 0..4 {
            assert_eq!(qb.dot(i, &q).to_bits(), KvStorage::dot(&deq, i, &q).to_bits(), "dot row {i}");
            let mut a = vec![0.125f32; 40];
            let mut b = a.clone();
            qb.add_scaled(i, 0.35, &mut a);
            KvStorage::add_scaled(&deq, i, 0.35, &mut b);
            assert_eq!(a, b, "add_scaled row {i}");
        }
    }

    #[test]
    fn quant_cache_cuts_resident_kv_bytes_severalfold() {
        let c = wide_cfg();
        let mut rng = Pcg32::seeded(23);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..8).map(|_| rng.below(c.vocab) as u32).collect();
        let mut exact = KvCache::new(&c);
        feed(&mut exact, &params, &history);
        let mut quant = QuantKvCache::new(&c);
        feed(&mut quant, &params, &history);
        let (fb, qb) = (exact.kv_resident_bytes(), quant.kv_resident_bytes());
        assert!(fb > 0 && qb > 0);
        let ratio = fb as f64 / qb as f64;
        // 1.125 bytes/scalar vs 4 at k = v = 16 ⇒ 3.2×; wider dims approach
        // the 3.56× format ceiling
        assert!(ratio >= 3.0, "resident KV ratio {ratio} below the ≥3× claim");
        // logical contents account identically
        assert_eq!(exact.num_cached_scalars(), quant.num_cached_scalars());
    }

    #[test]
    fn quant_decode_tracks_f32_within_documented_bound() {
        // teacher-forced decode of the same history through both tiers:
        // per-step logits within the DESIGN.md §17 serve drift bound, and
        // greedy argmax only ever differs on a within-drift near-tie
        let c = wide_cfg();
        let mut rng = Pcg32::seeded(29);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..10).map(|_| rng.below(c.vocab) as u32).collect();
        let mut exact = KvCache::new(&c);
        let mut quant = QuantKvCache::new(&c);
        let argmax = |t: &Tensor| -> usize {
            t.row(0)
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        for (step, &tok) in history.iter().enumerate() {
            let a = forward_incremental(&c, &params, &mut exact, tok).unwrap();
            let b = forward_incremental(&c, &params, &mut quant, tok).unwrap();
            let d = a.max_abs_diff(&b).unwrap();
            assert!(d <= 5e-2, "step {step}: quant logit drift {d} above bound");
            let (am, bm) = (argmax(&a), argmax(&b));
            if am != bm {
                let gap = a.row(0)[am] - a.row(0)[bm];
                assert!(
                    gap <= 2.0 * d,
                    "step {step}: greedy flip on a non-tie (gap {gap}, drift {d})"
                );
            }
        }
    }

    #[test]
    fn quant_remap_is_bitexact_vs_fresh_quant_prime_for_stream_preserving_ops() {
        // stream-preserving ops keep the f32 stream buffers bit-identical,
        // and phase 2 re-quantizes row-by-row with the same arithmetic a
        // fresh prime under the new params would run — so remapped-quant
        // and fresh-quant decode must agree *bitwise*, not just in bound
        use crate::config::GrowthOp::*;
        let c = wide_cfg();
        let ops = vec![
            Mlp { p: 64 },
            HeadsAdd { count: 1 },
            LayersAdd { count: 1, position: LayerPosition::At(1) },
        ];
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let mut rng = Pcg32::seeded(31);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..5).map(|_| rng.below(c.vocab) as u32).collect();
        let new_params = ExpansionPlan::new(&c, ops.clone())
            .unwrap()
            .materialize(&params, &opts, &mut rng)
            .unwrap();

        let mut remapped = QuantKvCache::new(&c);
        feed(&mut remapped, &params, &history);
        remap_via_plan(&mut remapped, &ops, &new_params).unwrap();
        let a = forward_incremental(new_params.config(), &new_params, &mut remapped, 7).unwrap();

        let mut fresh = QuantKvCache::new(new_params.config());
        feed(&mut fresh, &new_params, &history);
        let b = forward_incremental(new_params.config(), &new_params, &mut fresh, 7).unwrap();
        assert_eq!(a, b, "quant remap must be bit-identical to a fresh quant prime");
        assert_eq!(remapped.kv_resident_bytes(), fresh.kv_resident_bytes());
    }

    #[test]
    fn quant_remap_tracks_fresh_prime_for_general_ops() {
        // the composed case includes hidden widening (changes the stream →
        // re-quantization of *different* rows): agreement is bounded by the
        // f32 remap tolerance plus quantization drift
        use crate::config::GrowthOp::*;
        let c = wide_cfg();
        let ops = vec![Mlp { p: 64 }, AttnExpand { k: 32 }, Hidden { h: 24 }];
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let mut rng = Pcg32::seeded(37);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..6).map(|_| rng.below(c.vocab) as u32).collect();
        let new_params = ExpansionPlan::new(&c, ops.clone())
            .unwrap()
            .materialize(&params, &opts, &mut rng)
            .unwrap();

        let mut remapped = QuantKvCache::new(&c);
        feed(&mut remapped, &params, &history);
        remap_via_plan(&mut remapped, &ops, &new_params).unwrap();
        let a = forward_incremental(new_params.config(), &new_params, &mut remapped, 2).unwrap();

        let mut fresh = QuantKvCache::new(new_params.config());
        feed(&mut fresh, &new_params, &history);
        let b = forward_incremental(new_params.config(), &new_params, &mut fresh, 2).unwrap();
        let d = a.max_abs_diff(&b).unwrap();
        assert!(d <= 5e-2, "general-op quant remap drift {d} above bound");
    }

    // ---- f16 middle tier ----------------------------------------------

    #[test]
    fn f16_conversion_edge_cases() {
        // exact zero (both signs) survives bit-for-bit in sign+magnitude
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f16_bits_to_f32(0x0000), 0.0);
        // values beyond the f16 range saturate to ±Inf
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00);
        assert_eq!(f32_to_f16_bits(-1e6), 0xFC00);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(0xFC00), f32::NEG_INFINITY);
        // NaN stays NaN in both directions
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // tiny values land in the f16 subnormal range and round-trip
        // within half a subnormal ulp (2^-25)
        let tiny = 3.0e-6_f32;
        let back = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((tiny - back).abs() <= 2f32.powi(-25), "subnormal {tiny} -> {back}");
        // below half the smallest subnormal flushes to zero
        assert_eq!(f32_to_f16_bits(1.0e-8), 0x0000);
        // round-to-nearest-even carry: 2047.5 ulps of mantissa rounds up
        // and carries into the exponent (65519.996.. -> 65504 is the max
        // finite f16; just above the midpoint to Inf saturates)
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(65504.0)), 65504.0);
        assert_eq!(f32_to_f16_bits(65520.0), 0x7C00); // midpoint rounds to even => Inf
        // representable values are exact
        for &x in &[1.0f32, -2.5, 0.125, 1024.0, -0.0009765625] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(x)), x, "{x} must be exact in f16");
        }
    }

    #[test]
    fn f16_roundtrip_error_is_bounded() {
        // the quant round-trip prop test extended to the f16 tier: binary16
        // keeps 10 mantissa bits, so RNE gives ≤2^-11 relative error for
        // normal values; assert the looser |x|·2^-10 plus a subnormal-range
        // absolute term (half the smallest subnormal ulp); random shapes AND
        // random magnitude scales, via the prop harness
        Runner::new("f16-kv-roundtrip", 64).run_sized(
            &mut |rng| {
                let rows = 1 + rng.below(5);
                let cols = 1 + rng.below(80);
                let mag = match rng.below(5) {
                    0 => 1e-3,
                    1 => 0.05,
                    2 => 1.0,
                    3 => 40.0,
                    _ => 1e4,
                };
                let mut t = Tensor::zeros(&[rows, cols]);
                rng.fill_normal(t.data_mut(), mag);
                if rng.below(4) == 0 {
                    // an all-zero row exercises the sign/zero encode path
                    for x in t.row_mut(0) {
                        *x = 0.0;
                    }
                }
                t
            },
            |t| t.numel(),
            &mut |t| {
                let hb = <F16Buf as KvStorage>::from_tensor(t);
                if hb.rows() != t.rows() || hb.cols() != t.cols() {
                    return Err("shape mismatch after encode".into());
                }
                for i in 0..t.rows() {
                    let back = hb.row_f32(i);
                    for (c, &x) in t.row(i).iter().enumerate() {
                        let y = back[c];
                        let bound = x.abs() * 2f32.powi(-10) + 2f32.powi(-25);
                        if (x - y).abs() > bound {
                            return Err(format!("row {i} col {c}: |{x} - {y}| > {bound}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn f16_dot_and_add_scaled_match_dequantized_rows() {
        // the read primitives must be plain f32 math over the *decoded*
        // values, in the same ascending order as GrowBuf — so a GrowBuf
        // built from row_f32 copies reproduces them bit for bit
        let mut rng = Pcg32::seeded(22);
        let t = Tensor::randn(&[4, 40], &mut rng, 0.7);
        let hb = <F16Buf as KvStorage>::from_tensor(&t);
        let mut deq = <GrowBuf as KvStorage>::new(40);
        for i in 0..4 {
            KvStorage::push_row(&mut deq, &hb.row_f32(i));
        }
        let q: Vec<f32> = (0..40).map(|_| rng.normal_f32(1.0)).collect();
        for i in 0..4 {
            assert_eq!(hb.dot(i, &q).to_bits(), KvStorage::dot(&deq, i, &q).to_bits(), "dot row {i}");
            let mut a = vec![0.125f32; 40];
            let mut b = a.clone();
            hb.add_scaled(i, 0.35, &mut a);
            KvStorage::add_scaled(&deq, i, 0.35, &mut b);
            assert_eq!(a, b, "add_scaled row {i}");
        }
    }

    #[test]
    fn f16_cache_halves_resident_kv_bytes() {
        let c = wide_cfg();
        let mut rng = Pcg32::seeded(11);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..8).map(|_| rng.below(c.vocab) as u32).collect();

        let mut full = KvCache::new(&c);
        feed(&mut full, &params, &history);
        let mut half = F16KvCache::new(&c);
        feed(&mut half, &params, &history);

        assert_eq!(full.num_cached_scalars(), half.num_cached_scalars());
        let ratio = full.kv_resident_bytes() as f64 / half.kv_resident_bytes() as f64;
        assert!(
            (ratio - 2.0).abs() < 1e-9,
            "f16 KV must hold exactly 2x fewer resident bytes, got {ratio:.2}x"
        );
    }

    #[test]
    fn f16_decode_tracks_f32_within_documented_bound() {
        // teacher-forced decode with an f16 cache vs exact f32: per-step
        // logit drift stays well under the int8 tier's 5e-2 — assert the
        // tighter 5e-3 that the 2^-11 relative error affords at this scale
        let c = cfg();
        let mut rng = Pcg32::seeded(5);
        let params = ParamStore::init(&c, &mut rng, 0.08);
        let history: Vec<u32> = (0..10).map(|_| rng.below(c.vocab) as u32).collect();

        let mut exact = KvCache::new(&c);
        let mut half = F16KvCache::new(&c);
        let mut worst = 0.0f32;
        for &tok in &history {
            let a = forward_incremental(&c, &params, &mut exact, tok).unwrap();
            let b = forward_incremental(&c, &params, &mut half, tok).unwrap();
            worst = worst.max(a.max_abs_diff(&b).unwrap());
        }
        assert!(worst <= 5e-3, "f16 decode drift {worst} above documented bound");
        assert!(worst > 0.0, "f16 path suspiciously identical to f32 (not exercising quant)");
    }

    #[test]
    fn kv_tier_parse_and_label() {
        assert!(matches!(KvTier::parse("f32").unwrap(), KvTier::F32));
        assert!(matches!(KvTier::parse("f16").unwrap(), KvTier::F16));
        assert!(matches!(KvTier::parse("int8").unwrap(), KvTier::Int8));
        assert!(KvTier::parse("bf16").is_err());
        assert_eq!(KvTier::F16.label(), "f16");
    }
}
