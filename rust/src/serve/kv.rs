//! Per-sequence KV cache for incremental decode (S15a), remappable through
//! the paper's expansion ops.
//!
//! For each transformer layer the cache holds (a) the layer's **pre-norm
//! residual-stream input rows** `[t, h]` (plus one extra buffer for the
//! final hidden state feeding `w_out`) and (b) each head's projected K/V
//! rows `[t, k]` / `[t, v]`. The K/V buffers make a decode step cost one
//! position of attention instead of a full re-forward; the input buffers
//! are what make **hot-swap** possible: every cached K/V row is a pure
//! function of the layer input and the live `W^K`/`W^V`, so after
//! parameter surgery ([`KvCache::remap`]) the projections are *recomputed*
//! from the structurally-remapped inputs instead of being rebuilt from the
//! token history with a full re-forward.
//!
//! The structural remap leans on the residual-stream invariants of the
//! preservation theorems (argument in DESIGN.md §9.3):
//!
//! * `mlp` / `heads_add` / `heads_expand` / `attn_expand` leave every
//!   residual-stream value bit-identical → inputs unchanged;
//! * `hidden` extends the residual stream with **exact zeros** (embed/pos/
//!   `W^O`/`W2`/`b2` extensions are all zero) → append zero columns;
//! * `layers_add` inserts identity layers (`I_n + 0`) → insert *copies* of
//!   the stream value at the insertion point.
//!
//! Numerics: `attend` replicates [`crate::model::attention`]'s operation
//! order exactly (dot, scale, max-subtracted softmax, weighted V sum with
//! the same zero-skip), so incremental logits are bit-identical to the
//! matching [`crate::model::forward_one`] row — see the cross-check test
//! in `model.rs`.

use crate::config::{GrowthOp, LayerPosition, ModelConfig};
use crate::error::{Error, Result};
use crate::params::ParamStore;
use crate::tensor::Tensor;

/// Append-only row buffer: a `[rows, cols]` f32 matrix grown one row at a
/// time (no per-step reallocation of the whole matrix).
#[derive(Clone, Debug)]
pub(crate) struct GrowBuf {
    cols: usize,
    data: Vec<f32>,
}

impl GrowBuf {
    fn new(cols: usize) -> GrowBuf {
        GrowBuf { cols, data: Vec::new() }
    }

    fn from_tensor(t: &Tensor) -> GrowBuf {
        GrowBuf { cols: t.cols(), data: t.data().to_vec() }
    }

    pub(crate) fn rows(&self) -> usize {
        if self.cols == 0 { 0 } else { self.data.len() / self.cols }
    }

    pub(crate) fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cols);
        self.data.extend_from_slice(row);
    }

    /// Materialize as a `[rows, cols]` tensor (copies).
    fn as_tensor(&self) -> Tensor {
        Tensor::from_vec(&[self.rows(), self.cols], self.data.clone())
            .expect("GrowBuf invariant: data.len() == rows*cols")
    }

    /// Widen every row by `extra` zero columns (hidden-expansion remap).
    fn append_zero_cols(&mut self, extra: usize) {
        let rows = self.rows();
        let new_cols = self.cols + extra;
        let mut data = Vec::with_capacity(rows * new_cols);
        for i in 0..rows {
            data.extend_from_slice(self.row(i));
            data.extend(std::iter::repeat(0.0f32).take(extra));
        }
        self.cols = new_cols;
        self.data = data;
    }
}

/// KV + residual-stream cache for one in-flight sequence.
#[derive(Clone, Debug)]
pub struct KvCache {
    cfg: ModelConfig,
    /// `xs[n]` = pre-norm input rows of layer `n`; `xs[layers]` = the final
    /// hidden state (input to `w_out`).
    xs: Vec<GrowBuf>,
    /// `heads[n][e]` = (K rows, V rows) for layer `n`, head `e`.
    heads: Vec<Vec<(GrowBuf, GrowBuf)>>,
    len: usize,
}

impl KvCache {
    /// Empty cache for one sequence under `cfg`.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        let xs = (0..=cfg.layers).map(|_| GrowBuf::new(cfg.hidden)).collect();
        let heads = (0..cfg.layers)
            .map(|_| (0..cfg.heads).map(|_| (GrowBuf::new(cfg.k), GrowBuf::new(cfg.v))).collect())
            .collect();
        KvCache { cfg: *cfg, xs, heads, len: 0 }
    }

    /// Number of cached positions (== the next token's position index).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The architecture this cache is laid out for.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// Drop all cached positions, keeping the layout (window re-prime).
    pub fn reset(&mut self) {
        *self = KvCache::new(&self.cfg);
    }

    /// Total cached scalars (capacity accounting / tests).
    pub fn num_cached_scalars(&self) -> usize {
        self.xs.iter().map(|b| b.data.len()).sum::<usize>()
            + self
                .heads
                .iter()
                .flatten()
                .map(|(k, v)| k.data.len() + v.data.len())
                .sum::<usize>()
    }

    // ---- incremental-forward hooks (crate-internal; see model.rs) ---------

    pub(crate) fn push_x(&mut self, layer: usize, row: &[f32]) {
        self.xs[layer].push_row(row);
    }

    pub(crate) fn push_kv(&mut self, layer: usize, head: usize, k: &[f32], v: &[f32]) {
        let (kb, vb) = &mut self.heads[layer][head];
        kb.push_row(k);
        vb.push_row(v);
    }

    /// Mark one full token as cached (called once per incremental forward).
    pub(crate) fn bump(&mut self) {
        self.len += 1;
    }

    /// Causal attention of one query row over every cached position of
    /// `(layer, head)`, replicating `model::attention`'s op order exactly.
    pub(crate) fn attend(&self, layer: usize, head: usize, q: &[f32]) -> Vec<f32> {
        let (kb, vb) = &self.heads[layer][head];
        let t = kb.rows();
        debug_assert!(t > 0, "attend on empty cache");
        let scale = 1.0 / (kb.cols as f32).sqrt();
        // scores = (q · K^T) * 1/sqrt(k)
        let mut scores = Vec::with_capacity(t);
        for j in 0..t {
            let krow = kb.row(j);
            let mut acc = 0.0f32;
            for kk in 0..kb.cols {
                acc += q[kk] * krow[kk];
            }
            scores.push(acc * scale);
        }
        // max-subtracted softmax (same order as tensor::softmax_rows)
        let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - max).exp();
            sum += *s;
        }
        for s in scores.iter_mut() {
            *s /= sum;
        }
        // weighted V sum (same ikj order + zero-skip as Tensor::matmul)
        let mut out = vec![0.0f32; vb.cols];
        for (j, &w) in scores.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let vrow = vb.row(j);
            for c in 0..vb.cols {
                out[c] += w * vrow[c];
            }
        }
        out
    }

    /// Logits of the most recently cached position, recomputed from the
    /// cached final hidden state (used to refresh a sequence's pending
    /// logits after a hot-swap).
    pub fn last_logits(&self, params: &ParamStore) -> Result<Tensor> {
        if self.len == 0 {
            return Err(Error::Serve("last_logits on an empty cache".into()));
        }
        let last = Tensor::from_vec(&[1, self.cfg.hidden], self.xs[self.cfg.layers].row(self.len - 1).to_vec())?;
        last.matmul(params.get("w_out")?)
    }

    // ---- hot-swap remap ----------------------------------------------------

    /// Remap the cache through an expansion-op sequence so that decoding
    /// continues under `new_params` as if the whole history had been fed to
    /// the expanded model. Crate-internal mechanism: the public entry is
    /// [`crate::expand::StagedKv`]'s `Expandable::apply_plan`.
    ///
    /// Two phases: (1) structural remap of the residual-stream buffers
    /// (zero-column extension under `hidden`, copy insertion under
    /// `layers_add`); (2) rebuild of every head's K/V from the remapped
    /// inputs and the *new* projection weights — which also covers new
    /// heads, widened K/V dims and the `sqrt(k̂/k)` key rescaling without
    /// op-specific K/V surgery. Exactness argument: DESIGN.md §9.3.
    pub(crate) fn remap(&mut self, ops: &[GrowthOp], new_params: &ParamStore) -> Result<()> {
        let mut cfg = self.cfg;
        for op in ops {
            let next = op
                .apply_to_config(&cfg)
                .map_err(|e| Error::Serve(format!("kv remap: {e}")))?;
            match *op {
                GrowthOp::Hidden { h } => {
                    let extra = h - cfg.hidden;
                    for x in &mut self.xs {
                        x.append_zero_cols(extra);
                    }
                }
                GrowthOp::LayersAdd { count, position } => {
                    let pos = match position {
                        LayerPosition::Top => cfg.layers,
                        LayerPosition::Bottom => 0,
                        LayerPosition::At(p) => p,
                    };
                    // an inserted identity layer sees — and passes through —
                    // the stream value at its position
                    for _ in 0..count {
                        let copy = self.xs[pos].clone();
                        self.xs.insert(pos, copy);
                    }
                }
                // mlp / heads_add / heads_expand / attn_expand leave the
                // residual stream untouched
                _ => {}
            }
            cfg = next;
        }
        if &cfg != new_params.config() {
            return Err(Error::Serve(format!(
                "kv remap: ops produce {:?} but new params are {:?}",
                cfg,
                new_params.config()
            )));
        }

        // phase 2: rebuild K/V from remapped inputs + new weights
        let mut heads = Vec::with_capacity(cfg.layers);
        for n in 0..cfg.layers {
            let x = self.xs[n].as_tensor();
            let nrm = crate::model::rmsnorm(&x, new_params.get(&format!("layer_{n}.g_mha"))?)?;
            let mut layer_heads = Vec::with_capacity(cfg.heads);
            for e in 0..cfg.heads {
                let k = nrm.matmul(new_params.get(&format!("layer_{n}.head_{e}.wk"))?)?;
                let v = nrm.matmul(new_params.get(&format!("layer_{n}.head_{e}.wv"))?)?;
                layer_heads.push((GrowBuf::from_tensor(&k), GrowBuf::from_tensor(&v)));
            }
            heads.push(layer_heads);
        }
        self.heads = heads;
        self.cfg = cfg;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::expand::{Expandable, ExpandOptions, ExpansionPlan, Init, StagedKv};
    use crate::model::{forward_incremental, forward_one};
    use crate::rng::Pcg32;

    /// Remap `cache` through `ops` via the plan seam (the only entry).
    fn remap_via_plan(cache: &mut KvCache, ops: &[GrowthOp], new_params: &ParamStore) -> Result<()> {
        let plan = ExpansionPlan::new(cache.config(), ops.to_vec())
            .map_err(|e| Error::Serve(format!("kv remap: {e}")))?;
        let mut staged = StagedKv { cache: cache.clone(), new_params };
        staged.apply_plan(&plan, &ExpandOptions::default(), &mut Pcg32::seeded(0))?;
        *cache = staged.cache;
        Ok(())
    }

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 2, hidden: 16, heads: 2, k: 8, v: 8, mlp: 32, seq: 16, vocab: 32 }
    }

    fn feed(cache: &mut KvCache, params: &ParamStore, tokens: &[u32]) -> Tensor {
        let cfg = *cache.config();
        let mut logits = None;
        for &t in tokens {
            logits = Some(forward_incremental(&cfg, params, cache, t).unwrap());
        }
        logits.expect("at least one token")
    }

    #[test]
    fn cache_grows_and_resets() {
        let c = cfg();
        let mut rng = Pcg32::seeded(3);
        let params = ParamStore::init(&c, &mut rng, 0.02);
        let mut cache = KvCache::new(&c);
        assert!(cache.is_empty());
        feed(&mut cache, &params, &[1, 2, 3]);
        assert_eq!(cache.len(), 3);
        // xs: (layers+1) buffers of [3, h]; heads: layers*heads*(K+V)
        let expect = (c.layers + 1) * 3 * c.hidden + c.layers * c.heads * 3 * (c.k + c.v);
        assert_eq!(cache.num_cached_scalars(), expect);
        cache.reset();
        assert!(cache.is_empty());
        assert_eq!(cache.num_cached_scalars(), 0);
    }

    #[test]
    fn last_logits_matches_incremental_output() {
        let c = cfg();
        let mut rng = Pcg32::seeded(4);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let mut cache = KvCache::new(&c);
        let logits = feed(&mut cache, &params, &[5, 6, 7, 8]);
        let again = cache.last_logits(&params).unwrap();
        assert_eq!(again, logits);
        assert!(KvCache::new(&c).last_logits(&params).is_err());
    }

    /// The central hot-swap property: remap(ops) then decode ≡ feeding the
    /// whole history to the expanded model from scratch.
    #[test]
    fn remap_agrees_with_fresh_prime_under_new_params() {
        use crate::config::GrowthOp::*;
        let c = cfg();
        let cases: Vec<(&str, Vec<GrowthOp>)> = vec![
            ("mlp", vec![Mlp { p: 64 }]),
            ("heads_add", vec![HeadsAdd { count: 2 }]),
            ("heads_expand", vec![HeadsExpand { v: 16 }]),
            ("attn_expand", vec![AttnExpand { k: 16 }]),
            ("hidden", vec![Hidden { h: 24 }]),
            ("layers_top", vec![LayersAdd { count: 1, position: LayerPosition::Top }]),
            ("layers_bottom", vec![LayersAdd { count: 2, position: LayerPosition::Bottom }]),
            ("layers_mid", vec![LayersAdd { count: 1, position: LayerPosition::At(1) }]),
            (
                "composed",
                vec![
                    Mlp { p: 64 },
                    HeadsAdd { count: 1 },
                    AttnExpand { k: 16 },
                    Hidden { h: 24 },
                    LayersAdd { count: 1, position: LayerPosition::Top },
                ],
            ),
        ];
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        for (name, ops) in cases {
            let mut rng = Pcg32::seeded(11);
            let params = ParamStore::init(&c, &mut rng, 0.05);
            let history: Vec<u32> = (0..6).map(|_| rng.below(c.vocab) as u32).collect();
            let new_params = ExpansionPlan::new(&c, ops.clone())
                .unwrap()
                .materialize(&params, &opts, &mut rng)
                .unwrap();

            // path A: prime under old params, remap, feed one more token
            let mut remapped = KvCache::new(&c);
            feed(&mut remapped, &params, &history);
            remap_via_plan(&mut remapped, &ops, &new_params).unwrap();
            let next = 9u32;
            let a = forward_incremental(new_params.config(), &new_params, &mut remapped, next).unwrap();

            // path B: feed the full history + token to the expanded model
            let mut fresh = KvCache::new(new_params.config());
            feed(&mut fresh, &new_params, &history);
            let b = forward_incremental(new_params.config(), &new_params, &mut fresh, next).unwrap();

            let delta = a.max_abs_diff(&b).unwrap();
            assert!(delta <= 1e-4, "{name}: remap vs fresh prime max|Δ| = {delta}");
            assert_eq!(remapped.len(), fresh.len(), "{name}");
            assert_eq!(remapped.config(), new_params.config(), "{name}");
        }
    }

    /// For ops that do not touch attention inputs, the remap is not just
    /// within tolerance but *bit-identical* to a fresh prime.
    #[test]
    fn remap_is_bitexact_for_stream_preserving_ops() {
        use crate::config::GrowthOp::*;
        let c = cfg();
        let ops = vec![
            Mlp { p: 64 },
            HeadsAdd { count: 1 },
            HeadsExpand { v: 16 },
            LayersAdd { count: 1, position: LayerPosition::At(1) },
        ];
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let mut rng = Pcg32::seeded(13);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let history: Vec<u32> = (0..5).map(|_| rng.below(c.vocab) as u32).collect();
        let new_params = ExpansionPlan::new(&c, ops.clone())
            .unwrap()
            .materialize(&params, &opts, &mut rng)
            .unwrap();

        let mut remapped = KvCache::new(&c);
        feed(&mut remapped, &params, &history);
        remap_via_plan(&mut remapped, &ops, &new_params).unwrap();
        let a = forward_incremental(new_params.config(), &new_params, &mut remapped, 3).unwrap();

        let mut window: Vec<u32> = history.clone();
        window.push(3);
        window.resize(new_params.config().seq, 0);
        let full = forward_one(new_params.config(), &new_params, &window).unwrap();
        let row = full.slice_rows(history.len(), history.len() + 1).unwrap();
        assert_eq!(a, row, "stream-preserving remap must be bit-identical to the full forward");
    }

    #[test]
    fn remap_rejects_mismatched_params() {
        let c = cfg();
        let mut rng = Pcg32::seeded(17);
        let params = ParamStore::init(&c, &mut rng, 0.05);
        let mut cache = KvCache::new(&c);
        feed(&mut cache, &params, &[1, 2]);
        // ops say mlp=64 but hand the cache the *old* params
        let ops = vec![GrowthOp::Mlp { p: 64 }];
        let err = remap_via_plan(&mut cache, &ops, &params).unwrap_err().to_string();
        assert!(err.contains("kv remap"), "{err}");
    }
}
