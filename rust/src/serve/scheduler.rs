//! Batch scheduler (S15b): request queue + continuous batching.
//!
//! The scheduler owns a FIFO queue of pending requests and a set of
//! in-flight **slots** (bounded by `max_slots`). Batching is *continuous*:
//! a finished sequence frees its slot at the end of the tick and a queued
//! request is admitted at the start of the next one, so sequences of very
//! different lengths never barrier on each other — the batch composition
//! changes tick by tick.
//!
//! Each tick every active slot advances one token: sample from its pending
//! logits (per-request [`Sampler`], per-request RNG stream so results are
//! independent of batch composition), then run one KV-cached incremental
//! forward ([`crate::model::forward_incremental`]). Slots are mutually
//! independent, so when `parallel` is set the decode fans out over the
//! shared scoped-thread pool ([`crate::parallel::Pool`], sized by
//! `TEXPAND_THREADS` — the same seam native training parallelizes
//! through), replacing the old ad-hoc thread-per-slot `std::thread::scope`
//! loop: worker count no longer grows with slot count, and results are
//! identical either way, which `integration_serve.rs` asserts.
//!
//! Window policy: while a sequence fits the positional table the decode is
//! purely incremental; past `seq` tokens the window slides, which
//! invalidates every cached position (the positional embedding of each
//! cached token changes), so the slot re-primes its cache over the last
//! `seq`-token window — the same sliding rule as `generate::generate_ref`,
//! keeping greedy decodes token-identical to the KV-less oracle.

use std::collections::VecDeque;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::generate::{sample_from_logits, Sampler};
use crate::metrics::Timer;
use crate::model::forward_incremental;
use crate::parallel::Pool;
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::serve::kv::{F16KvCache, KvCache, KvTier, QuantKvCache};
use crate::tensor::Tensor;

/// Opaque request handle returned by `submit`.
pub type RequestId = u64;

/// Why a sequence left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated the requested number of tokens.
    MaxTokens,
    /// Exceeded the engine's per-request deadline
    /// (`EngineOptions::request_timeout_ticks`); the completion carries
    /// the partial output decoded before expiry.
    TimedOut,
}

/// One queued generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampler: Sampler,
    /// Per-request deadline in scheduler ticks spent in a slot; `0` falls
    /// back to the engine-wide `EngineOptions::request_timeout_ticks`.
    /// The HTTP front-end maps wall-clock `deadline_ms` onto this.
    pub timeout_ticks: u64,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    /// Full token history: prompt followed by the generated continuation.
    pub tokens: Vec<u32>,
    pub prompt_len: usize,
    pub generated: usize,
    pub finish: FinishReason,
    /// Scheduler ticks the request spent in a slot.
    pub ticks_in_flight: u64,
}

/// Storage-tier dispatch for one slot's KV cache: exact f32, half-precision
/// f16, or block-quantized i8 (`--kv-quant=f16|int8` /
/// `EngineOptions::kv_tier`). An enum rather than a generic `Slot` keeps
/// the scheduler/engine/hot-swap layer monomorphic — the dispatch cost is
/// one match per decode step, and each lossy tier's bounded logit drift is
/// documented in DESIGN.md §17–18.
#[derive(Clone, Debug)]
pub(crate) enum SlotCache {
    F32(KvCache),
    F16(F16KvCache),
    Quant(QuantKvCache),
}

impl SlotCache {
    pub(crate) fn new(cfg: &ModelConfig, tier: KvTier) -> SlotCache {
        match tier {
            KvTier::F32 => SlotCache::F32(KvCache::new(cfg)),
            KvTier::F16 => SlotCache::F16(F16KvCache::new(cfg)),
            KvTier::Int8 => SlotCache::Quant(QuantKvCache::new(cfg)),
        }
    }

    pub(crate) fn len(&self) -> usize {
        match self {
            SlotCache::F32(c) => c.len(),
            SlotCache::F16(c) => c.len(),
            SlotCache::Quant(c) => c.len(),
        }
    }

    pub(crate) fn reset(&mut self) {
        match self {
            SlotCache::F32(c) => c.reset(),
            SlotCache::F16(c) => c.reset(),
            SlotCache::Quant(c) => c.reset(),
        }
    }

    /// Resident bytes of the K/V storage proper (the quantity `--kv-quant`
    /// shrinks; exact-f32 stream buffers excluded in all tiers).
    pub(crate) fn kv_resident_bytes(&self) -> usize {
        match self {
            SlotCache::F32(c) => c.kv_resident_bytes(),
            SlotCache::F16(c) => c.kv_resident_bytes(),
            SlotCache::Quant(c) => c.kv_resident_bytes(),
        }
    }

    /// One incremental forward through whichever tier backs this slot.
    pub(crate) fn feed(
        &mut self,
        cfg: &ModelConfig,
        params: &ParamStore,
        token: u32,
    ) -> Result<Tensor> {
        match self {
            SlotCache::F32(c) => forward_incremental(cfg, params, c, token),
            SlotCache::F16(c) => forward_incremental(cfg, params, c, token),
            SlotCache::Quant(c) => forward_incremental(cfg, params, c, token),
        }
    }
}

/// An in-flight sequence bound to a slot.
pub(crate) struct Slot {
    id: RequestId,
    history: Vec<u32>,
    prompt_len: usize,
    generated: usize,
    max_new_tokens: usize,
    sampler: Sampler,
    rng: Pcg32,
    pub(crate) cache: SlotCache,
    /// Logits of the last fed position — the next token samples from these.
    pub(crate) logits: Vec<f32>,
    admitted_tick: u64,
    /// Per-request deadline in ticks (`0` = engine-wide default applies).
    timeout_ticks: u64,
}

impl Slot {
    /// Re-prime the cache over the last `seq`-token window of the history
    /// (also the initial prompt prime, where the history *is* the window).
    fn reprime(&mut self, params: &ParamStore) -> Result<()> {
        let cfg = *params.config();
        self.cache.reset();
        let lo = self.history.len().saturating_sub(cfg.seq);
        let mut logits = None;
        for &t in &self.history[lo..] {
            logits = Some(self.cache.feed(&cfg, params, t)?);
        }
        self.logits = logits.expect("non-empty history").into_vec();
        Ok(())
    }

    /// Feed the newest history token: incremental while it fits the
    /// positional table, sliding-window re-prime afterwards.
    fn feed_last(&mut self, params: &ParamStore) -> Result<()> {
        let cfg = *params.config();
        if self.history.len() <= cfg.seq && self.cache.len() + 1 == self.history.len() {
            let t = *self.history.last().expect("non-empty history");
            self.logits = self.cache.feed(&cfg, params, t)?.into_vec();
            Ok(())
        } else {
            self.reprime(params)
        }
    }

    /// One decode step: sample, append, and (unless finished) feed the new
    /// token. Returns `true` when the sequence is done.
    fn step(&mut self, params: &ParamStore) -> Result<bool> {
        let next = sample_from_logits(&self.logits, &self.sampler, &mut self.rng);
        self.history.push(next);
        self.generated += 1;
        if self.generated >= self.max_new_tokens {
            return Ok(true);
        }
        self.feed_last(params)?;
        Ok(false)
    }

    fn into_completion(self, finish: FinishReason, tick: u64) -> Completion {
        Completion {
            id: self.id,
            tokens: self.history,
            prompt_len: self.prompt_len,
            generated: self.generated,
            finish,
            ticks_in_flight: tick.saturating_sub(self.admitted_tick),
        }
    }
}

/// One admission made by [`Scheduler::admit`]: which request entered a
/// slot, how much prompt it primed and what the prime cost — the record
/// the engine's span tracker and prefill histogram consume.
#[derive(Clone, Copy, Debug)]
pub struct Admission {
    pub id: RequestId,
    /// Prompt tokens primed through the KV cache (window-clipped).
    pub prompt_tokens: usize,
    /// Wall-clock cost of the prime.
    pub prime_ms: f64,
}

/// Outcome of one scheduler tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct TickReport {
    /// Requests moved from the queue into slots this tick.
    pub admitted: usize,
    /// Prompt tokens processed while priming admissions.
    pub prompt_tokens: usize,
    /// Continuation tokens decoded this tick (one per active slot).
    pub decoded: usize,
    /// Requests that finished this tick.
    pub completed: usize,
    /// In-flight sequences expired by the per-request deadline this tick.
    pub expired: usize,
}

/// Request queue + in-flight slots (see module docs).
pub struct Scheduler {
    queue: VecDeque<(RequestId, Request)>,
    pub(crate) active: Vec<Slot>,
    max_slots: usize,
    next_id: RequestId,
    tick: u64,
    /// Shared decode fan-out pool (`TEXPAND_THREADS`-sized by default).
    pool: Pool,
    /// Storage tier new slots are admitted with (exact f32 by default,
    /// f16 or block-int8 via `--kv-quant`).
    pub(crate) kv_tier: KvTier,
}

impl Scheduler {
    pub fn new(max_slots: usize) -> Scheduler {
        Scheduler::with_pool(max_slots, Pool::from_env())
    }

    /// Scheduler with an explicit worker pool (tests, custom sizing).
    pub fn with_pool(max_slots: usize, pool: Pool) -> Scheduler {
        Scheduler {
            queue: VecDeque::new(),
            active: Vec::new(),
            max_slots: max_slots.max(1),
            next_id: 0,
            tick: 0,
            pool,
            kv_tier: KvTier::F32,
        }
    }

    /// Enqueue a request (validated by the engine); returns its handle.
    pub fn enqueue(&mut self, request: Request) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((id, request));
        id
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn in_flight(&self) -> usize {
        self.active.len()
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    pub fn max_slots(&self) -> usize {
        self.max_slots
    }

    /// Admit queued requests into free slots, priming each prompt through
    /// the KV cache. Returns one [`Admission`] per request admitted, each
    /// carrying its prompt size and measured prime cost.
    pub fn admit(&mut self, params: &ParamStore) -> Result<Vec<Admission>> {
        let cfg = *params.config();
        let mut admissions = Vec::new();
        while self.active.len() < self.max_slots {
            let Some((id, req)) = self.queue.pop_front() else { break };
            let mut slot = Slot {
                id,
                prompt_len: req.prompt.len(),
                history: req.prompt,
                generated: 0,
                max_new_tokens: req.max_new_tokens,
                sampler: req.sampler,
                // per-request stream: decoding order/batch composition
                // cannot perturb another request's draws
                rng: Pcg32::new(req.sampler.seed, 0x5E4E ^ id),
                cache: SlotCache::new(&cfg, self.kv_tier),
                logits: Vec::new(),
                admitted_tick: self.tick,
                timeout_ticks: req.timeout_ticks,
            };
            let prompt_tokens = slot.history.len().min(cfg.seq);
            let prime = Timer::start();
            slot.reprime(params)?;
            admissions.push(Admission { id, prompt_tokens, prime_ms: prime.ms() });
            self.active.push(slot);
        }
        Ok(admissions)
    }

    /// Expire in-flight sequences past their deadline. Each slot's
    /// effective deadline is its own `Request::timeout_ticks` when set,
    /// else the engine-wide `timeout_ticks` passed here (`0` on both
    /// levels disables). Run at the start of a tick, before admission, so
    /// freed slots are immediately reusable. Expired sequences complete
    /// with their partial output and [`FinishReason::TimedOut`].
    pub fn expire(&mut self, timeout_ticks: u64) -> Vec<Completion> {
        if self.active.is_empty() {
            return Vec::new();
        }
        let tick = self.tick;
        let mut expired = Vec::new();
        let mut kept = Vec::with_capacity(self.active.len());
        for slot in self.active.drain(..) {
            let effective = if slot.timeout_ticks > 0 { slot.timeout_ticks } else { timeout_ticks };
            if effective > 0 && tick.saturating_sub(slot.admitted_tick) >= effective {
                expired.push(slot.into_completion(FinishReason::TimedOut, tick));
            } else {
                kept.push(slot);
            }
        }
        self.active = kept;
        expired
    }

    /// Advance every active slot one token. With `parallel`, slots decode
    /// across the shared scoped-thread pool (identical results — slots
    /// share nothing mutable and the pool returns outcomes in slot
    /// order). Finished sequences are drained and returned.
    pub fn decode_tick(&mut self, params: &ParamStore, parallel: bool) -> Result<Vec<Completion>> {
        self.tick += 1;
        if self.active.is_empty() {
            return Ok(Vec::new());
        }
        let outcomes: Vec<Result<bool>> = if parallel && self.active.len() > 1 {
            self.pool.map_mut(&mut self.active, |_, slot| {
                // surface a panicking slot as this tick's Err (the
                // pre-pool behavior) rather than unwinding through the
                // engine — the pool itself propagates worker panics like
                // inline execution, so the catch lives at this call site
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| slot.step(params)))
                    .unwrap_or_else(|_| Err(Error::Serve("decode worker thread panicked".into())))
            })
        } else {
            self.active.iter_mut().map(|slot| slot.step(params)).collect()
        };

        let mut finished_flags = Vec::with_capacity(outcomes.len());
        for outcome in outcomes {
            finished_flags.push(outcome?);
        }
        let mut completions = Vec::new();
        let mut kept = Vec::with_capacity(self.active.len());
        for (slot, finished) in self.active.drain(..).zip(finished_flags) {
            if finished {
                completions.push(slot.into_completion(FinishReason::MaxTokens, self.tick));
            } else {
                kept.push(slot);
            }
        }
        self.active = kept;
        Ok(completions)
    }

    /// Tick counter (for swap-scheduling and latency accounting).
    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// Incremental view of an in-flight sequence: its prompt length and
    /// the tokens generated so far. `None` once the request has left its
    /// slot (completed/expired — the result is in the completion) or was
    /// never admitted. The HTTP front-end polls this each tick to stream
    /// tokens as they are decoded.
    pub fn partial(&self, id: RequestId) -> Option<(usize, &[u32])> {
        self.active
            .iter()
            .find(|s| s.id == id)
            .map(|s| (s.prompt_len, &s.history[s.prompt_len..]))
    }

    /// Largest per-sequence resident K/V byte count across the in-flight
    /// slots right now (0 when idle) — the memory quantity `--kv-quant`
    /// shrinks, sampled by the engine each tick for its peak gauge.
    pub fn max_kv_resident_bytes(&self) -> usize {
        self.active.iter().map(|s| s.cache.kv_resident_bytes()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn params() -> ParamStore {
        ParamStore::init(&cfg(), &mut Pcg32::seeded(1), 0.05)
    }

    fn greedy_req(prompt: Vec<u32>, n: usize) -> Request {
        Request {
            prompt,
            max_new_tokens: n,
            sampler: Sampler { temperature: 0.0, top_k: None, seed: 0 },
            timeout_ticks: 0,
        }
    }

    #[test]
    fn fifo_admission_respects_slot_bound() {
        let p = params();
        let mut s = Scheduler::new(2);
        for i in 0..5u32 {
            s.enqueue(greedy_req(vec![i % 16], 4));
        }
        assert_eq!(s.queued(), 5);
        let admissions = s.admit(&p).unwrap();
        assert_eq!(admissions.len(), 2);
        assert_eq!(admissions.iter().map(|a| a.prompt_tokens).sum::<usize>(), 2);
        assert!(admissions.iter().all(|a| a.prime_ms >= 0.0));
        assert_eq!((s.queued(), s.in_flight()), (3, 2));
        // no free slots: second admit is a no-op
        assert_eq!(s.admit(&p).unwrap().len(), 0);
    }

    #[test]
    fn sequences_complete_and_drain_in_slot_order() {
        let p = params();
        let mut s = Scheduler::new(4);
        let a = s.enqueue(greedy_req(vec![1, 2], 3));
        let b = s.enqueue(greedy_req(vec![3], 5));
        s.admit(&p).unwrap();
        let mut done = Vec::new();
        for _ in 0..10 {
            done.extend(s.decode_tick(&p, false).unwrap());
            if s.is_idle() {
                break;
            }
        }
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, a);
        assert_eq!(done[0].generated, 3);
        assert_eq!(done[0].tokens.len(), 2 + 3);
        assert_eq!(done[0].finish, FinishReason::MaxTokens);
        assert_eq!(done[1].id, b);
        assert_eq!(done[1].tokens.len(), 1 + 5);
        assert!(done[1].ticks_in_flight >= done[0].ticks_in_flight);
    }

    #[test]
    fn sliding_window_reprimes_past_seq() {
        // prompt 2 + 12 generated = 14 > seq 8: the slot must slide without
        // erroring and keep producing in-vocab tokens
        let p = params();
        let mut s = Scheduler::new(1);
        s.enqueue(greedy_req(vec![1, 2], 12));
        s.admit(&p).unwrap();
        let mut done = Vec::new();
        while !s.is_idle() {
            done.extend(s.decode_tick(&p, false).unwrap());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].tokens.len(), 14);
        assert!(done[0].tokens.iter().all(|&t| (t as usize) < cfg().vocab));
    }

    #[test]
    fn expire_frees_slots_and_returns_partial_completions() {
        let p = params();
        let mut s = Scheduler::new(1);
        let slow = s.enqueue(greedy_req(vec![1, 2], 50));
        let waiting = s.enqueue(greedy_req(vec![3], 2));
        s.admit(&p).unwrap();
        for _ in 0..2 {
            assert!(s.decode_tick(&p, false).unwrap().is_empty());
        }
        // timeout 0 disables
        assert!(s.expire(0).is_empty());
        // 2 ticks in flight < 5: nothing expires yet
        assert!(s.expire(5).is_empty());
        let expired = s.expire(2);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, slow);
        assert_eq!(expired[0].finish, FinishReason::TimedOut);
        assert_eq!(expired[0].generated, 2, "partial output survives expiry");
        assert_eq!(expired[0].tokens.len(), 2 + 2);
        assert_eq!(expired[0].ticks_in_flight, 2);
        // the freed slot admits the queued request
        assert_eq!(s.admit(&p).unwrap().len(), 1);
        let mut done = Vec::new();
        while !s.is_idle() {
            done.extend(s.decode_tick(&p, false).unwrap());
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, waiting);
    }

    #[test]
    fn per_request_deadline_overrides_engine_global() {
        let p = params();
        let mut s = Scheduler::new(2);
        let strict = s.enqueue(Request { timeout_ticks: 2, ..greedy_req(vec![1], 50) });
        let lax = s.enqueue(greedy_req(vec![2], 50));
        s.admit(&p).unwrap();
        for _ in 0..2 {
            s.decode_tick(&p, false).unwrap();
        }
        // global disabled (0): the per-request deadline still fires
        let expired = s.expire(0);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, strict);
        assert_eq!(expired[0].finish, FinishReason::TimedOut);
        assert_eq!(expired[0].generated, 2);
        // the other slot has no per-request deadline and follows the global
        assert!(s.expire(0).is_empty());
        let expired = s.expire(2);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, lax);
        // a per-request deadline *longer* than the global wins too
        let slow = s.enqueue(Request { timeout_ticks: 10, ..greedy_req(vec![3], 50) });
        s.admit(&p).unwrap();
        for _ in 0..3 {
            s.decode_tick(&p, false).unwrap();
        }
        assert!(s.expire(1).is_empty(), "per-request deadline shields from a shorter global");
        for _ in 0..7 {
            s.decode_tick(&p, false).unwrap();
        }
        let expired = s.expire(1);
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, slow);
    }

    #[test]
    fn partial_exposes_generated_tokens_while_in_flight() {
        let p = params();
        let mut s = Scheduler::new(1);
        let id = s.enqueue(greedy_req(vec![1, 2], 4));
        assert!(s.partial(id).is_none(), "queued but unadmitted: no partial yet");
        s.admit(&p).unwrap();
        let (pl, gen) = s.partial(id).expect("admitted");
        assert_eq!((pl, gen.len()), (2, 0));
        let mut seen: Vec<u32> = Vec::new();
        let mut done = Vec::new();
        while !s.is_idle() {
            done.extend(s.decode_tick(&p, false).unwrap());
            if let Some((_, gen)) = s.partial(id) {
                assert_eq!(&gen[..seen.len()], &seen[..], "partial must be append-only");
                seen = gen.to_vec();
            }
        }
        assert_eq!(done.len(), 1);
        // the streamed prefix plus whatever the final tick added equals the
        // completed continuation
        assert_eq!(&done[0].tokens[2..2 + seen.len()], &seen[..]);
        assert_eq!(done[0].tokens.len(), 2 + 4);
        assert!(s.partial(id).is_none(), "completed: partial view is gone");
        assert!(s.partial(999).is_none());
    }

    #[test]
    fn undersized_pool_decodes_all_slots_identically() {
        // 4 active slots over a 2-worker pool: chunked fan-out must cover
        // every slot and match the serial decode exactly
        let p = params();
        let run = |max_slots: usize, pool: Pool, parallel: bool| {
            let mut s = Scheduler::with_pool(max_slots, pool);
            for i in 0..4u32 {
                s.enqueue(Request {
                    prompt: vec![i, i + 1],
                    max_new_tokens: 5,
                    sampler: Sampler { temperature: 0.9, top_k: Some(6), seed: 11 },
                    timeout_ticks: 0,
                });
            }
            s.admit(&p).unwrap();
            let mut done = Vec::new();
            while !s.is_idle() {
                done.extend(s.decode_tick(&p, parallel).unwrap());
            }
            done.sort_by_key(|c| c.id);
            done.iter().map(|c| c.tokens.clone()).collect::<Vec<_>>()
        };
        let serial = run(4, Pool::new(1), false);
        assert_eq!(run(4, Pool::new(2), true), serial);
        assert_eq!(run(4, Pool::new(8), true), serial);
    }

    #[test]
    fn quant_slots_decode_greedily_like_f32_and_shrink_kv_bytes() {
        // same greedy workload through both storage tiers: tokens must
        // match (the wide_cfg drift margin comfortably covers greedy
        // decisions at this scale) and the quant tier must hold several
        // times fewer resident K/V bytes while slots are in flight
        let c = ModelConfig {
            layers: 2,
            hidden: 16,
            heads: 2,
            k: 16,
            v: 16,
            mlp: 32,
            seq: 16,
            vocab: 32,
        };
        let p = ParamStore::init(&c, &mut Pcg32::seeded(41), 0.05);
        let run = |tier: KvTier| {
            let mut s = Scheduler::new(2);
            s.kv_tier = tier;
            s.enqueue(greedy_req(vec![1, 2, 3], 8));
            s.enqueue(greedy_req(vec![4, 5], 8));
            s.admit(&p).unwrap();
            let mut peak_bytes = s.max_kv_resident_bytes();
            let mut done = Vec::new();
            while !s.is_idle() {
                done.extend(s.decode_tick(&p, false).unwrap());
                peak_bytes = peak_bytes.max(s.max_kv_resident_bytes());
            }
            done.sort_by_key(|d| d.id);
            let out: Vec<(usize, Vec<u32>)> =
                done.iter().map(|d| (d.prompt_len, d.tokens.clone())).collect();
            (out, peak_bytes)
        };
        let (exact_tokens, exact_bytes) = run(KvTier::F32);
        let (quant_tokens, quant_bytes) = run(KvTier::Int8);
        let (half_tokens, half_bytes) = run(KvTier::F16);
        // shape must agree exactly; token-level agreement is a numerics
        // property with a near-tie escape hatch, asserted in kv.rs
        // (`quant_decode_tracks_f32_within_documented_bound`)
        assert_eq!(exact_tokens.len(), quant_tokens.len());
        assert_eq!(exact_tokens.len(), half_tokens.len());
        for ((pl, a), (_, b)) in exact_tokens.iter().zip(&quant_tokens) {
            assert_eq!(a.len(), b.len(), "tiers decoded different lengths");
            assert_eq!(a[..*pl], b[..*pl], "prompt must survive both tiers");
        }
        for ((pl, a), (_, b)) in exact_tokens.iter().zip(&half_tokens) {
            assert_eq!(a.len(), b.len(), "f16 tier decoded different lengths");
            assert_eq!(a[..*pl], b[..*pl], "prompt must survive the f16 tier");
        }
        assert!(exact_bytes > 0 && quant_bytes > 0 && half_bytes > 0);
        let ratio = exact_bytes as f64 / quant_bytes as f64;
        assert!(ratio >= 3.0, "peak KV bytes ratio {ratio} below the severalfold claim");
        // the f16 middle tier sits strictly between exact and int8
        assert!(half_bytes < exact_bytes && half_bytes > quant_bytes);
        // idle scheduler reports zero
        assert_eq!(Scheduler::new(1).max_kv_resident_bytes(), 0);
    }

    #[test]
    fn parallel_and_serial_decode_agree() {
        let p = params();
        let run = |parallel: bool| {
            let mut s = Scheduler::new(4);
            for i in 0..4u32 {
                s.enqueue(Request {
                    prompt: vec![i, i + 1],
                    max_new_tokens: 6,
                    sampler: Sampler { temperature: 0.8, top_k: Some(8), seed: 7 },
                    timeout_ticks: 0,
                });
            }
            s.admit(&p).unwrap();
            let mut done = Vec::new();
            while !s.is_idle() {
                done.extend(s.decode_tick(&p, parallel).unwrap());
            }
            done.sort_by_key(|c| c.id);
            done.iter().map(|c| c.tokens.clone()).collect::<Vec<_>>()
        };
        assert_eq!(run(true), run(false));
    }
}
