//! HTTP serve front-end (S21): streaming `POST /v1/generate` + adaptive
//! admission control.
//!
//! Two halves:
//!
//! * [`admission`] — [`AimdController`], the AIMD admitted-in-flight
//!   window driven by per-token latency gradients and rejection rate;
//!   replaces the engine's static `max_pending` as the serving-side
//!   overload defense.
//! * [`server`] — [`HttpServer`], the `std::net` listener + engine-owning
//!   thread that streams decoded tokens as chunked NDJSON, maps wall-clock
//!   `deadline_ms` onto tick-denominated engine timeouts, and answers
//!   `429 Too Many Requests` + `Retry-After` past the live window.
//!
//! Driven end to end by `texpand serve --http-addr` and the
//! [`crate::serve::loadgen`] synthetic client; protocol and controller
//! math in DESIGN.md §18.

pub mod admission;
pub mod server;

pub use admission::{AimdController, AimdOptions, Adjustment, Verdict};
pub use server::{HttpServer, HttpServerOptions, HttpSummary};
