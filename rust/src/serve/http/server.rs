//! The streaming HTTP front-end (S21): `POST /v1/generate` over a
//! hand-rolled `std::net` HTTP/1.1 server.
//!
//! Architecture (DESIGN.md §18.2): the [`Engine`] is not `Sync`, so one
//! **engine thread** owns it outright and runs the submit/tick/stream
//! loop; an **accept thread** (the `MetricsServer` nonblocking-listener
//! pattern) hands each connection to a short-lived handler thread; handler
//! threads talk to the engine thread over an mpsc channel of [`Cmd`]s and
//! get tokens back over a per-request reply channel of [`StreamMsg`]s.
//! Tokens stream to the client as they decode, one chunked-transfer NDJSON
//! line per tick:
//!
//! ```text
//! {"tokens":[17,32]}
//! {"tokens":[9]}
//! {"done":true,"finish":"max_tokens","generated":3,"prompt_len":8}
//! ```
//!
//! Admission is the engine thread's [`AimdController`]: a request beyond
//! the live window (or beyond the engine's own queue bound) is answered
//! `429 Too Many Requests` + `Retry-After` before any engine work happens.
//! Per-request deadlines arrive as wall-clock `deadline_ms` and are mapped
//! onto the engine's tick-denominated timeouts through an EWMA of
//! measured tick duration; an expired request still streams everything it
//! decoded, then a terminal `"finish":"timeout"` chunk.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::data::ByteTokenizer;
use crate::error::{Error, Result};
use crate::generate::Sampler;
use crate::json::Value;
use crate::obs::http::write_response;
use crate::obs::{read_http_request, Counter, Gauge, MetricsRegistry, SpanRing};
use crate::serve::http::admission::{AimdController, AimdOptions, Verdict};
use crate::serve::scheduler::FinishReason;
use crate::serve::Engine;

/// Accept-loop poll interval (the listener is nonblocking).
const POLL: Duration = Duration::from_millis(10);
/// Per-connection socket read/write timeout.
const CONN_TIMEOUT: Duration = Duration::from_secs(2);
/// How long a handler waits for the engine thread's admission verdict.
const ADMIT_TIMEOUT: Duration = Duration::from_secs(10);

/// Knobs for [`HttpServer::bind`].
#[derive(Clone, Default)]
pub struct HttpServerOptions {
    /// Admission-controller configuration (set `adaptive: false` for the
    /// static-window baseline).
    pub aimd: AimdOptions,
    /// Hard cap applied to each request's `max_new_tokens` (0 = engine
    /// default of 512).
    pub max_new_tokens_cap: usize,
    /// When set, admission verdicts are pushed as span events alongside
    /// the engine's own request spans.
    pub span_ring: Option<Arc<SpanRing>>,
}

/// End-of-life totals returned by [`HttpServer::shutdown`].
#[derive(Clone, Copy, Debug, Default)]
pub struct HttpSummary {
    /// Generate requests reaching the engine thread.
    pub requests: u64,
    /// Requests that streamed to a terminal `done` chunk.
    pub streamed: u64,
    /// Requests shed with a 429.
    pub rejected: u64,
    /// Requests failed after admission (submit error, engine shutdown).
    pub errors: u64,
    /// Admission verdicts issued.
    pub adjustments: u64,
    /// Admission window when the server stopped.
    pub final_window: usize,
}

/// Engine-thread → handler-thread stream protocol, one channel per
/// request.
enum StreamMsg {
    /// Admitted: the handler writes the 200 chunked head now, before the
    /// first token decodes.
    Accepted,
    /// Newly decoded token ids since the last message.
    Tokens(Vec<u32>),
    /// Terminal chunk: `finish` is `"max_tokens"` or `"timeout"`.
    Done { finish: &'static str, generated: usize, prompt_len: usize },
    /// Shed by admission control; handler answers 429 + `Retry-After`.
    Rejected { retry_after: u64 },
    /// Failed after parse (submit error, engine shutting down).
    Error(String),
}

/// One admitted generation the engine thread is streaming.
struct ActiveStream {
    id: crate::serve::RequestId,
    reply: Sender<StreamMsg>,
    /// Generated tokens already sent (client disconnects flip `dead`; the
    /// engine keeps decoding — cancel-on-disconnect is a ROADMAP item).
    sent: usize,
    dead: bool,
}

struct GenCmd {
    prompt: Vec<u32>,
    max_new_tokens: usize,
    sampler: Sampler,
    deadline_ms: u64,
    reply: Sender<StreamMsg>,
}

enum Cmd {
    Generate(GenCmd),
    Shutdown,
}

/// HTTP-layer metric handles (engine-level serve metrics are the engine's
/// own `texpand_serve_*` families).
struct HttpMetrics {
    requests: Counter,
    rejected: Counter,
    completed: Counter,
    bad_requests: Counter,
    window: Gauge,
    gradient: Gauge,
    increase: Counter,
    decrease: Counter,
    hold: Counter,
}

impl HttpMetrics {
    fn register(reg: &MetricsRegistry) -> HttpMetrics {
        HttpMetrics {
            requests: reg
                .counter("texpand_http_requests_total", "generate requests reaching the engine"),
            rejected: reg
                .counter("texpand_http_rejected_total", "requests shed with 429 by admission"),
            completed: reg
                .counter("texpand_http_streams_completed_total", "streams reaching a done chunk"),
            bad_requests: reg
                .counter("texpand_http_bad_requests_total", "malformed requests answered 4xx"),
            window: reg.gauge("texpand_http_admission_window", "live AIMD admission window"),
            gradient: reg
                .gauge("texpand_http_latency_gradient", "per-token latency vs EWMA baseline"),
            increase: reg
                .counter("texpand_http_admission_increase_total", "AIMD increase verdicts"),
            decrease: reg
                .counter("texpand_http_admission_decrease_total", "AIMD decrease verdicts"),
            hold: reg.counter("texpand_http_admission_hold_total", "AIMD hold verdicts"),
        }
    }

    fn verdict_counter(&self, v: Verdict) -> &Counter {
        match v {
            Verdict::Hold => &self.hold,
            Verdict::Increase => &self.increase,
            Verdict::Decrease => &self.decrease,
        }
    }
}

/// Shared state each connection-handler thread needs.
struct ConnCtx {
    registry: Arc<MetricsRegistry>,
    cmds: Sender<Cmd>,
    quit: Arc<AtomicBool>,
    bad_requests: Counter,
    vocab: usize,
    max_new_tokens_cap: usize,
}

/// The serve front-end: accept loop + engine thread behind one socket.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    quit: Arc<AtomicBool>,
    cmds: Sender<Cmd>,
    accept_handle: Option<JoinHandle<()>>,
    engine_handle: Option<JoinHandle<(Engine, HttpSummary)>>,
}

impl HttpServer {
    /// Bind on `addr` (e.g. `127.0.0.1:0`) and take ownership of `engine`;
    /// metrics go to the global registry.
    pub fn bind(addr: &str, engine: Engine, opts: HttpServerOptions) -> Result<HttpServer> {
        HttpServer::bind_with_registry(addr, engine, opts, Arc::clone(crate::obs::global()))
    }

    /// [`HttpServer::bind`] with an explicit registry (tests).
    pub fn bind_with_registry(
        addr: &str,
        engine: Engine,
        opts: HttpServerOptions,
        registry: Arc<MetricsRegistry>,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Serve(format!("http listener bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Serve(format!("http listener addr: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| Error::Serve(format!("http listener nonblocking: {e}")))?;

        let stop = Arc::new(AtomicBool::new(false));
        let quit = Arc::new(AtomicBool::new(false));
        let (cmd_tx, cmd_rx) = channel::<Cmd>();
        let metrics = HttpMetrics::register(&registry);
        let vocab = engine.config().vocab;

        let ctx = Arc::new(ConnCtx {
            registry: Arc::clone(&registry),
            cmds: cmd_tx.clone(),
            quit: Arc::clone(&quit),
            bad_requests: metrics.bad_requests.clone(),
            vocab,
            max_new_tokens_cap: if opts.max_new_tokens_cap == 0 {
                512
            } else {
                opts.max_new_tokens_cap
            },
        });

        let accept_stop = Arc::clone(&stop);
        let accept_handle = std::thread::spawn(move || accept_loop(listener, accept_stop, ctx));

        let aimd = opts.aimd;
        let span_ring = opts.span_ring.clone();
        let engine_handle =
            std::thread::spawn(move || engine_loop(engine, cmd_rx, aimd, metrics, span_ring));

        Ok(HttpServer {
            addr: local,
            stop,
            quit,
            cmds: cmd_tx,
            accept_handle: Some(accept_handle),
            engine_handle: Some(engine_handle),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a client has requested `GET /quitz`.
    pub fn quit_requested(&self) -> bool {
        self.quit.load(Ordering::Relaxed)
    }

    /// Block until `/quitz` is hit or `timeout` elapses; returns whether
    /// quit was requested.
    pub fn wait_for_quit(&self, timeout: Duration) -> bool {
        let start = Instant::now();
        while start.elapsed() < timeout {
            if self.quit_requested() {
                return true;
            }
            std::thread::sleep(POLL);
        }
        self.quit_requested()
    }

    /// Stop accepting, drain in-flight streams to completion, and hand the
    /// engine back with the run's totals.
    pub fn shutdown(mut self) -> Result<(Engine, HttpSummary)> {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            h.join().map_err(|_| Error::Serve("http accept thread panicked".into()))?;
        }
        let _ = self.cmds.send(Cmd::Shutdown);
        let handle = self
            .engine_handle
            .take()
            .ok_or_else(|| Error::Serve("http engine thread already taken".into()))?;
        handle.join().map_err(|_| Error::Serve("http engine thread panicked".into()))
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        let _ = self.cmds.send(Cmd::Shutdown);
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Accept + connection handling
// ---------------------------------------------------------------------------

fn accept_loop(listener: TcpListener, stop: Arc<AtomicBool>, ctx: Arc<ConnCtx>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let ctx = Arc::clone(&ctx);
                handlers.push(std::thread::spawn(move || handle_conn(stream, &ctx)));
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => std::thread::sleep(POLL),
            Err(_) => std::thread::sleep(POLL),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_read_timeout(Some(CONN_TIMEOUT));
    let _ = stream.set_write_timeout(Some(CONN_TIMEOUT));
    let request = match read_http_request(&mut stream) {
        Ok(Ok(req)) => req,
        Ok(Err(parse_err)) => {
            ctx.bad_requests.inc();
            let _ = write_response(
                &mut stream,
                parse_err.status_line(),
                "text/plain; charset=utf-8",
                &format!("{}\n", parse_err.message()),
            );
            return;
        }
        Err(_) => return, // transport failure: nothing to answer
    };
    match (request.method.as_str(), request.path.as_str()) {
        ("POST", "/v1/generate") => handle_generate(&mut stream, &request.body, ctx),
        ("GET", "/metrics") => {
            let _ = write_response(
                &mut stream,
                "200 OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &crate::obs::render(&ctx.registry),
            );
        }
        ("GET", "/healthz") => {
            let _ = write_response(&mut stream, "200 OK", "text/plain; charset=utf-8", "ok\n");
        }
        ("GET", "/quitz") => {
            ctx.quit.store(true, Ordering::Relaxed);
            let _ = write_response(&mut stream, "200 OK", "text/plain; charset=utf-8", "bye\n");
        }
        (_, "/v1/generate") => {
            let _ = write_response(
                &mut stream,
                "405 Method Not Allowed",
                "text/plain; charset=utf-8",
                "use POST\n",
            );
        }
        ("GET", _) => {
            let _ = write_response(
                &mut stream,
                "404 Not Found",
                "text/plain; charset=utf-8",
                "not found\n",
            );
        }
        _ => {
            let _ = write_response(
                &mut stream,
                "405 Method Not Allowed",
                "text/plain; charset=utf-8",
                "unsupported method\n",
            );
        }
    }
}

/// A parsed, validated `/v1/generate` body.
struct GenerateBody {
    prompt: Vec<u32>,
    max_new_tokens: usize,
    deadline_ms: u64,
    sampler: Sampler,
}

fn parse_generate_body(body: &str, vocab: usize, cap: usize) -> Result<GenerateBody> {
    let v = Value::parse(body).map_err(|e| Error::Serve(format!("request body: {e}")))?;
    let prompt: Vec<u32> = if let Some(toks) = v.get("tokens") {
        let arr = toks
            .as_arr()
            .map_err(|_| Error::Serve("'tokens' must be an array of token ids".into()))?;
        let mut out = Vec::with_capacity(arr.len());
        for t in arr {
            let id = t
                .as_usize()
                .map_err(|_| Error::Serve("'tokens' entries must be non-negative ints".into()))?;
            if id >= vocab {
                return Err(Error::Serve(format!("token id {id} out of vocab range {vocab}")));
            }
            out.push(id as u32);
        }
        out
    } else if let Some(text) = v.get("prompt") {
        let text =
            text.as_str().map_err(|_| Error::Serve("'prompt' must be a string".into()))?;
        ByteTokenizer::new(vocab.min(256))?.encode(text.as_bytes())
    } else {
        return Err(Error::Serve("body needs 'tokens' (array) or 'prompt' (string)".into()));
    };
    if prompt.is_empty() {
        return Err(Error::Serve("empty prompt".into()));
    }
    let max_new_tokens = match v.get("max_new_tokens") {
        Some(n) => n
            .as_usize()
            .map_err(|_| Error::Serve("'max_new_tokens' must be a non-negative int".into()))?,
        None => 32,
    };
    if max_new_tokens == 0 {
        return Err(Error::Serve("'max_new_tokens' must be at least 1".into()));
    }
    let deadline_ms = match v.get("deadline_ms") {
        Some(n) => n
            .as_usize()
            .map_err(|_| Error::Serve("'deadline_ms' must be a non-negative int".into()))?
            as u64,
        None => 0,
    };
    let temperature = match v.get("temperature") {
        Some(t) => {
            t.as_f64().map_err(|_| Error::Serve("'temperature' must be a number".into()))? as f32
        }
        None => 0.0,
    };
    if !(0.0..=100.0).contains(&temperature) {
        return Err(Error::Serve(format!("temperature {temperature} out of range [0,100]")));
    }
    let top_k = match v.get("top_k") {
        Some(k) => Some(
            k.as_usize().map_err(|_| Error::Serve("'top_k' must be a positive int".into()))?,
        ),
        None => None,
    };
    let seed = match v.get("seed") {
        Some(s) => s
            .as_usize()
            .map_err(|_| Error::Serve("'seed' must be a non-negative int".into()))?
            as u64,
        None => 0,
    };
    Ok(GenerateBody {
        prompt,
        max_new_tokens: max_new_tokens.min(cap),
        deadline_ms,
        sampler: Sampler { temperature, top_k, seed },
    })
}

fn handle_generate(stream: &mut TcpStream, body: &str, ctx: &ConnCtx) {
    let parsed = match parse_generate_body(body, ctx.vocab, ctx.max_new_tokens_cap) {
        Ok(p) => p,
        Err(e) => {
            ctx.bad_requests.inc();
            let msg = Value::obj(vec![("error", Value::str(e.to_string()))]).to_string();
            let _ = write_response(
                stream,
                "400 Bad Request",
                "application/json; charset=utf-8",
                &format!("{msg}\n"),
            );
            return;
        }
    };
    let (reply_tx, reply_rx) = channel::<StreamMsg>();
    let cmd = Cmd::Generate(GenCmd {
        prompt: parsed.prompt,
        max_new_tokens: parsed.max_new_tokens,
        sampler: parsed.sampler,
        deadline_ms: parsed.deadline_ms,
        reply: reply_tx,
    });
    if ctx.cmds.send(cmd).is_err() {
        let _ = write_response(
            stream,
            "503 Service Unavailable",
            "text/plain; charset=utf-8",
            "server shutting down\n",
        );
        return;
    }
    // admission verdict first; tokens only after Accepted
    match reply_rx.recv_timeout(ADMIT_TIMEOUT) {
        Ok(StreamMsg::Rejected { retry_after }) => {
            let body = "overloaded, retry later\n";
            let head = format!(
                "HTTP/1.1 429 Too Many Requests\r\nContent-Type: text/plain; charset=utf-8\r\n\
                 Content-Length: {}\r\nRetry-After: {retry_after}\r\nConnection: close\r\n\r\n{body}",
                body.len()
            );
            use std::io::Write;
            let _ = stream.write_all(head.as_bytes());
            let _ = stream.flush();
        }
        Ok(StreamMsg::Error(msg)) => {
            let body = Value::obj(vec![("error", Value::str(msg))]).to_string();
            let _ = write_response(
                stream,
                "400 Bad Request",
                "application/json; charset=utf-8",
                &format!("{body}\n"),
            );
        }
        Ok(StreamMsg::Accepted) => stream_tokens(stream, &reply_rx),
        // Tokens/Done before Accepted can't happen (engine sends Accepted
        // first); treat as protocol error and drop the connection
        Ok(_) => {}
        Err(_) => {
            let _ = write_response(
                stream,
                "503 Service Unavailable",
                "text/plain; charset=utf-8",
                "engine did not answer\n",
            );
        }
    }
}

/// Write one NDJSON line as an HTTP/1.1 chunk.
fn write_chunk_line(stream: &mut TcpStream, line: &str) -> std::io::Result<()> {
    use std::io::Write;
    write!(stream, "{:x}\r\n{line}\n\r\n", line.len() + 1)?;
    stream.flush()
}

/// Stream an admitted request: chunked head, one NDJSON line per
/// [`StreamMsg`], terminal chunk after `Done`.
fn stream_tokens(stream: &mut TcpStream, rx: &Receiver<StreamMsg>) {
    use std::io::Write;
    let head = "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson; charset=utf-8\r\n\
                Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        return;
    }
    let _ = stream.flush();
    // Done (or a closed channel) ends the stream; a mid-stream write
    // failure stops writing but keeps draining so the engine side sees the
    // send error and marks the stream dead.
    let mut writable = true;
    loop {
        match rx.recv() {
            Ok(StreamMsg::Tokens(tokens)) => {
                if writable {
                    let ids: Vec<String> = tokens.iter().map(|t| t.to_string()).collect();
                    let line = format!("{{\"tokens\":[{}]}}", ids.join(","));
                    writable = write_chunk_line(stream, &line).is_ok();
                }
            }
            Ok(StreamMsg::Done { finish, generated, prompt_len }) => {
                if writable {
                    let line = format!(
                        "{{\"done\":true,\"finish\":\"{finish}\",\"generated\":{generated},\
                         \"prompt_len\":{prompt_len}}}"
                    );
                    let _ = write_chunk_line(stream, &line);
                }
                break;
            }
            Ok(StreamMsg::Error(msg)) => {
                if writable {
                    let line = Value::obj(vec![
                        ("done", Value::Bool(true)),
                        ("finish", Value::str("error")),
                        ("error", Value::str(msg)),
                    ])
                    .to_string();
                    let _ = write_chunk_line(stream, &line);
                }
                break;
            }
            Ok(_) => {} // stray Accepted/Rejected: ignore
            Err(_) => break,
        }
    }
    let _ = stream.write_all(b"0\r\n\r\n");
    let _ = stream.flush();
}

// ---------------------------------------------------------------------------
// Engine thread
// ---------------------------------------------------------------------------

/// Map a wall-clock deadline onto the engine's tick-denominated timeout
/// using the live EWMA of tick duration. `deadline_ms == 0` means no
/// deadline (the engine treats `timeout_ticks == 0` as unbounded).
fn deadline_to_ticks(deadline_ms: u64, ewma_tick_ms: f64) -> u64 {
    if deadline_ms == 0 {
        return 0;
    }
    (deadline_ms as f64 / ewma_tick_ms.max(1e-3)).ceil().max(1.0) as u64
}

fn engine_loop(
    mut engine: Engine,
    cmds: Receiver<Cmd>,
    aimd_opts: AimdOptions,
    metrics: HttpMetrics,
    span_ring: Option<Arc<SpanRing>>,
) -> (Engine, HttpSummary) {
    let mut aimd = AimdController::new(aimd_opts);
    let mut summary = HttpSummary::default();
    let mut active: Vec<ActiveStream> = Vec::new();
    // seed ~demo-model tick cost; converges within a handful of ticks
    let mut ewma_tick_ms = 5.0f64;
    let mut shutdown = false;
    metrics.window.set(aimd.window() as f64);

    loop {
        // 1. drain commands. Block briefly only when fully idle, so an
        //    idle server doesn't spin; once anything is in flight the
        //    drain is non-blocking and the tick below provides pacing.
        let mut first = true;
        loop {
            let cmd = if first && active.is_empty() && engine.is_idle() && !shutdown {
                match cmds.recv_timeout(POLL) {
                    Ok(c) => Some(c),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                match cmds.try_recv() {
                    Ok(c) => Some(c),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            };
            first = false;
            let Some(cmd) = cmd else { break };
            match cmd {
                Cmd::Shutdown => shutdown = true,
                Cmd::Generate(g) => {
                    summary.requests += 1;
                    metrics.requests.inc();
                    if shutdown {
                        let _ = g.reply.send(StreamMsg::Error("server shutting down".into()));
                        summary.errors += 1;
                        continue;
                    }
                    if !aimd.try_admit(active.len()) || !engine.has_capacity() {
                        summary.rejected += 1;
                        metrics.rejected.inc();
                        let _ = g.reply.send(StreamMsg::Rejected { retry_after: 1 });
                        continue;
                    }
                    let timeout_ticks = deadline_to_ticks(g.deadline_ms, ewma_tick_ms);
                    match engine.submit_with_deadline(
                        g.prompt,
                        g.max_new_tokens,
                        g.sampler,
                        timeout_ticks,
                    ) {
                        Ok(id) => {
                            let _ = g.reply.send(StreamMsg::Accepted);
                            active.push(ActiveStream { id, reply: g.reply, sent: 0, dead: false });
                        }
                        Err(e) => {
                            summary.errors += 1;
                            let _ = g.reply.send(StreamMsg::Error(e.to_string()));
                        }
                    }
                }
            }
        }

        // 2. advance the engine one tick and feed the controller
        if !engine.is_idle() {
            let tick_start = Instant::now();
            let report = match engine.tick() {
                Ok(r) => r,
                Err(e) => {
                    let msg = e.to_string();
                    for s in active.drain(..) {
                        let _ = s.reply.send(StreamMsg::Error(msg.clone()));
                        summary.errors += 1;
                    }
                    break;
                }
            };
            let tick_ms = tick_start.elapsed().as_secs_f64() * 1e3;
            ewma_tick_ms = 0.2 * tick_ms + 0.8 * ewma_tick_ms;
            if report.decoded > 0 {
                // the controller's sample is the *round* wall time, not
                // round/decoded: every in-flight stream receives exactly
                // one token per round, so the round duration is each
                // client's per-token latency — and it grows with the
                // admitted batch, which is precisely the overload signal.
                // (Normalizing by `decoded` would cancel that growth and
                // the window would never back off.)
                if let Some(adj) = aimd.observe(tick_ms) {
                    summary.adjustments += 1;
                    metrics.window.set(adj.window);
                    metrics.gradient.set(adj.gradient);
                    metrics.verdict_counter(adj.verdict).inc();
                    if let Some(ring) = &span_ring {
                        ring.push(format!(
                            "{{\"event\":\"admission\",\"verdict\":\"{}\",\"window\":{:.3},\
                             \"gradient\":{:.4},\"ewma_ms\":{:.4},\"sample_ms\":{:.4},\
                             \"rejection_rate\":{:.4}}}",
                            adj.verdict.name(),
                            adj.window,
                            adj.gradient,
                            adj.ewma_ms,
                            adj.sample_ms,
                            adj.rejection_rate,
                        ));
                    }
                }
            }
        }

        // 3. stream newly decoded tokens for every in-flight request
        for s in active.iter_mut() {
            if s.dead {
                continue;
            }
            if let Some((_prompt_len, generated)) = engine.partial(s.id) {
                if generated.len() > s.sent {
                    let delta = generated[s.sent..].to_vec();
                    s.sent = generated.len();
                    if s.reply.send(StreamMsg::Tokens(delta)).is_err() {
                        s.dead = true;
                    }
                }
            }
        }

        // 4. retire completions (normal and deadline-expired alike: an
        //    expired request streams its tail + a terminal timeout chunk)
        active.retain_mut(|s| {
            let Some(c) = engine.poll(s.id) else { return true };
            let generated = c.generated;
            if !s.dead && generated > s.sent {
                let tail = c.tokens[c.prompt_len + s.sent..].to_vec();
                if s.reply.send(StreamMsg::Tokens(tail)).is_err() {
                    s.dead = true;
                }
            }
            let finish = match c.finish {
                FinishReason::MaxTokens => "max_tokens",
                FinishReason::TimedOut => "timeout",
            };
            let _ = s.reply.send(StreamMsg::Done {
                finish,
                generated,
                prompt_len: c.prompt_len,
            });
            summary.streamed += 1;
            metrics.completed.inc();
            false
        });

        if shutdown && active.is_empty() && engine.is_idle() {
            break;
        }
    }

    summary.final_window = aimd.window();
    metrics.window.set(aimd.window() as f64);
    (engine, summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_mapping_rounds_up_and_keeps_zero_unbounded() {
        assert_eq!(deadline_to_ticks(0, 5.0), 0, "0 = no deadline, never 0-tick expiry");
        assert_eq!(deadline_to_ticks(10, 5.0), 2);
        assert_eq!(deadline_to_ticks(11, 5.0), 3, "partial ticks round up");
        assert_eq!(deadline_to_ticks(1, 5.0), 1, "sub-tick deadlines get one tick");
        assert_eq!(deadline_to_ticks(100, 0.0), 100_000, "degenerate EWMA clamped");
    }

    #[test]
    fn generate_body_accepts_tokens_and_prompt_forms() {
        let b = parse_generate_body(
            r#"{"tokens":[1,2,3],"max_new_tokens":4,"deadline_ms":50,"seed":9}"#,
            128,
            512,
        )
        .unwrap();
        assert_eq!(b.prompt, vec![1, 2, 3]);
        assert_eq!(b.max_new_tokens, 4);
        assert_eq!(b.deadline_ms, 50);
        assert_eq!(b.sampler.seed, 9);
        assert_eq!(b.sampler.temperature, 0.0, "greedy by default");
        assert_eq!(b.sampler.top_k, None);

        let b = parse_generate_body(r#"{"prompt":"hi","temperature":0.5,"top_k":3}"#, 128, 512)
            .unwrap();
        assert_eq!(b.prompt, vec![104, 105], "byte tokenizer on the prompt string");
        assert_eq!(b.max_new_tokens, 32, "default");
        assert_eq!(b.deadline_ms, 0, "no deadline by default");
        assert_eq!(b.sampler.temperature, 0.5);
        assert_eq!(b.sampler.top_k, Some(3));
    }

    #[test]
    fn generate_body_rejects_bad_inputs() {
        assert!(parse_generate_body("not json", 128, 512).is_err());
        assert!(parse_generate_body(r#"{}"#, 128, 512).is_err(), "needs tokens or prompt");
        assert!(parse_generate_body(r#"{"tokens":[]}"#, 128, 512).is_err(), "empty prompt");
        assert!(parse_generate_body(r#"{"tokens":["x"]}"#, 128, 512).is_err());
        assert!(parse_generate_body(r#"{"tokens":[500]}"#, 128, 512).is_err(), "out of vocab");
        assert!(
            parse_generate_body(r#"{"tokens":[1],"max_new_tokens":0}"#, 128, 512).is_err(),
            "zero generation budget"
        );
        assert!(parse_generate_body(r#"{"tokens":[1],"temperature":-1}"#, 128, 512).is_err());
    }

    #[test]
    fn generate_body_caps_max_new_tokens() {
        let b = parse_generate_body(r#"{"tokens":[1],"max_new_tokens":100000}"#, 128, 64).unwrap();
        assert_eq!(b.max_new_tokens, 64);
    }
}
