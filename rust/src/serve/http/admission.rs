//! Adaptive admission control for the HTTP serve front-end (S21a).
//!
//! The engine's static `max_pending` bound answers "how much queue can I
//! hold", not "how much load can I serve without degrading". This module
//! answers the second question with an AIMD (additive-increase /
//! multiplicative-decrease) window over two live signals:
//!
//! * the **per-token latency gradient** — the ratio of the most recent
//!   batch-mean per-token decode latency to an EWMA baseline of healthy
//!   latency. A gradient near 1.0 means the engine is keeping up;
//!   a gradient above `degrade_ratio` means admitted work is now slowing
//!   everyone down (continuous batching shares each tick across slots);
//! * the **rejection rate** of the round just ended — when the controller
//!   is turning clients away while latency stays flat, the window is too
//!   small, so additive growth is scaled up to re-probe capacity faster.
//!
//! Verdict rules (one verdict per `samples_per_verdict` observations):
//!
//! * gradient > `degrade_ratio`            → **Decrease**: `window *=
//!   decrease_factor` (geometric back-off toward `min_window`). The EWMA
//!   baseline is deliberately **not** updated on a decrease — the
//!   baseline must keep describing *healthy* latency; letting it chase
//!   overloaded samples would normalize the degradation and stop the
//!   controller from ever shedding (the classic gradient-controller
//!   stability failure).
//! * gradient ≤ 1 + (degrade_ratio−1)/2    → **Increase**: `window +=
//!   increase_step * (1 + rejection_rate)`, capped at `max_window`.
//! * otherwise                              → **Hold** (the dead band
//!   between "clearly fine" and "clearly degrading" absorbs noise).
//!
//! Stability sketch: the window is bounded in `[min_window, max_window]`;
//! decreases are multiplicative, so consecutive Decrease verdicts converge
//! geometrically; increases are a bounded additive probe, so the
//! steady-state oscillates in a narrow band around the knee of the
//! latency curve — the same argument as TCP congestion avoidance, with
//! per-token latency standing in for packet loss. DESIGN.md §18.3 works
//! the math.
//!
//! The controller is pure state + arithmetic (no clocks, no I/O), so the
//! unit tests below drive every verdict path deterministically.

/// Knobs for [`AimdController`]. Defaults are tuned for the demo-model
/// serve path (ticks of a few ms); every bound is a plain number so the
/// CLI can override them.
#[derive(Clone, Copy, Debug)]
pub struct AimdOptions {
    /// Starting admitted-in-flight window.
    pub initial_window: f64,
    /// Floor: the controller never sheds below this many in flight.
    pub min_window: f64,
    /// Ceiling: additive growth stops here.
    pub max_window: f64,
    /// EWMA smoothing for the healthy-latency baseline.
    pub ewma_alpha: f64,
    /// Gradient above which a round is judged degraded (Decrease).
    pub degrade_ratio: f64,
    /// Multiplicative back-off per Decrease verdict.
    pub decrease_factor: f64,
    /// Additive growth per Increase verdict (scaled by 1 + rejection rate).
    pub increase_step: f64,
    /// Per-token latency samples folded into one verdict.
    pub samples_per_verdict: usize,
    /// `false` freezes the window at `initial_window` — the static
    /// baseline the overload benchmark compares against. Observation
    /// bookkeeping (gradient, EWMA) still runs so both modes export the
    /// same telemetry.
    pub adaptive: bool,
}

impl Default for AimdOptions {
    fn default() -> Self {
        AimdOptions {
            initial_window: 4.0,
            min_window: 1.0,
            max_window: 64.0,
            ewma_alpha: 0.2,
            degrade_ratio: 1.3,
            decrease_factor: 0.7,
            increase_step: 1.0,
            samples_per_verdict: 8,
            adaptive: true,
        }
    }
}

/// What one observation round concluded.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Latency in the dead band (or static mode): window unchanged.
    Hold,
    /// Latency flat: additive window growth.
    Increase,
    /// Latency gradient past `degrade_ratio`: multiplicative back-off.
    Decrease,
}

impl Verdict {
    pub fn name(&self) -> &'static str {
        match self {
            Verdict::Hold => "hold",
            Verdict::Increase => "increase",
            Verdict::Decrease => "decrease",
        }
    }
}

/// One verdict's full telemetry — everything the obs registry gauges and
/// the span events export.
#[derive(Clone, Copy, Debug)]
pub struct Adjustment {
    pub verdict: Verdict,
    /// Continuous window value after the verdict.
    pub window: f64,
    /// `sample_ms / ewma_ms` — the latency gradient that was judged.
    pub gradient: f64,
    /// Batch-mean per-token latency of the round.
    pub sample_ms: f64,
    /// Healthy-latency EWMA baseline after the verdict.
    pub ewma_ms: f64,
    /// Fraction of admission decisions this round that were rejections.
    pub rejection_rate: f64,
}

/// AIMD admitted-in-flight window (see module docs).
#[derive(Clone, Debug)]
pub struct AimdController {
    opts: AimdOptions,
    /// Continuous window; [`AimdController::window`] floors it.
    window: f64,
    /// Healthy per-token latency baseline; `None` until the first round.
    ewma_ms: Option<f64>,
    /// Per-token samples accumulated toward the next verdict.
    samples: Vec<f64>,
    /// Admission decisions since the last verdict.
    admitted: u64,
    rejected: u64,
}

impl AimdController {
    pub fn new(opts: AimdOptions) -> AimdController {
        let hi = opts.max_window.max(opts.min_window);
        AimdController {
            window: opts.initial_window.clamp(opts.min_window, hi),
            opts,
            ewma_ms: None,
            samples: Vec::new(),
            admitted: 0,
            rejected: 0,
        }
    }

    /// The integer admitted-in-flight bound (never below 1).
    pub fn window(&self) -> usize {
        self.window.floor().max(1.0) as usize
    }

    /// Admission decision for a request arriving with `in_flight`
    /// requests already admitted and not yet finished. Counts toward the
    /// round's rejection rate either way.
    pub fn try_admit(&mut self, in_flight: usize) -> bool {
        if in_flight < self.window() {
            self.admitted += 1;
            true
        } else {
            self.rejected += 1;
            false
        }
    }

    /// Feed one per-token latency sample (ms). Returns `Some(Adjustment)`
    /// every `samples_per_verdict` samples, `None` while accumulating.
    /// Non-finite or non-positive samples are dropped.
    pub fn observe(&mut self, per_token_ms: f64) -> Option<Adjustment> {
        if !per_token_ms.is_finite() || per_token_ms <= 0.0 {
            return None;
        }
        self.samples.push(per_token_ms);
        if self.samples.len() < self.opts.samples_per_verdict.max(1) {
            return None;
        }
        let sample_ms = self.samples.iter().sum::<f64>() / self.samples.len() as f64;
        self.samples.clear();
        let decisions = self.admitted + self.rejected;
        let rejection_rate =
            if decisions == 0 { 0.0 } else { self.rejected as f64 / decisions as f64 };
        self.admitted = 0;
        self.rejected = 0;

        // first round: seed the baseline, judge nothing
        let Some(baseline) = self.ewma_ms else {
            self.ewma_ms = Some(sample_ms);
            return Some(Adjustment {
                verdict: Verdict::Hold,
                window: self.window,
                gradient: 1.0,
                sample_ms,
                ewma_ms: sample_ms,
                rejection_rate,
            });
        };

        let gradient = sample_ms / baseline.max(1e-9);
        let verdict = if !self.opts.adaptive {
            Verdict::Hold
        } else if gradient > self.opts.degrade_ratio {
            Verdict::Decrease
        } else if gradient <= 1.0 + (self.opts.degrade_ratio - 1.0) / 2.0 {
            Verdict::Increase
        } else {
            Verdict::Hold
        };
        match verdict {
            Verdict::Decrease => {
                self.window = (self.window * self.opts.decrease_factor).max(self.opts.min_window);
                // EWMA frozen: the baseline keeps describing healthy
                // latency instead of chasing the overload (module docs)
            }
            Verdict::Increase => {
                self.window = (self.window + self.opts.increase_step * (1.0 + rejection_rate))
                    .min(self.opts.max_window.max(self.opts.min_window));
                self.ewma_ms = Some(baseline + self.opts.ewma_alpha * (sample_ms - baseline));
            }
            Verdict::Hold => {
                self.ewma_ms = Some(baseline + self.opts.ewma_alpha * (sample_ms - baseline));
            }
        }
        Some(Adjustment {
            verdict,
            window: self.window,
            gradient,
            sample_ms,
            ewma_ms: self.ewma_ms.unwrap_or(sample_ms),
            rejection_rate,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> AimdOptions {
        // samples_per_verdict 1: each observe() is one verdict, so the
        // tests drive the state machine sample by sample
        AimdOptions { samples_per_verdict: 1, ..Default::default() }
    }

    /// Feed `n` verdicts of constant latency `ms` and return the last.
    fn feed(c: &mut AimdController, ms: f64, n: usize) -> Adjustment {
        let mut last = None;
        for _ in 0..n {
            last = c.observe(ms);
        }
        last.expect("samples_per_verdict=1 yields a verdict per observe")
    }

    #[test]
    fn first_round_seeds_baseline_and_holds() {
        let mut c = AimdController::new(opts());
        assert_eq!(c.window(), 4);
        let adj = c.observe(2.0).unwrap();
        assert_eq!(adj.verdict, Verdict::Hold);
        assert_eq!(adj.window, 4.0);
        assert_eq!(adj.ewma_ms, 2.0);
        assert_eq!(adj.gradient, 1.0);
    }

    #[test]
    fn samples_accumulate_to_one_verdict() {
        let mut c = AimdController::new(AimdOptions { samples_per_verdict: 4, ..Default::default() });
        assert!(c.observe(1.0).is_none());
        assert!(c.observe(2.0).is_none());
        assert!(c.observe(3.0).is_none());
        let adj = c.observe(4.0).unwrap();
        assert_eq!(adj.sample_ms, 2.5, "verdict judges the batch mean");
        // junk samples never count toward a verdict
        assert!(c.observe(f64::NAN).is_none());
        assert!(c.observe(-1.0).is_none());
        assert!(c.observe(0.0).is_none());
    }

    #[test]
    fn flat_latency_grows_window_to_max() {
        let mut c = AimdController::new(opts());
        feed(&mut c, 1.0, 1); // baseline
        let mut verdicts = 0;
        while c.window() < 64 {
            let adj = feed(&mut c, 1.0, 1);
            assert_eq!(adj.verdict, Verdict::Increase);
            verdicts += 1;
            assert!(verdicts < 200, "window never reached max");
        }
        // pinned at the ceiling
        let adj = feed(&mut c, 1.0, 5);
        assert_eq!(adj.window, 64.0);
        assert_eq!(c.window(), 64);
    }

    #[test]
    fn rejections_scale_the_additive_probe() {
        let mut starved = AimdController::new(opts());
        feed(&mut starved, 1.0, 1);
        // a round where every decision was a rejection
        for _ in 0..10 {
            assert!(!starved.try_admit(starved.window()));
        }
        let adj = feed(&mut starved, 1.0, 1);
        assert_eq!(adj.verdict, Verdict::Increase);
        assert_eq!(adj.rejection_rate, 1.0);

        let mut calm = AimdController::new(opts());
        feed(&mut calm, 1.0, 1);
        let calm_adj = feed(&mut calm, 1.0, 1);
        assert_eq!(calm_adj.rejection_rate, 0.0);
        // increase_step * (1 + 1.0) vs increase_step * (1 + 0.0)
        assert!(adj.window > calm_adj.window, "{} !> {}", adj.window, calm_adj.window);
    }

    #[test]
    fn latency_spike_backs_off_multiplicatively_to_min() {
        let mut c = AimdController::new(opts());
        feed(&mut c, 1.0, 1); // baseline 1.0 ms/token
        // grow a bit first so the back-off has room to show its shape
        feed(&mut c, 1.0, 6);
        let before = c.window() as f64;
        let adj = feed(&mut c, 10.0, 1);
        assert_eq!(adj.verdict, Verdict::Decrease);
        assert!((adj.window - before * 0.7).abs() < 1e-9, "multiplicative: {}", adj.window);
        // EWMA frozen on decrease: the baseline still says ~1 ms, so the
        // overload keeps reading as a 10x gradient and the shed continues
        assert!(adj.ewma_ms < 1.5, "baseline chased the overload: {}", adj.ewma_ms);
        let mut last = adj;
        for _ in 0..40 {
            last = feed(&mut c, 10.0, 1);
            assert_eq!(last.verdict, Verdict::Decrease);
        }
        assert_eq!(last.window, 1.0, "converged to min_window");
        assert_eq!(c.window(), 1);
        assert!(last.gradient > 5.0, "gradient still sees the overload: {}", last.gradient);
    }

    #[test]
    fn recovery_after_shed_regrows_the_window() {
        let mut c = AimdController::new(opts());
        feed(&mut c, 1.0, 1);
        feed(&mut c, 10.0, 10); // shed to min
        assert_eq!(c.window(), 1);
        let adj = feed(&mut c, 1.0, 3); // latency healthy again
        assert_eq!(adj.verdict, Verdict::Increase);
        assert!(c.window() > 1, "window regrew after recovery");
    }

    #[test]
    fn dead_band_holds_without_freezing_the_baseline() {
        let mut c = AimdController::new(opts());
        feed(&mut c, 1.0, 1);
        // 1.2 is between the increase bound (1.15) and degrade_ratio (1.3)
        let adj = feed(&mut c, 1.2, 1);
        assert_eq!(adj.verdict, Verdict::Hold);
        assert_eq!(adj.window, 4.0);
        assert!(adj.ewma_ms > 1.0, "Hold still tracks the baseline");
    }

    #[test]
    fn static_mode_never_moves_the_window() {
        let mut c = AimdController::new(AimdOptions {
            adaptive: false,
            initial_window: 6.0,
            ..opts()
        });
        feed(&mut c, 1.0, 1);
        for ms in [1.0, 50.0, 0.1, 200.0] {
            let adj = feed(&mut c, ms, 1);
            assert_eq!(adj.verdict, Verdict::Hold);
            assert_eq!(c.window(), 6);
        }
    }

    #[test]
    fn try_admit_enforces_the_window() {
        let mut c = AimdController::new(AimdOptions { initial_window: 2.0, ..opts() });
        assert!(c.try_admit(0));
        assert!(c.try_admit(1));
        assert!(!c.try_admit(2));
        assert!(!c.try_admit(99));
    }

    #[test]
    fn window_is_clamped_into_bounds_at_construction() {
        let c = AimdController::new(AimdOptions { initial_window: 1000.0, ..opts() });
        assert_eq!(c.window(), 64);
        let c = AimdController::new(AimdOptions { initial_window: 0.0, ..opts() });
        assert_eq!(c.window(), 1);
    }
}
