//! Serving engine (S15c): the live model behind a swap point.
//!
//! The [`Engine`] owns the live [`ParamStore`] plus every in-flight
//! sequence's KV cache, and exposes the serving surface:
//!
//! * [`Engine::submit`] / [`Engine::poll`] — enqueue a generation request,
//!   collect its completion;
//! * [`Engine::tick`] — one scheduler round: admit queued requests into
//!   free slots, advance every in-flight sequence one token;
//! * [`Engine::hot_swap`] — between ticks, grow the live model with a
//!   function-preserving op sequence: surgery → preservation probe →
//!   KV-cache remap → atomic swap (see [`crate::serve::hotswap`]);
//! * [`Engine::counters`] — throughput/latency counters
//!   ([`crate::metrics::ServeCounters`]).
//!
//! Unless `EngineOptions::metrics` is off, the engine also publishes
//! live counters, queue/in-flight gauges and per-phase latency
//! histograms through a [`crate::obs::MetricsRegistry`] (the process
//! [`crate::obs::global`] one by default, an explicit one via
//! [`Engine::with_registry`]) and traces every request as a
//! [`crate::obs::Span`] — drained with [`Engine::take_spans`] for the
//! run log. The instrumentation is handle-based atomics, so the decode
//! hot path never takes a lock (`benches/runtime_overhead.rs` measures
//! the on/off cost).
//!
//! Ticks are synchronous and swaps only happen between them, so the swap
//! point needs no locking: the engine is single-owner, and intra-tick
//! parallelism (the shared [`crate::parallel::Pool`] decode fan-out)
//! never outlives the tick.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::expand::{ExpandOptions, ExpansionPlan};
use crate::generate::Sampler;
use crate::metrics::{PhasePercentiles, ServeCounters, Timer};
use crate::obs::{
    self, Counter, Gauge, Histogram, MetricsRegistry, Span, SpanRing, SpanTracker,
    LATENCY_MS_BOUNDS,
};
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::serve::hotswap::{self, SwapReport};
use crate::serve::kv::KvTier;
use crate::serve::scheduler::{Completion, Request, RequestId, Scheduler, TickReport};

/// Engine construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Maximum concurrently-decoding sequences (scheduler slots).
    pub max_slots: usize,
    /// Fan the per-slot decode out over the shared worker pool
    /// (`TEXPAND_THREADS`-sized; identical results either way).
    pub parallel: bool,
    /// Hot-swap preservation tolerance on the probe batch (same default as
    /// `TrainConfig::preserve_tol`).
    pub preserve_tol: f32,
    /// Rows in the synthesized held-out probe batch.
    pub probe_rows: usize,
    /// Seed for probe synthesis.
    pub probe_seed: u64,
    /// Queue backpressure: maximum queued + in-flight requests. `submit`
    /// rejects over-capacity (counted in `ServeCounters::rejected`);
    /// `0` disables the bound.
    pub max_pending: usize,
    /// Per-request deadline: a sequence still decoding after this many
    /// ticks in its slot is expired at the next tick — its partial output
    /// completes with [`crate::serve::FinishReason::TimedOut`] and frees
    /// the slot (counted in `ServeCounters::timeouts`). `0` disables.
    pub request_timeout_ticks: u64,
    /// Publish registry metrics + span traces (on by default; the off
    /// switch exists for the overhead benchmark and metrics-free embeds).
    pub metrics: bool,
    /// Span sampling: keep 1-in-N finished spans (`take_spans` + the
    /// live `/spans` ring). Counters and latency histograms always see
    /// every request regardless — sampling thins only the per-request
    /// trace stream. `0` and `1` both mean "keep everything".
    pub span_sample: u64,
    /// In-flight K/V storage tier ([`crate::serve::kv::KvTier`]): exact
    /// f32 (default), half-precision f16 (2× fewer resident bytes,
    /// ≤2⁻¹¹ relative error), or block-quantized i8 (≥3× fewer bytes,
    /// drift bounded as documented in DESIGN.md §17). Lossy caches ride
    /// hot-swaps exactly like exact ones (the remap reads the exact f32
    /// stream buffers in every tier).
    pub kv_tier: KvTier,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            max_slots: 8,
            parallel: true,
            preserve_tol: 1e-4,
            probe_rows: 2,
            probe_seed: 0xBEE,
            max_pending: 1024,
            request_timeout_ticks: 0,
            metrics: true,
            span_sample: 1,
            kv_tier: KvTier::F32,
        }
    }
}

/// Registry handles the engine publishes through (one registration at
/// construction; every update afterwards is a lock-free atomic bump).
struct EngineMetrics {
    submitted: Counter,
    completed: Counter,
    tokens_generated: Counter,
    prompt_tokens: Counter,
    rejected: Counter,
    timeouts: Counter,
    swaps: Counter,
    swap_rejected: Counter,
    queued: Gauge,
    in_flight: Gauge,
    queue_ms: Histogram,
    prefill_ms: Histogram,
    decode_ms: Histogram,
    total_ms: Histogram,
    swap_ms: Histogram,
    spans_dropped: Counter,
    preservation_drift: Gauge,
    kv_bytes_per_seq: Gauge,
}

impl EngineMetrics {
    fn register(reg: &MetricsRegistry) -> EngineMetrics {
        let lat = &LATENCY_MS_BOUNDS;
        EngineMetrics {
            submitted: reg.counter("texpand_serve_submitted_total", "Requests accepted by submit"),
            completed: reg.counter("texpand_serve_completed_total", "Requests finished normally"),
            tokens_generated: reg.counter("texpand_serve_tokens_generated_total", "Tokens decoded"),
            prompt_tokens: reg.counter("texpand_serve_prompt_tokens_total", "Primed prompt tokens"),
            rejected: reg.counter("texpand_serve_rejected_total", "Backpressure rejections"),
            timeouts: reg.counter("texpand_serve_timeouts_total", "Requests expired by deadline"),
            swaps: reg.counter("texpand_serve_swaps_total", "Successful hot swaps"),
            swap_rejected: reg.counter("texpand_serve_swap_rejected_total", "Rejected hot swaps"),
            queued: reg.gauge("texpand_serve_queued", "Requests waiting in queue"),
            in_flight: reg.gauge("texpand_serve_in_flight", "Sequences decoding in slots"),
            queue_ms: reg.histogram("texpand_serve_queue_latency_ms", "Queue wait (ms)", lat),
            prefill_ms: reg.histogram("texpand_serve_prefill_latency_ms", "Prompt prime (ms)", lat),
            decode_ms: reg.histogram("texpand_serve_decode_latency_ms", "Decode phase (ms)", lat),
            total_ms: reg.histogram("texpand_serve_total_latency_ms", "Submit to finish (ms)", lat),
            swap_ms: reg.histogram("texpand_serve_swap_ms", "Hot swap duration (ms)", lat),
            spans_dropped: reg
                .counter("texpand_spans_dropped_total", "Spans evicted from the live export ring"),
            preservation_drift: reg.gauge(
                "texpand_preservation_drift",
                "max|delta logits| on the probe batch at the latest hot swap",
            ),
            kv_bytes_per_seq: reg.gauge(
                "texpand_serve_kv_bytes_per_seq",
                "Largest resident K/V bytes of any in-flight sequence",
            ),
        }
    }
}

/// p50/p95/p99 snapshot of a phase histogram (for `ServeCounters`).
fn percentiles_of(h: &Histogram) -> PhasePercentiles {
    let s = h.snapshot();
    PhasePercentiles {
        p50_ms: s.quantile(0.50),
        p95_ms: s.quantile(0.95),
        p99_ms: s.quantile(0.99),
    }
}

/// Batched KV-cached inference engine with hot-swap (see module docs).
pub struct Engine {
    params: ParamStore,
    sched: Scheduler,
    completed: HashMap<RequestId, Completion>,
    counters: ServeCounters,
    opts: EngineOptions,
    /// Held-out probe batch (full-`seq` rows) for swap verification.
    probe: Vec<Vec<u32>>,
    /// Registry handles (`None` when `opts.metrics` is off).
    metrics: Option<EngineMetrics>,
    spans: SpanTracker,
    finished_spans: Vec<Span>,
    /// Live export ring shared with the `/spans` HTTP route (`None`
    /// unless [`Engine::set_span_ring`] attached one).
    span_ring: Option<Arc<SpanRing>>,
    /// Largest resident K/V byte count any single sequence has held
    /// (sampled every tick) — the per-sequence memory figure `--kv-quant`
    /// is judged by.
    peak_kv_bytes_per_seq: usize,
}

impl Engine {
    /// Build an engine serving `params`, publishing metrics through the
    /// process-global registry.
    pub fn new(params: ParamStore, opts: EngineOptions) -> Engine {
        Engine::with_registry(params, opts, obs::global())
    }

    /// Build an engine publishing through an explicit registry (tests and
    /// benchmarks; production uses [`Engine::new`]).
    pub fn with_registry(
        params: ParamStore,
        opts: EngineOptions,
        registry: &MetricsRegistry,
    ) -> Engine {
        let cfg = *params.config();
        let mut rng = Pcg32::new(opts.probe_seed, 0x9B0E);
        let probe = (0..opts.probe_rows.max(1))
            .map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect())
            .collect();
        let metrics = opts.metrics.then(|| EngineMetrics::register(registry));
        let mut sched = Scheduler::new(opts.max_slots);
        sched.kv_tier = opts.kv_tier;
        Engine {
            params,
            sched,
            completed: HashMap::new(),
            counters: ServeCounters::default(),
            opts,
            probe,
            metrics,
            spans: SpanTracker::new(),
            finished_spans: Vec::new(),
            span_ring: None,
            peak_kv_bytes_per_seq: 0,
        }
    }

    /// Attach the bounded ring the `/spans` route streams from: every
    /// kept span is also pushed there as a JSON line. Evictions (a slow
    /// or absent consumer) bump `texpand_spans_dropped_total`.
    pub fn set_span_ring(&mut self, ring: Arc<SpanRing>) {
        self.span_ring = Some(ring);
    }

    /// The live architecture (changes after a successful hot-swap).
    pub fn config(&self) -> &ModelConfig {
        self.params.config()
    }

    /// The live parameters.
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// Throughput/latency counters.
    pub fn counters(&self) -> &ServeCounters {
        &self.counters
    }

    /// Drain the spans of requests finished since the last call (empty
    /// when `EngineOptions::metrics` is off).
    pub fn take_spans(&mut self) -> Vec<Span> {
        std::mem::take(&mut self.finished_spans)
    }

    /// Queued + in-flight requests.
    pub fn pending(&self) -> usize {
        self.sched.queued() + self.sched.in_flight()
    }

    /// True when no request is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// True when `submit` would not be rejected by queue backpressure —
    /// the single definition of the admission predicate (callers that
    /// want to wait for capacity poll this and `tick` instead of
    /// re-deriving the rule).
    pub fn has_capacity(&self) -> bool {
        self.opts.max_pending == 0 || self.pending() < self.opts.max_pending
    }

    /// Enqueue a generation request; decoding starts at the next tick with
    /// a free slot.
    pub fn submit(
        &mut self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        sampler: Sampler,
    ) -> Result<RequestId> {
        self.submit_with_deadline(prompt, max_new_tokens, sampler, 0)
    }

    /// [`Engine::submit`] with a per-request deadline in scheduler ticks:
    /// the sequence is expired with its partial output once it has spent
    /// `timeout_ticks` ticks in a slot, overriding the engine-wide
    /// `request_timeout_ticks` for this request. `0` falls back to the
    /// engine-wide setting. The HTTP front-end maps wall-clock
    /// `deadline_ms` onto this.
    pub fn submit_with_deadline(
        &mut self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        sampler: Sampler,
        timeout_ticks: u64,
    ) -> Result<RequestId> {
        let cfg = self.params.config();
        if prompt.is_empty() {
            return Err(Error::Serve("empty prompt".into()));
        }
        if max_new_tokens == 0 {
            return Err(Error::Serve("max_new_tokens must be positive".into()));
        }
        if let Some(&t) = prompt.iter().find(|&&t| t as usize >= cfg.vocab) {
            return Err(Error::Serve(format!("prompt token {t} out of vocab {}", cfg.vocab)));
        }
        if !self.has_capacity() {
            self.counters.rejected += 1;
            if let Some(m) = &self.metrics {
                m.rejected.inc();
            }
            return Err(Error::Serve(format!(
                "engine at capacity: {} pending >= max_pending {} (backpressure)",
                self.pending(),
                self.opts.max_pending
            )));
        }
        self.counters.submitted += 1;
        let id = self.sched.enqueue(Request { prompt, max_new_tokens, sampler, timeout_ticks });
        if let Some(m) = &self.metrics {
            m.submitted.inc();
            m.queued.set(self.sched.queued() as f64);
            self.spans.on_submit(id, self.sched.ticks());
        }
        Ok(id)
    }

    /// Take a finished request's completion, if it has finished.
    pub fn poll(&mut self, id: RequestId) -> Option<Completion> {
        self.completed.remove(&id)
    }

    /// Incremental view of an in-flight request: `(prompt_len, generated
    /// tokens so far)`. `None` while still queued or once finished
    /// (use [`Engine::poll`] then). The HTTP front-end streams from this
    /// between ticks.
    pub fn partial(&self, id: RequestId) -> Option<(usize, &[u32])> {
        self.sched.partial(id)
    }

    /// Close a request's span: feed the phase histograms (tagging each
    /// bucket with the request id as its exemplar), refresh the
    /// percentile fields in `counters`, and — subject to
    /// `EngineOptions::span_sample` — stash the span for `take_spans`
    /// and the live export ring. Sampled-out requests still hit every
    /// counter and histogram; only the trace record is thinned.
    fn finish_span(&mut self, c: &Completion, finish: &'static str) {
        let Some(m) = &self.metrics else { return };
        let tick = self.sched.ticks();
        let Some(span) = self.spans.on_finish(c.id, tick, c.generated, finish) else { return };
        m.queue_ms.observe_with_exemplar(span.queue_ms, c.id);
        m.prefill_ms.observe_with_exemplar(span.prefill_ms, c.id);
        m.decode_ms.observe_with_exemplar(span.decode_ms, c.id);
        m.total_ms.observe_with_exemplar(span.total_ms, c.id);
        self.counters.queue_latency = percentiles_of(&m.queue_ms);
        self.counters.prefill_latency = percentiles_of(&m.prefill_ms);
        self.counters.decode_latency = percentiles_of(&m.decode_ms);
        self.counters.total_latency = percentiles_of(&m.total_ms);
        let sample = self.opts.span_sample.max(1);
        if c.id % sample != 0 {
            return;
        }
        if let Some(ring) = &self.span_ring {
            if ring.push(crate::json::Value::obj(span.fields()).to_string()) {
                m.spans_dropped.inc();
            }
        }
        self.finished_spans.push(span);
    }

    /// One scheduler round: expire timed-out slots, admit queued requests
    /// into the freed capacity, then advance every in-flight sequence one
    /// token.
    pub fn tick(&mut self) -> Result<TickReport> {
        let expired = self.sched.expire(self.opts.request_timeout_ticks);
        let timed_out = expired.len();
        for c in expired {
            self.counters.timeouts += 1;
            if let Some(m) = &self.metrics {
                m.timeouts.inc();
            }
            self.finish_span(&c, "timed_out");
            self.completed.insert(c.id, c);
        }

        let admissions = self.sched.admit(&self.params)?;
        let mut prompt_tokens = 0;
        for a in &admissions {
            prompt_tokens += a.prompt_tokens;
            self.counters.prime_ns += (a.prime_ms * 1e6) as u128;
            if let Some(m) = &self.metrics {
                m.prompt_tokens.add(a.prompt_tokens as u64);
                self.spans.on_admit(a.id, self.sched.ticks(), a.prompt_tokens, a.prime_ms);
            }
        }
        if !admissions.is_empty() {
            self.counters.prompt_tokens += prompt_tokens as u64;
        }

        let decode_timer = Timer::start();
        let decoding = self.sched.in_flight();
        let completions = self.sched.decode_tick(&self.params, self.opts.parallel)?;
        if decoding > 0 {
            self.counters.decode_ns += (decode_timer.ms() * 1e6) as u128;
            self.counters.tokens_generated += decoding as u64;
            self.counters.ticks += 1;
            if let Some(m) = &self.metrics {
                m.tokens_generated.add(decoding as u64);
            }
        }

        let report = TickReport {
            admitted: admissions.len(),
            prompt_tokens,
            decoded: decoding,
            completed: completions.len(),
            expired: timed_out,
        };
        for c in completions {
            self.counters.completed += 1;
            if let Some(m) = &self.metrics {
                m.completed.inc();
            }
            self.finish_span(&c, "max_tokens");
            self.completed.insert(c.id, c);
        }
        // sample before finished slots' caches are dropped next tick: the
        // per-sequence peak is the figure the kv_quant tier is judged by
        let kv_now = self.sched.max_kv_resident_bytes();
        self.peak_kv_bytes_per_seq = self.peak_kv_bytes_per_seq.max(kv_now);
        if let Some(m) = &self.metrics {
            m.queued.set(self.sched.queued() as f64);
            m.in_flight.set(self.sched.in_flight() as f64);
            m.kv_bytes_per_seq.set(kv_now as f64);
        }
        Ok(report)
    }

    /// Tick until every submitted request has completed.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.tick()?;
        }
        Ok(())
    }

    /// Scheduler ticks elapsed (swap scheduling).
    pub fn ticks(&self) -> u64 {
        self.sched.ticks()
    }

    /// Largest resident K/V byte count any single in-flight sequence has
    /// held so far (sampled each tick; 0 before any decode). Quantized
    /// engines report several-fold less than exact-f32 ones for the same
    /// workload — `benches/serving_latency.rs` records both.
    pub fn peak_kv_bytes_per_seq(&self) -> usize {
        self.peak_kv_bytes_per_seq
    }

    /// Zero-downtime function-preserving expansion of the live model.
    ///
    /// Runs between ticks: applies the plan to a copy of the live
    /// parameters (the plan's built-in probe gate verifies
    /// `max|Δ logits| ≤ preserve_tol` on the held-out probe batch), remaps
    /// every in-flight KV cache through the same plan, refreshes pending
    /// logits, and atomically swaps. On any failure — including a rejected
    /// probe — the live model and every cache are untouched and serving
    /// continues on the old parameters. The report pairs the plan's
    /// predicted deltas with the measured outcome.
    pub fn hot_swap(
        &mut self,
        plan: &ExpansionPlan,
        rng: &mut Pcg32,
        expand_opts: &ExpandOptions,
    ) -> Result<SwapReport> {
        let timer = Timer::start();
        let result = hotswap::hot_swap(
            &mut self.params,
            &mut self.sched.active,
            plan,
            rng,
            expand_opts,
            &self.probe,
            self.opts.preserve_tol,
        );
        match result {
            Ok(report) => {
                let ms = timer.ms();
                self.counters.swaps += 1;
                self.counters.swap_ns += (ms * 1e6) as u128;
                if let Some(m) = &self.metrics {
                    m.swaps.inc();
                    m.swap_ms.observe(ms);
                    m.preservation_drift.set(f64::from(report.probe_delta));
                }
                // the probe batch keeps its token content: none of the
                // paper's six ops touches seq or vocab, so the rows stay
                // valid full-`seq` windows under the new config
                Ok(report)
            }
            Err(e) => {
                if let Some(m) = &self.metrics {
                    m.swap_rejected.inc();
                }
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, LayerPosition};
    use crate::expand::Init;
    use crate::serve::FinishReason;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn engine(slots: usize) -> Engine {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        Engine::new(params, EngineOptions { max_slots: slots, parallel: false, ..Default::default() })
    }

    fn greedy() -> Sampler {
        Sampler { temperature: 0.0, top_k: None, seed: 0 }
    }

    #[test]
    fn submit_validates_requests() {
        let mut e = engine(2);
        assert!(e.submit(vec![], 4, greedy()).is_err());
        assert!(e.submit(vec![1], 0, greedy()).is_err());
        assert!(e.submit(vec![99], 4, greedy()).is_err());
        assert!(e.submit(vec![1, 2], 4, greedy()).is_ok());
        assert_eq!(e.counters().submitted, 1);
    }

    #[test]
    fn submit_poll_roundtrip_with_queueing() {
        let mut e = engine(2);
        let ids: Vec<_> =
            (0..5u32).map(|i| e.submit(vec![i % 16, (i + 1) % 16], 3, greedy()).unwrap()).collect();
        assert_eq!(e.pending(), 5);
        e.run_until_idle().unwrap();
        for id in &ids {
            let c = e.poll(*id).expect("completed");
            assert_eq!(c.generated, 3);
            assert_eq!(c.tokens.len(), 2 + 3);
            // poll is take-once
            assert!(e.poll(*id).is_none());
        }
        let stats = e.counters();
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.tokens_generated, 15);
        assert!(stats.tokens_per_sec() > 0.0);
    }

    #[test]
    fn hot_swap_grows_the_live_config_and_counts() {
        let mut e = engine(2);
        e.submit(vec![1, 2], 6, greedy()).unwrap();
        e.tick().unwrap();
        let plan = ExpansionPlan::new(
            e.config(),
            vec![
                GrowthOp::Mlp { p: 32 },
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
            ],
        )
        .unwrap();
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let before = e.params().num_scalars();
        let report = e.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap();
        assert_eq!(report.params_before, before);
        assert!(report.params_after > before);
        assert_eq!(report.params_after, report.params_predicted);
        assert!(report.probe_delta <= 1e-4);
        assert_eq!(report.remapped_sequences, 1);
        assert_eq!((e.config().mlp, e.config().layers), (32, 2));
        assert_eq!(e.counters().swaps, 1);
        e.run_until_idle().unwrap();
    }

    #[test]
    fn rejected_swap_leaves_engine_serving_old_params() {
        let mut e = engine(2);
        e.submit(vec![3], 4, greedy()).unwrap();
        e.tick().unwrap();
        // violate the zero-init constraints: probe must reject the swap
        let opts = ExpandOptions {
            init: Init::Normal(0.5),
            zero_constrained: false,
            ..Default::default()
        };
        let plan = ExpansionPlan::new(e.config(), vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        let err = e.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap_err().to_string();
        assert!(err.contains("rejected"), "{err}");
        assert_eq!(e.config(), &cfg(), "live config must be untouched");
        assert_eq!(e.counters().swaps, 0);
        e.run_until_idle().unwrap(); // decoding continues on the old model
    }

    #[test]
    fn quant_engine_serves_swaps_and_reports_smaller_kv() {
        // k = v = 16 so the per-block scale overhead amortizes past 3×
        let c = ModelConfig {
            layers: 1,
            hidden: 8,
            heads: 1,
            k: 16,
            v: 16,
            mlp: 16,
            seq: 8,
            vocab: 16,
        };
        let run = |kv_tier: KvTier| {
            let params = ParamStore::init(&c, &mut Pcg32::seeded(8), 0.05);
            let mut e = Engine::new(
                params,
                EngineOptions { max_slots: 2, parallel: false, kv_tier, ..Default::default() },
            );
            e.submit(vec![1, 2], 6, greedy()).unwrap();
            e.tick().unwrap();
            // a quantized cache must ride a mid-flight swap like an exact one
            let plan = ExpansionPlan::new(e.config(), vec![GrowthOp::Mlp { p: 32 }]).unwrap();
            let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
            let report = e.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap();
            assert_eq!(report.remapped_sequences, 1);
            e.run_until_idle().unwrap();
            assert_eq!(e.counters().completed, 1);
            e.peak_kv_bytes_per_seq()
        };
        let exact = run(KvTier::F32);
        let quant = run(KvTier::Int8);
        let half = run(KvTier::F16);
        assert!(exact > 0 && quant > 0 && half > 0);
        let ratio = exact as f64 / quant as f64;
        assert!(ratio >= 3.0, "peak KV bytes/seq ratio {ratio} below severalfold");
        // the f16 middle tier also rides the swap and lands between tiers
        assert!(half < exact && half > quant, "f16 {half} not between int8 {quant} and f32 {exact}");
    }

    #[test]
    fn submit_backpressure_rejects_over_capacity() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions { max_slots: 1, parallel: false, max_pending: 2, ..Default::default() },
        );
        assert!(e.has_capacity());
        assert!(e.submit(vec![1], 3, greedy()).is_ok());
        assert!(e.submit(vec![2], 3, greedy()).is_ok());
        assert!(!e.has_capacity(), "has_capacity is the submit admission predicate");
        let err = e.submit(vec![3], 3, greedy()).unwrap_err().to_string();
        assert!(err.contains("capacity"), "{err}");
        assert_eq!(e.counters().rejected, 1);
        assert_eq!(e.counters().submitted, 2, "rejected requests are not submissions");
        // draining frees capacity for new submissions
        e.run_until_idle().unwrap();
        assert!(e.has_capacity());
        assert!(e.submit(vec![3], 3, greedy()).is_ok());
    }

    #[test]
    fn request_timeout_expires_slot_with_partial_output() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions {
                max_slots: 2,
                parallel: false,
                request_timeout_ticks: 3,
                ..Default::default()
            },
        );
        // wants 50 tokens but is only allowed 3 ticks in its slot
        let slow = e.submit(vec![1, 2], 50, greedy()).unwrap();
        let fast = e.submit(vec![3], 2, greedy()).unwrap();
        e.run_until_idle().unwrap();
        let c = e.poll(slow).expect("timed-out request still completes");
        assert_eq!(c.finish, FinishReason::TimedOut);
        assert!(c.generated < 50, "partial output: {}", c.generated);
        assert!(c.generated >= 3, "got the ticks it was allowed: {}", c.generated);
        assert_eq!(c.tokens.len(), 2 + c.generated);
        let f = e.poll(fast).unwrap();
        assert_eq!(f.finish, FinishReason::MaxTokens);
        assert_eq!(e.counters().timeouts, 1);
        assert_eq!(e.counters().completed, 1, "only the fast request completed normally");
    }

    #[test]
    fn per_request_deadline_overrides_engine_default() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions {
                max_slots: 2,
                parallel: false,
                request_timeout_ticks: 0, // engine-wide deadline disabled
                ..Default::default()
            },
        );
        let strict = e.submit_with_deadline(vec![1, 2], 50, greedy(), 3).unwrap();
        // timeout_ticks 0 = unlimited: must run to its natural finish
        let unlimited = e.submit_with_deadline(vec![3], 40, greedy(), 0).unwrap();
        e.run_until_idle().unwrap();
        let c = e.poll(strict).expect("expired request still completes");
        assert_eq!(c.finish, FinishReason::TimedOut);
        assert!(c.generated >= 3 && c.generated < 50, "partial: {}", c.generated);
        let u = e.poll(unlimited).unwrap();
        assert_eq!(u.finish, FinishReason::MaxTokens);
        assert_eq!(u.generated, 40);
        assert_eq!(e.counters().timeouts, 1);
    }

    #[test]
    fn partial_streams_generated_prefix_of_final_completion() {
        let mut e = engine(1);
        let id = e.submit(vec![1, 2], 5, greedy()).unwrap();
        assert!(e.partial(id).is_none(), "still queued");
        let mut seen: Vec<u32> = Vec::new();
        while !e.is_idle() {
            e.tick().unwrap();
            if let Some((pl, gen)) = e.partial(id) {
                assert_eq!(pl, 2);
                assert_eq!(&gen[..seen.len()], &seen[..], "append-only stream");
                seen = gen.to_vec();
            }
        }
        let c = e.poll(id).unwrap();
        assert_eq!(&c.tokens[2..2 + seen.len()], &seen[..]);
        assert_eq!(c.tokens.len(), 2 + 5);
    }

    #[test]
    fn zero_knobs_disable_backpressure_and_timeouts() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions {
                max_slots: 1,
                parallel: false,
                max_pending: 0,
                request_timeout_ticks: 0,
                ..Default::default()
            },
        );
        for i in 0..10u32 {
            e.submit(vec![i % 16], 8, greedy()).unwrap();
        }
        e.run_until_idle().unwrap();
        assert_eq!(e.counters().completed, 10);
        assert_eq!(e.counters().rejected, 0);
        assert_eq!(e.counters().timeouts, 0);
    }

    #[test]
    fn spans_cover_completions_with_metrics_on() {
        let reg = MetricsRegistry::new();
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::with_registry(
            params,
            EngineOptions { max_slots: 2, parallel: false, ..Default::default() },
            &reg,
        );
        e.submit(vec![1, 2], 3, greedy()).unwrap();
        e.submit(vec![3], 4, greedy()).unwrap();
        e.run_until_idle().unwrap();
        let spans = e.take_spans();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().all(|s| s.finish == "max_tokens"));
        assert!(spans.iter().all(|s| s.total_ms >= s.decode_ms));
        assert!(e.take_spans().is_empty(), "take_spans drains");
        let p = e.counters().decode_latency;
        assert!(p.p50_ms <= p.p95_ms && p.p95_ms <= p.p99_ms);
        let text = crate::obs::render(&reg);
        assert!(text.contains("texpand_serve_completed_total 2\n"), "{text}");
        assert!(text.contains("texpand_serve_tokens_generated_total 7\n"), "{text}");
    }

    #[test]
    fn span_sampling_thins_traces_but_not_counters() {
        let reg = MetricsRegistry::new();
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::with_registry(
            params,
            EngineOptions { max_slots: 2, parallel: false, span_sample: 2, ..Default::default() },
            &reg,
        );
        for i in 0..4u32 {
            e.submit(vec![i % 16], 3, greedy()).unwrap();
        }
        e.run_until_idle().unwrap();
        // ids 0..4, keep id % 2 == 0 → half the traces survive
        let spans = e.take_spans();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().all(|s| s.id % 2 == 0));
        // ...but aggregates saw all four requests
        assert_eq!(e.counters().completed, 4);
        let text = crate::obs::render(&reg);
        assert!(text.contains("texpand_serve_completed_total 4\n"), "{text}");
        assert!(text.contains("texpand_serve_total_latency_ms_count 4\n"), "{text}");
    }

    #[test]
    fn span_ring_receives_json_lines_and_counts_drops() {
        let reg = MetricsRegistry::new();
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::with_registry(
            params,
            EngineOptions { max_slots: 2, parallel: false, ..Default::default() },
            &reg,
        );
        let ring = Arc::new(SpanRing::new(3));
        e.set_span_ring(Arc::clone(&ring));
        for i in 0..5u32 {
            e.submit(vec![i % 16], 2, greedy()).unwrap();
        }
        e.run_until_idle().unwrap();
        // capacity 3, 5 spans pushed → 2 evictions, newest 3 retained
        assert_eq!(ring.len(), 3);
        let (lines, _) = ring.read_from(0);
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let v = crate::json::Value::parse(line).unwrap();
            assert_eq!(v.req("finish").unwrap().as_str().unwrap(), "max_tokens");
        }
        let text = crate::obs::render(&reg);
        assert!(text.contains("texpand_spans_dropped_total 2\n"), "{text}");
    }

    #[test]
    fn metrics_off_engine_tracks_no_spans() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions { max_slots: 2, parallel: false, metrics: false, ..Default::default() },
        );
        e.submit(vec![1], 3, greedy()).unwrap();
        e.run_until_idle().unwrap();
        assert!(e.take_spans().is_empty());
        assert_eq!(e.counters().completed, 1);
        let p = e.counters().decode_latency;
        assert_eq!((p.p50_ms, p.p95_ms, p.p99_ms), (0.0, 0.0, 0.0));
    }
}
