//! Serving engine (S15c): the live model behind a swap point.
//!
//! The [`Engine`] owns the live [`ParamStore`] plus every in-flight
//! sequence's KV cache, and exposes the serving surface:
//!
//! * [`Engine::submit`] / [`Engine::poll`] — enqueue a generation request,
//!   collect its completion;
//! * [`Engine::tick`] — one scheduler round: admit queued requests into
//!   free slots, advance every in-flight sequence one token;
//! * [`Engine::hot_swap`] — between ticks, grow the live model with a
//!   function-preserving op sequence: surgery → preservation probe →
//!   KV-cache remap → atomic swap (see [`crate::serve::hotswap`]);
//! * [`Engine::counters`] — throughput/latency counters
//!   ([`crate::metrics::ServeCounters`]).
//!
//! Ticks are synchronous and swaps only happen between them, so the swap
//! point needs no locking: the engine is single-owner, and intra-tick
//! parallelism (the shared [`crate::parallel::Pool`] decode fan-out)
//! never outlives the tick.

use std::collections::HashMap;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::expand::{ExpandOptions, ExpansionPlan};
use crate::generate::Sampler;
use crate::metrics::{ServeCounters, Timer};
use crate::params::ParamStore;
use crate::rng::Pcg32;
use crate::serve::hotswap::{self, SwapReport};
use crate::serve::scheduler::{Completion, Request, RequestId, Scheduler, TickReport};

/// Engine construction knobs.
#[derive(Clone, Copy, Debug)]
pub struct EngineOptions {
    /// Maximum concurrently-decoding sequences (scheduler slots).
    pub max_slots: usize,
    /// Fan the per-slot decode out over the shared worker pool
    /// (`TEXPAND_THREADS`-sized; identical results either way).
    pub parallel: bool,
    /// Hot-swap preservation tolerance on the probe batch (same default as
    /// `TrainConfig::preserve_tol`).
    pub preserve_tol: f32,
    /// Rows in the synthesized held-out probe batch.
    pub probe_rows: usize,
    /// Seed for probe synthesis.
    pub probe_seed: u64,
    /// Queue backpressure: maximum queued + in-flight requests. `submit`
    /// rejects over-capacity (counted in `ServeCounters::rejected`);
    /// `0` disables the bound.
    pub max_pending: usize,
    /// Per-request deadline: a sequence still decoding after this many
    /// ticks in its slot is expired at the next tick — its partial output
    /// completes with [`crate::serve::FinishReason::TimedOut`] and frees
    /// the slot (counted in `ServeCounters::timeouts`). `0` disables.
    pub request_timeout_ticks: u64,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            max_slots: 8,
            parallel: true,
            preserve_tol: 1e-4,
            probe_rows: 2,
            probe_seed: 0xBEE,
            max_pending: 1024,
            request_timeout_ticks: 0,
        }
    }
}

/// Batched KV-cached inference engine with hot-swap (see module docs).
pub struct Engine {
    params: ParamStore,
    sched: Scheduler,
    completed: HashMap<RequestId, Completion>,
    counters: ServeCounters,
    opts: EngineOptions,
    /// Held-out probe batch (full-`seq` rows) for swap verification.
    probe: Vec<Vec<u32>>,
}

impl Engine {
    /// Build an engine serving `params`.
    pub fn new(params: ParamStore, opts: EngineOptions) -> Engine {
        let cfg = *params.config();
        let mut rng = Pcg32::new(opts.probe_seed, 0x9B0E);
        let probe = (0..opts.probe_rows.max(1))
            .map(|_| (0..cfg.seq).map(|_| rng.below(cfg.vocab) as u32).collect())
            .collect();
        Engine {
            params,
            sched: Scheduler::new(opts.max_slots),
            completed: HashMap::new(),
            counters: ServeCounters::default(),
            opts,
            probe,
        }
    }

    /// The live architecture (changes after a successful hot-swap).
    pub fn config(&self) -> &ModelConfig {
        self.params.config()
    }

    /// The live parameters.
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// Throughput/latency counters.
    pub fn counters(&self) -> &ServeCounters {
        &self.counters
    }

    /// Queued + in-flight requests.
    pub fn pending(&self) -> usize {
        self.sched.queued() + self.sched.in_flight()
    }

    /// True when no request is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// True when `submit` would not be rejected by queue backpressure —
    /// the single definition of the admission predicate (callers that
    /// want to wait for capacity poll this and `tick` instead of
    /// re-deriving the rule).
    pub fn has_capacity(&self) -> bool {
        self.opts.max_pending == 0 || self.pending() < self.opts.max_pending
    }

    /// Enqueue a generation request; decoding starts at the next tick with
    /// a free slot.
    pub fn submit(
        &mut self,
        prompt: Vec<u32>,
        max_new_tokens: usize,
        sampler: Sampler,
    ) -> Result<RequestId> {
        let cfg = self.params.config();
        if prompt.is_empty() {
            return Err(Error::Serve("empty prompt".into()));
        }
        if max_new_tokens == 0 {
            return Err(Error::Serve("max_new_tokens must be positive".into()));
        }
        if let Some(&t) = prompt.iter().find(|&&t| t as usize >= cfg.vocab) {
            return Err(Error::Serve(format!("prompt token {t} out of vocab {}", cfg.vocab)));
        }
        if !self.has_capacity() {
            self.counters.rejected += 1;
            return Err(Error::Serve(format!(
                "engine at capacity: {} pending >= max_pending {} (backpressure)",
                self.pending(),
                self.opts.max_pending
            )));
        }
        self.counters.submitted += 1;
        Ok(self.sched.enqueue(Request { prompt, max_new_tokens, sampler }))
    }

    /// Take a finished request's completion, if it has finished.
    pub fn poll(&mut self, id: RequestId) -> Option<Completion> {
        self.completed.remove(&id)
    }

    /// One scheduler round: expire timed-out slots, admit queued requests
    /// into the freed capacity, then advance every in-flight sequence one
    /// token.
    pub fn tick(&mut self) -> Result<TickReport> {
        let expired = self.sched.expire(self.opts.request_timeout_ticks);
        let timed_out = expired.len();
        for c in expired {
            self.counters.timeouts += 1;
            self.completed.insert(c.id, c);
        }

        let prime_timer = Timer::start();
        let (admitted, prompt_tokens) = self.sched.admit(&self.params)?;
        if admitted > 0 {
            self.counters.prime_ns += (prime_timer.ms() * 1e6) as u128;
            self.counters.prompt_tokens += prompt_tokens as u64;
        }

        let decode_timer = Timer::start();
        let decoding = self.sched.in_flight();
        let completions = self.sched.decode_tick(&self.params, self.opts.parallel)?;
        if decoding > 0 {
            self.counters.decode_ns += (decode_timer.ms() * 1e6) as u128;
            self.counters.tokens_generated += decoding as u64;
            self.counters.ticks += 1;
        }

        let report = TickReport {
            admitted,
            prompt_tokens,
            decoded: decoding,
            completed: completions.len(),
            expired: timed_out,
        };
        for c in completions {
            self.counters.completed += 1;
            self.completed.insert(c.id, c);
        }
        Ok(report)
    }

    /// Tick until every submitted request has completed.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.tick()?;
        }
        Ok(())
    }

    /// Scheduler ticks elapsed (swap scheduling).
    pub fn ticks(&self) -> u64 {
        self.sched.ticks()
    }

    /// Zero-downtime function-preserving expansion of the live model.
    ///
    /// Runs between ticks: applies the plan to a copy of the live
    /// parameters (the plan's built-in probe gate verifies
    /// `max|Δ logits| ≤ preserve_tol` on the held-out probe batch), remaps
    /// every in-flight KV cache through the same plan, refreshes pending
    /// logits, and atomically swaps. On any failure — including a rejected
    /// probe — the live model and every cache are untouched and serving
    /// continues on the old parameters. The report pairs the plan's
    /// predicted deltas with the measured outcome.
    pub fn hot_swap(
        &mut self,
        plan: &ExpansionPlan,
        rng: &mut Pcg32,
        expand_opts: &ExpandOptions,
    ) -> Result<SwapReport> {
        let timer = Timer::start();
        let report = hotswap::hot_swap(
            &mut self.params,
            &mut self.sched.active,
            plan,
            rng,
            expand_opts,
            &self.probe,
            self.opts.preserve_tol,
        )?;
        self.counters.swaps += 1;
        self.counters.swap_ns += (timer.ms() * 1e6) as u128;
        // the probe batch keeps its token content: none of the paper's six
        // ops touches seq or vocab, so the rows stay valid full-`seq`
        // windows under the new config
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GrowthOp, LayerPosition};
    use crate::expand::Init;
    use crate::serve::FinishReason;

    fn cfg() -> ModelConfig {
        ModelConfig { layers: 1, hidden: 8, heads: 1, k: 4, v: 4, mlp: 16, seq: 8, vocab: 16 }
    }

    fn engine(slots: usize) -> Engine {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        Engine::new(params, EngineOptions { max_slots: slots, parallel: false, ..Default::default() })
    }

    fn greedy() -> Sampler {
        Sampler { temperature: 0.0, top_k: None, seed: 0 }
    }

    #[test]
    fn submit_validates_requests() {
        let mut e = engine(2);
        assert!(e.submit(vec![], 4, greedy()).is_err());
        assert!(e.submit(vec![1], 0, greedy()).is_err());
        assert!(e.submit(vec![99], 4, greedy()).is_err());
        assert!(e.submit(vec![1, 2], 4, greedy()).is_ok());
        assert_eq!(e.counters().submitted, 1);
    }

    #[test]
    fn submit_poll_roundtrip_with_queueing() {
        let mut e = engine(2);
        let ids: Vec<_> =
            (0..5u32).map(|i| e.submit(vec![i % 16, (i + 1) % 16], 3, greedy()).unwrap()).collect();
        assert_eq!(e.pending(), 5);
        e.run_until_idle().unwrap();
        for id in &ids {
            let c = e.poll(*id).expect("completed");
            assert_eq!(c.generated, 3);
            assert_eq!(c.tokens.len(), 2 + 3);
            // poll is take-once
            assert!(e.poll(*id).is_none());
        }
        let stats = e.counters();
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.tokens_generated, 15);
        assert!(stats.tokens_per_sec() > 0.0);
    }

    #[test]
    fn hot_swap_grows_the_live_config_and_counts() {
        let mut e = engine(2);
        e.submit(vec![1, 2], 6, greedy()).unwrap();
        e.tick().unwrap();
        let plan = ExpansionPlan::new(
            e.config(),
            vec![
                GrowthOp::Mlp { p: 32 },
                GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top },
            ],
        )
        .unwrap();
        let opts = ExpandOptions { init: Init::Normal(0.3), ..Default::default() };
        let before = e.params().num_scalars();
        let report = e.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap();
        assert_eq!(report.params_before, before);
        assert!(report.params_after > before);
        assert_eq!(report.params_after, report.params_predicted);
        assert!(report.probe_delta <= 1e-4);
        assert_eq!(report.remapped_sequences, 1);
        assert_eq!((e.config().mlp, e.config().layers), (32, 2));
        assert_eq!(e.counters().swaps, 1);
        e.run_until_idle().unwrap();
    }

    #[test]
    fn rejected_swap_leaves_engine_serving_old_params() {
        let mut e = engine(2);
        e.submit(vec![3], 4, greedy()).unwrap();
        e.tick().unwrap();
        // violate the zero-init constraints: probe must reject the swap
        let opts = ExpandOptions {
            init: Init::Normal(0.5),
            zero_constrained: false,
            ..Default::default()
        };
        let plan = ExpansionPlan::new(e.config(), vec![GrowthOp::Mlp { p: 32 }]).unwrap();
        let err = e.hot_swap(&plan, &mut Pcg32::seeded(9), &opts).unwrap_err().to_string();
        assert!(err.contains("rejected"), "{err}");
        assert_eq!(e.config(), &cfg(), "live config must be untouched");
        assert_eq!(e.counters().swaps, 0);
        e.run_until_idle().unwrap(); // decoding continues on the old model
    }

    #[test]
    fn submit_backpressure_rejects_over_capacity() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions { max_slots: 1, parallel: false, max_pending: 2, ..Default::default() },
        );
        assert!(e.has_capacity());
        assert!(e.submit(vec![1], 3, greedy()).is_ok());
        assert!(e.submit(vec![2], 3, greedy()).is_ok());
        assert!(!e.has_capacity(), "has_capacity is the submit admission predicate");
        let err = e.submit(vec![3], 3, greedy()).unwrap_err().to_string();
        assert!(err.contains("capacity"), "{err}");
        assert_eq!(e.counters().rejected, 1);
        assert_eq!(e.counters().submitted, 2, "rejected requests are not submissions");
        // draining frees capacity for new submissions
        e.run_until_idle().unwrap();
        assert!(e.has_capacity());
        assert!(e.submit(vec![3], 3, greedy()).is_ok());
    }

    #[test]
    fn request_timeout_expires_slot_with_partial_output() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions {
                max_slots: 2,
                parallel: false,
                request_timeout_ticks: 3,
                ..Default::default()
            },
        );
        // wants 50 tokens but is only allowed 3 ticks in its slot
        let slow = e.submit(vec![1, 2], 50, greedy()).unwrap();
        let fast = e.submit(vec![3], 2, greedy()).unwrap();
        e.run_until_idle().unwrap();
        let c = e.poll(slow).expect("timed-out request still completes");
        assert_eq!(c.finish, FinishReason::TimedOut);
        assert!(c.generated < 50, "partial output: {}", c.generated);
        assert!(c.generated >= 3, "got the ticks it was allowed: {}", c.generated);
        assert_eq!(c.tokens.len(), 2 + c.generated);
        let f = e.poll(fast).unwrap();
        assert_eq!(f.finish, FinishReason::MaxTokens);
        assert_eq!(e.counters().timeouts, 1);
        assert_eq!(e.counters().completed, 1, "only the fast request completed normally");
    }

    #[test]
    fn zero_knobs_disable_backpressure_and_timeouts() {
        let params = ParamStore::init(&cfg(), &mut Pcg32::seeded(2), 0.05);
        let mut e = Engine::new(
            params,
            EngineOptions {
                max_slots: 1,
                parallel: false,
                max_pending: 0,
                request_timeout_ticks: 0,
                ..Default::default()
            },
        );
        for i in 0..10u32 {
            e.submit(vec![i % 16], 8, greedy()).unwrap();
        }
        e.run_until_idle().unwrap();
        assert_eq!(e.counters().completed, 10);
        assert_eq!(e.counters().rejected, 0);
        assert_eq!(e.counters().timeouts, 0);
    }
}
