//! Serving subsystem (S15): KV-cached batched inference with zero-downtime
//! function-preserving model hot-swap.
//!
//! The production-facing layer of the stack, `texpand serve`'s engine:
//!
//! * [`kv`] — per-sequence KV + residual-stream cache; the incremental
//!   decode state and the object that is *remapped through expansion ops*
//!   at a hot-swap (the subsystem's central trick). Generic over a
//!   [`KvStorage`] backend ([`KvTier`], `--kv-quant=f16|int8`): exact f32
//!   ([`KvCache`]), half-precision f16 ([`F16KvCache`], 2× fewer resident
//!   bytes) or block-quantized i8 ([`QuantKvCache`], several-fold fewer).
//! * [`scheduler`] — request queue + continuous batching across in-flight
//!   sequences of different lengths; per-slot decode fans out over the
//!   shared [`crate::parallel::Pool`].
//! * [`engine`] — the live [`crate::params::ParamStore`] behind a swap
//!   point; `submit`/`poll`/`tick` plus counters, per-request deadlines
//!   ([`Engine::submit_with_deadline`]) and an incremental
//!   [`Engine::partial`] view for streaming consumers.
//! * [`hotswap`] — surgery → preservation probe → cache remap → atomic
//!   commit, the coordinator's boundary protocol transplanted under live
//!   traffic.
//! * [`http`] — the network face: a multi-client streaming HTTP server
//!   (`POST /v1/generate`, chunked NDJSON token stream) with AIMD
//!   adaptive admission control ([`http::AimdController`]).
//! * [`loadgen`] — synthetic open/closed-loop load generator behind
//!   `texpand loadgen`; drives the HTTP front-end and reports latency
//!   percentiles + tokens/sec as a `serve_http_load` bench series.
//!
//! Decode numerics are bit-compatible with the KV-less oracle
//! (`generate::generate_ref`): greedy decodes are token-identical, which
//! `tests/integration_serve.rs` asserts end to end, including across a
//! mid-flight hot-swap; `tests/integration_http.rs` extends the same
//! byte-identity claim to the HTTP streaming path.

pub mod engine;
pub mod hotswap;
pub mod http;
pub mod kv;
pub mod loadgen;
pub mod scheduler;

pub use engine::{Engine, EngineOptions};
pub use hotswap::SwapReport;
pub use kv::{F16KvCache, KvCache, KvCacheImpl, KvStorage, KvTier, QuantKvCache, QUANT_BLOCK};
pub use scheduler::{Admission, Completion, FinishReason, Request, RequestId, TickReport};

use crate::config::{GrowthOp, LayerPosition};
use crate::error::{Error, Result};

/// Parse a hot-swap op spec, the `--swap-ops` CLI syntax: comma-separated
/// `kind=value` items applied left to right.
///
/// ```text
/// mlp=256            Def 3.1: grow MLP width to 256
/// heads_add=2        Def 3.2: add 2 heads
/// heads_expand=32    Def 3.3: grow per-head value width to 32
/// attn_expand=32     Def 3.4: grow key/query width to 32
/// hidden=128         Def 3.5: grow hidden width to 128
/// layers_add=1@top   Def 3.6: insert 1 layer (`@top`, `@bottom` or `@<i>`)
/// ```
pub fn parse_swap_spec(spec: &str) -> Result<Vec<GrowthOp>> {
    let mut ops = Vec::new();
    for item in spec.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        let (kind, value) = item
            .split_once('=')
            .ok_or_else(|| Error::Cli(format!("swap op '{item}' is not kind=value")))?;
        let parse_n = |v: &str| -> Result<usize> {
            v.parse::<usize>()
                .map_err(|_| Error::Cli(format!("swap op '{item}': '{v}' is not an integer")))
        };
        let op = match kind {
            "mlp" => GrowthOp::Mlp { p: parse_n(value)? },
            "heads_add" => GrowthOp::HeadsAdd { count: parse_n(value)? },
            "heads_expand" => GrowthOp::HeadsExpand { v: parse_n(value)? },
            "attn_expand" => GrowthOp::AttnExpand { k: parse_n(value)? },
            "hidden" => GrowthOp::Hidden { h: parse_n(value)? },
            "layers_add" => {
                let (count, position) = match value.split_once('@') {
                    None => (parse_n(value)?, LayerPosition::Top),
                    Some((c, "top")) => (parse_n(c)?, LayerPosition::Top),
                    Some((c, "bottom")) => (parse_n(c)?, LayerPosition::Bottom),
                    Some((c, at)) => (parse_n(c)?, LayerPosition::At(parse_n(at)?)),
                };
                GrowthOp::LayersAdd { count, position }
            }
            other => {
                return Err(Error::Cli(format!(
                    "unknown swap op kind '{other}' \
                     (mlp|heads_add|heads_expand|attn_expand|hidden|layers_add)"
                )))
            }
        };
        ops.push(op);
    }
    if ops.is_empty() {
        return Err(Error::Cli(format!("swap spec '{spec}' contains no ops")));
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind() {
        let ops = parse_swap_spec(
            "mlp=256, heads_add=2, heads_expand=32, attn_expand=32, hidden=128, layers_add=1@top",
        )
        .unwrap();
        assert_eq!(ops.len(), 6);
        assert_eq!(ops[0], GrowthOp::Mlp { p: 256 });
        assert_eq!(ops[1], GrowthOp::HeadsAdd { count: 2 });
        assert_eq!(ops[5], GrowthOp::LayersAdd { count: 1, position: LayerPosition::Top });
    }

    #[test]
    fn layers_add_positions() {
        assert_eq!(
            parse_swap_spec("layers_add=2").unwrap()[0],
            GrowthOp::LayersAdd { count: 2, position: LayerPosition::Top }
        );
        assert_eq!(
            parse_swap_spec("layers_add=1@bottom").unwrap()[0],
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::Bottom }
        );
        assert_eq!(
            parse_swap_spec("layers_add=1@3").unwrap()[0],
            GrowthOp::LayersAdd { count: 1, position: LayerPosition::At(3) }
        );
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(parse_swap_spec("").is_err());
        assert!(parse_swap_spec("mlp").is_err());
        assert!(parse_swap_spec("mlp=abc").is_err());
        assert!(parse_swap_spec("shrink=4").is_err());
        assert!(parse_swap_spec("layers_add=1@sideways").is_err());
    }
}
