//! Synthetic load generator for the HTTP serve front-end (S21b):
//! `texpand loadgen`.
//!
//! Spawns N concurrent clients against a [`crate::serve::http::HttpServer`]
//! and reports what the *client* observed — end-to-end request latency
//! percentiles, streamed tokens/sec, and the 429/timeout/error breakdown —
//! the numbers the adaptive-admission acceptance benchmark compares across
//! controllers (DESIGN.md §18.4).
//!
//! Two arrival models:
//!
//! * **closed loop** (`rate_per_sec == 0`): each client fires its next
//!   request the moment the previous one finishes — concurrency is the
//!   offered load, the classic saturation probe;
//! * **open loop** (`rate_per_sec > 0`): request *i* is released at
//!   `i / rate` seconds after start regardless of completions — offered
//!   load is independent of service rate, which is what actually
//!   overloads a server (closed loops self-throttle and hide the knee).
//!
//! Requests draw prompt lengths round-robin from a configurable mix and
//! per-request token ids from seeded [`Pcg32`] streams, so a run is fully
//! reproducible from `(seed, options)`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::json::Value;
use crate::obs::http_post_stream;
use crate::rng::Pcg32;

/// Knobs for [`run`].
#[derive(Clone, Debug)]
pub struct LoadgenOptions {
    /// Target server, `host:port`.
    pub addr: String,
    /// Concurrent client workers.
    pub clients: usize,
    /// Total requests across all workers.
    pub requests: usize,
    /// Open-loop arrival rate in requests/sec; `0.0` = closed loop.
    pub rate_per_sec: f64,
    /// `max_new_tokens` per request.
    pub tokens: usize,
    /// Prompt lengths cycled per request index.
    pub prompt_mix: Vec<usize>,
    /// Per-request wall-clock deadline forwarded as `deadline_ms`
    /// (0 = none).
    pub deadline_ms: u64,
    /// Token-id range for synthetic prompts (must match the served
    /// model's vocab).
    pub vocab: usize,
    /// Base seed; request *i* draws from stream `seed ^ i`.
    pub seed: u64,
    /// Per-request client timeout.
    pub timeout: Duration,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            addr: "127.0.0.1:7080".into(),
            clients: 4,
            requests: 32,
            rate_per_sec: 0.0,
            tokens: 16,
            prompt_mix: vec![4, 8, 16],
            deadline_ms: 0,
            vocab: 128,
            seed: 0,
            timeout: Duration::from_secs(30),
        }
    }
}

/// Client-observed outcome of one loadgen run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    pub sent: usize,
    /// Streams that reached `"finish":"max_tokens"`.
    pub completed: usize,
    /// 429 answers (admission shed).
    pub rejected: usize,
    /// Streams that reached `"finish":"timeout"` (deadline expiry).
    pub timeouts: usize,
    /// Transport failures, non-429 error statuses, or truncated streams.
    pub errors: usize,
    /// Token ids received across all streams.
    pub tokens_streamed: usize,
    pub wall_ms: f64,
    /// Latency stats over *successful streams* (completed + timeouts):
    /// time from request start to terminal chunk.
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
    /// Streamed-token throughput over the whole run wall time.
    pub tokens_per_sec: f64,
    /// `"open"` or `"closed"`.
    pub mode: &'static str,
}

/// Build request `i`'s JSON body (hand-formatted: the body is the wire
/// protocol, worth seeing literally here).
fn request_body(opts: &LoadgenOptions, i: usize) -> String {
    let mut rng = Pcg32::new(opts.seed, 0x10AD ^ i as u64);
    let plen = opts.prompt_mix[i % opts.prompt_mix.len()].max(1);
    let ids: Vec<String> =
        (0..plen).map(|_| rng.below(opts.vocab.max(1)).to_string()).collect();
    format!(
        "{{\"tokens\":[{}],\"max_new_tokens\":{},\"deadline_ms\":{},\"temperature\":0,\"seed\":{i}}}",
        ids.join(","),
        opts.tokens,
        opts.deadline_ms,
    )
}

/// What one request resolved to.
enum Outcome {
    Completed(f64),
    TimedOut(f64),
    Rejected,
    Errored,
}

/// Fire request `i` and classify the result; `latency` is start→terminal
/// chunk for streamed responses.
fn fire(opts: &LoadgenOptions, i: usize, tokens_streamed: &AtomicUsize) -> Outcome {
    let body = request_body(opts, i);
    let started = Instant::now();
    let outcome = http_post_stream(
        &opts.addr,
        "/v1/generate",
        &body,
        opts.timeout,
        &mut |line| {
            if let Ok(v) = Value::parse(line) {
                if let Some(toks) = v.get("tokens") {
                    if let Ok(arr) = toks.as_arr() {
                        tokens_streamed.fetch_add(arr.len(), Ordering::Relaxed);
                    }
                }
            }
        },
    );
    let latency_ms = started.elapsed().as_secs_f64() * 1e3;
    match outcome {
        Ok(out) if out.status == 200 => {
            // terminal chunk decides the verdict
            let finish = out.lines.iter().rev().find_map(|line| {
                let v = Value::parse(line).ok()?;
                if v.get("done").is_some() {
                    Some(v.get("finish")?.as_str().ok()?.to_string())
                } else {
                    None
                }
            });
            match finish.as_deref() {
                Some("max_tokens") => Outcome::Completed(latency_ms),
                Some("timeout") => Outcome::TimedOut(latency_ms),
                _ => Outcome::Errored, // truncated stream or error chunk
            }
        }
        Ok(out) if out.status == 429 => Outcome::Rejected,
        Ok(_) | Err(_) => Outcome::Errored,
    }
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the load test (see module docs for the arrival models).
pub fn run(opts: &LoadgenOptions) -> Result<LoadReport> {
    if opts.requests == 0 {
        return Err(Error::Cli("loadgen needs --requests >= 1".into()));
    }
    if opts.clients == 0 {
        return Err(Error::Cli("loadgen needs --clients >= 1".into()));
    }
    if opts.prompt_mix.is_empty() {
        return Err(Error::Cli("loadgen needs a non-empty --prompt-mix".into()));
    }
    if opts.vocab == 0 {
        return Err(Error::Cli("loadgen needs --vocab >= 1".into()));
    }

    let next = Arc::new(AtomicUsize::new(0));
    let tokens_streamed = Arc::new(AtomicUsize::new(0));
    let latencies = Arc::new(Mutex::new(Vec::<f64>::new()));
    let counts = Arc::new([
        AtomicUsize::new(0), // completed
        AtomicUsize::new(0), // rejected
        AtomicUsize::new(0), // timeouts
        AtomicUsize::new(0), // errors
    ]);
    let start = Instant::now();

    let workers: Vec<_> = (0..opts.clients.min(opts.requests))
        .map(|_| {
            let opts = opts.clone();
            let next = Arc::clone(&next);
            let tokens_streamed = Arc::clone(&tokens_streamed);
            let latencies = Arc::clone(&latencies);
            let counts = Arc::clone(&counts);
            std::thread::spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= opts.requests {
                    break;
                }
                if opts.rate_per_sec > 0.0 {
                    // open loop: request i is due at i/rate after start,
                    // whether or not earlier requests have finished
                    let due = Duration::from_secs_f64(i as f64 / opts.rate_per_sec);
                    let elapsed = start.elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                }
                match fire(&opts, i, &tokens_streamed) {
                    Outcome::Completed(ms) => {
                        counts[0].fetch_add(1, Ordering::Relaxed);
                        latencies.lock().unwrap().push(ms);
                    }
                    Outcome::Rejected => {
                        counts[1].fetch_add(1, Ordering::Relaxed);
                    }
                    Outcome::TimedOut(ms) => {
                        counts[2].fetch_add(1, Ordering::Relaxed);
                        latencies.lock().unwrap().push(ms);
                    }
                    Outcome::Errored => {
                        counts[3].fetch_add(1, Ordering::Relaxed);
                    }
                }
            })
        })
        .collect();
    for w in workers {
        w.join().map_err(|_| Error::Serve("loadgen worker panicked".into()))?;
    }
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;

    let mut lat = Arc::try_unwrap(latencies)
        .map_err(|_| Error::Serve("loadgen latency vec still shared".into()))?
        .into_inner()
        .map_err(|_| Error::Serve("loadgen latency lock poisoned".into()))?;
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean_ms =
        if lat.is_empty() { 0.0 } else { lat.iter().sum::<f64>() / lat.len() as f64 };
    let streamed = tokens_streamed.load(Ordering::Relaxed);
    Ok(LoadReport {
        sent: opts.requests,
        completed: counts[0].load(Ordering::Relaxed),
        rejected: counts[1].load(Ordering::Relaxed),
        timeouts: counts[2].load(Ordering::Relaxed),
        errors: counts[3].load(Ordering::Relaxed),
        tokens_streamed: streamed,
        wall_ms,
        mean_ms,
        p50_ms: percentile(&lat, 0.50),
        p95_ms: percentile(&lat, 0.95),
        p99_ms: percentile(&lat, 0.99),
        max_ms: lat.last().copied().unwrap_or(0.0),
        tokens_per_sec: if wall_ms > 0.0 { streamed as f64 / (wall_ms / 1e3) } else { 0.0 },
        mode: if opts.rate_per_sec > 0.0 { "open" } else { "closed" },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_degenerate_options() {
        let base = LoadgenOptions::default();
        assert!(run(&LoadgenOptions { requests: 0, ..base.clone() }).is_err());
        assert!(run(&LoadgenOptions { clients: 0, ..base.clone() }).is_err());
        assert!(run(&LoadgenOptions { prompt_mix: vec![], ..base.clone() }).is_err());
        assert!(run(&LoadgenOptions { vocab: 0, ..base }).is_err());
    }

    #[test]
    fn request_bodies_are_reproducible_and_follow_the_mix() {
        let opts = LoadgenOptions {
            prompt_mix: vec![2, 5],
            tokens: 7,
            deadline_ms: 30,
            vocab: 16,
            seed: 42,
            ..Default::default()
        };
        let b0 = request_body(&opts, 0);
        assert_eq!(b0, request_body(&opts, 0), "same (seed, index) -> same body");
        assert_ne!(b0, request_body(&opts, 2), "different index -> different tokens");
        let v = Value::parse(&b0).unwrap();
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("max_new_tokens").unwrap().as_usize().unwrap(), 7);
        assert_eq!(v.get("deadline_ms").unwrap().as_usize().unwrap(), 30);
        let v1 = Value::parse(&request_body(&opts, 1)).unwrap();
        assert_eq!(v1.get("tokens").unwrap().as_arr().unwrap().len(), 5, "mix cycles");
        for t in v.get("tokens").unwrap().as_arr().unwrap() {
            assert!(t.as_usize().unwrap() < 16, "ids bounded by vocab");
        }
    }

    #[test]
    fn percentiles_interpolate_by_nearest_rank() {
        let lat = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&lat, 0.0), 1.0);
        assert_eq!(percentile(&lat, 0.50), 6.0);
        assert_eq!(percentile(&lat, 1.0), 10.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn unreachable_server_counts_errors_not_panics() {
        // reserved-port address nothing listens on
        let opts = LoadgenOptions {
            addr: "127.0.0.1:9".into(),
            clients: 2,
            requests: 3,
            timeout: Duration::from_millis(200),
            ..Default::default()
        };
        let report = run(&opts).unwrap();
        assert_eq!(report.sent, 3);
        assert_eq!(report.errors, 3);
        assert_eq!(report.completed, 0);
        assert_eq!(report.tokens_streamed, 0);
        assert_eq!(report.mode, "closed");
    }
}
